//! Serving metrics: latency percentiles, throughput, overhead breakdown
//! (feeds Fig. 14's scheduling-vs-execution split).

use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Per-request total latency, seconds.
    lat: Vec<f64>,
    /// Per-request scheduling (selection + batching) seconds.
    sched: Vec<f64>,
    /// Per-request kernel execution seconds.
    exec: Vec<f64>,
    /// FLOPs served.
    pub flops: f64,
    /// Wall-clock span of the run.
    pub span_secs: f64,
    /// Requests shed by the SLO admission controller
    /// ([`crate::serve::OverloadPolicy::Drop`]); shed requests never
    /// execute, so they contribute no latency sample.
    pub dropped: u64,
    /// Requests served under a mode-downgrade
    /// ([`crate::serve::OverloadPolicy::Degrade`]); these DO carry a
    /// latency sample (they executed) and are counted here on top.
    pub degraded: u64,
    /// Amortized allocation events on the serving hot path: pool and
    /// reservoir builds counted by loops that promise a zero-alloc
    /// steady state (the continuous-batching decode lane). The count
    /// is a function of the lane config and offered load — NEVER of
    /// how many steps ran — which is exactly what the decode lane's
    /// steady-state test pins.
    pub alloc_events: u64,
}

impl Metrics {
    pub fn record(&mut self, latency: f64, sched: f64, exec: f64, flops: f64) {
        self.lat.push(latency);
        self.sched.push(sched);
        self.exec.push(exec);
        self.flops += flops;
    }

    /// Pre-size the per-request reservoirs for `n` samples so the
    /// recording path never reallocates (one amortized build,
    /// accounted in [`Metrics::alloc_events`] by the caller).
    pub fn reserve(&mut self, n: usize) {
        self.lat.reserve(n);
        self.sched.reserve(n);
        self.exec.reserve(n);
    }

    pub fn count(&self) -> usize {
        self.lat.len()
    }

    /// Percentile of an ascending-sorted slice — the ONE index formula
    /// every latency report uses (per-lane via `latency_percentiles`,
    /// aggregate via `serve::MixedStats`), so per-lane and aggregate
    /// percentiles in the same table are always computed identically.
    pub(crate) fn pct(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx]
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut s = self.lat.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (Self::pct(&s, 0.5), Self::pct(&s, 0.95), Self::pct(&s, 0.99))
    }

    pub fn mean_latency(&self) -> f64 {
        if self.lat.is_empty() {
            0.0
        } else {
            self.lat.iter().sum::<f64>() / self.lat.len() as f64
        }
    }

    /// Fraction of serving time spent scheduling (Fig. 14).
    pub fn sched_fraction(&self) -> f64 {
        let s: f64 = self.sched.iter().sum();
        let e: f64 = self.exec.iter().sum();
        if s + e == 0.0 {
            0.0
        } else {
            s / (s + e)
        }
    }

    pub fn total_sched_secs(&self) -> f64 {
        self.sched.iter().sum()
    }

    pub fn total_exec_secs(&self) -> f64 {
        self.exec.iter().sum()
    }

    /// Requests per second over the run span.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_secs <= 0.0 {
            0.0
        } else {
            self.count() as f64 / self.span_secs
        }
    }

    pub fn gflops_per_sec(&self) -> f64 {
        if self.span_secs <= 0.0 {
            0.0
        } else {
            self.flops / self.span_secs / 1e9
        }
    }

    pub fn summary(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} sched%={:.2} thpt={:.1} rps {:.2} GFLOP/s",
            self.count(),
            Duration::from_secs_f64(self.mean_latency()),
            Duration::from_secs_f64(p50),
            Duration::from_secs_f64(p95),
            Duration::from_secs_f64(p99),
            100.0 * self.sched_fraction(),
            self.throughput_rps(),
            self.gflops_per_sec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record(i as f64, 0.1, i as f64 - 0.1, 1e9);
        }
        let (p50, p95, p99) = m.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert_eq!(m.count(), 100);
    }

    #[test]
    fn sched_fraction_sane() {
        let mut m = Metrics::default();
        m.record(1.0, 0.25, 0.75, 0.0);
        assert!((m.sched_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_do_not_panic() {
        // Zero admitted requests: every rate and percentile is a
        // well-defined 0.0, never NaN (the empty-trace guard the
        // metrics exports rely on).
        let m = Metrics::default();
        assert_eq!(m.mean_latency(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.latency_percentiles(), (0.0, 0.0, 0.0));
        assert_eq!(m.sched_fraction(), 0.0);
        assert_eq!(m.gflops_per_sec(), 0.0);
        assert_eq!(Metrics::pct(&[], 0.99), 0.0);
        assert!(!m.summary().contains("NaN"));
    }
}
