//! Runtime stage (paper §6.2, Fig. 6 right): shape → micro-kernel
//! selection, kernel construction (grid + padding), adaptive backend
//! choice, and the dynamic-shape serving loop.
//!
//! Everything here is sample-free: the only inputs are the offline
//! [`crate::compiler::MicroKernelLibrary`] and the concrete runtime
//! shape. Selection is a pure analytical pass over the compact kernel
//! set (microseconds — Fig. 14's scheduling sliver). Multi-op serving
//! (request lanes, bucketed plan cache) lives in [`crate::serve`];
//! the GEMM-only loop here delegates to a one-lane instance of it.

pub mod metrics;
pub mod select;
pub mod server;

pub use select::{HwMode, Selection, Selector};
pub use server::{Request, ServeOutcome, ServerConfig, ServingStats};
