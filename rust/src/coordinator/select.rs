//! Runtime micro-kernel selection + kernel construction (paper §6.2).
//!
//! Given the concrete (M, N, K) at request time, the selector evaluates
//! every library kernel with the analytical model — the offline stage
//! already folded empirical measurements into each kernel's `base_cost`
//! — and picks the argmin of estimated end-to-end time, including
//! padding waste (the padded problem is the top tile of the chain) and
//! per-launch overhead. Grid configuration falls out of the chosen tile
//! (`ceil(M/bm) x ceil(N/bn)` blocks, `ceil(K/bk)` reduction steps).

use std::time::Instant;

use crate::compiler::{MicroKernel, MicroKernelLibrary};
use crate::cost;
use crate::hw::HwSpec;
use crate::ir::{ceil_div, round_up, Contraction};

/// Backend restriction (paper Fig. 16 modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HwMode {
    /// Consider every library (the paper's default "Adaptive").
    Adaptive,
    /// Only libraries whose backend name matches.
    Only(&'static str),
}

/// The constructed kernel for one request.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Index of the owning library in the selector.
    pub lib: usize,
    /// Index of the micro-kernel within that library.
    pub kernel: usize,
    /// Problem shape padded up to L1-tile multiples.
    pub padded: [usize; 3],
    /// Launch grid: (M blocks, N blocks, K reduction steps).
    pub grid: [usize; 3],
    /// Analytical end-to-end estimate, seconds.
    pub est_secs: f64,
    /// Wall-clock spent selecting (Fig. 14 "scheduling" component).
    pub select_secs: f64,
}

/// Precomputed per-kernel constants for the allocation-free selection
/// hot path (§Perf: one `FastKernel` evaluation is ~25 ns, so scanning
/// a few hundred kernels stays well under the smallest kernel time).
#[derive(Debug, Clone)]
struct FastKernel {
    lib: usize,
    kernel: usize,
    l1: [usize; 3],
    base_cost: f64,
    /// dtype bytes of the library (load-slab coefficient).
    elem_bytes: f64,
    /// 1 / (top-level bandwidth in B/s).
    inv_bw: f64,
    /// level-1 unit count (parallel units the spatial grid maps onto).
    units: usize,
    /// launch overhead already scaled by the backend's launch factor.
    launch: f64,
    /// true when one executable call per (M, N) block is dispatched
    /// (the real PJRT constructor).
    per_block_launch: bool,
}

impl FastKernel {
    /// Eq. 2–4 at the top (grid) level, specialized and allocation-free.
    #[inline]
    fn estimate(&self, c: Contraction) -> (f64, [usize; 3], [usize; 3]) {
        let grid = [
            ceil_div(c.m, self.l1[0]),
            ceil_div(c.n, self.l1[1]),
            ceil_div(c.k, self.l1[2]),
        ];
        let padded =
            [grid[0] * self.l1[0], grid[1] * self.l1[1], grid[2] * self.l1[2]];
        // Eq. 2 at the grid level: load the A/B slabs of one reduction
        // step, pipelined against the block subchain.
        let t_load = (padded[0] * self.l1[2] + self.l1[2] * padded[1]) as f64
            * self.elem_bytes
            * self.inv_bw;
        let t_store = (padded[0] * padded[1]) as f64 * 4.0 * self.inv_bw;
        let n_t = grid[2] as f64;
        let t_temporal = t_load
            + (n_t - 1.0) * t_load.max(self.base_cost)
            + self.base_cost
            + t_store;
        // Eq. 3.
        let f_parallel = ceil_div(grid[0] * grid[1], self.units) as f64;
        let launches =
            if self.per_block_launch { (grid[0] * grid[1]) as f64 } else { 1.0 };
        (f_parallel * t_temporal + self.launch * launches, padded, grid)
    }
}

/// The runtime selector: one or more libraries (one per backend/dtype)
/// over a single hardware target.
pub struct Selector {
    pub hw: HwSpec,
    pub libraries: Vec<MicroKernelLibrary>,
    /// Added per grid-block launch (measured on the real testbed;
    /// simulator value on the paper testbeds).
    pub launch_overhead: f64,
    /// Flattened fast-path table over all libraries.
    fast: Vec<FastKernel>,
}

impl Selector {
    pub fn new(hw: HwSpec, libraries: Vec<MicroKernelLibrary>) -> Selector {
        let launch_overhead = match hw.name {
            "a100" => 4e-6,
            "xeon_8255c" => 1e-6,
            _ => 30e-6,
        };
        let per_block_launch = hw.name == "cpu_pjrt";
        let top_bw = hw.levels.last().unwrap().load_bw_gbps * 1e9;
        let units = hw.level(hw.n_levels() - 2).unit_count as usize;
        let mut fast = Vec::new();
        for (li, lib) in libraries.iter().enumerate() {
            for (ki, k) in lib.kernels.iter().enumerate() {
                fast.push(FastKernel {
                    lib: li,
                    kernel: ki,
                    l1: k.l1,
                    base_cost: k.base_cost,
                    elem_bytes: lib.dtype.bytes() as f64,
                    inv_bw: 1.0 / top_bw,
                    units,
                    launch: launch_overhead * hw.backends[k.backend].launch_factor,
                    per_block_launch,
                });
            }
        }
        Selector { hw, libraries, launch_overhead, fast }
    }

    /// Estimated end-to-end seconds for one kernel on one problem.
    pub fn estimate(&self, lib_idx: usize, k: &MicroKernel, c: Contraction) -> (f64, [usize; 3], [usize; 3]) {
        let lib = &self.libraries[lib_idx];
        let padded = [
            round_up(c.m, k.l1[0]),
            round_up(c.n, k.l1[1]),
            round_up(c.k, k.l1[2]),
        ];
        let grid = [
            ceil_div(c.m, k.l1[0]),
            ceil_div(c.n, k.l1[1]),
            ceil_div(c.k, k.l1[2]),
        ];
        let chain = k.chain(padded);
        // On GPU/CPU targets one launch covers the whole grid; on the
        // real PJRT path the constructor dispatches one executable call
        // per (M, N) block, so the overhead scales with the grid.
        let launches = if self.hw.name == "cpu_pjrt" {
            (grid[0] * grid[1]) as f64
        } else {
            1.0
        };
        let lf = self.hw.backends[k.backend].launch_factor;
        let secs = cost::cost_from(&self.hw, lib.dtype, &chain, 2, k.base_cost)
            .total_secs
            + self.launch_overhead * lf * launches;
        (secs, padded, grid)
    }

    /// Select the best micro-kernel for a runtime shape (§6.2) via the
    /// precomputed fast path (no allocation in the scan loop).
    pub fn select(&self, c: Contraction, mode: HwMode) -> Option<Selection> {
        let t0 = Instant::now();
        let mut best: Option<(f64, &FastKernel, [usize; 3], [usize; 3])> = None;
        match mode {
            HwMode::Adaptive => {
                for fk in &self.fast {
                    let (secs, padded, grid) = fk.estimate(c);
                    if best.as_ref().map(|b| secs < b.0).unwrap_or(true) {
                        best = Some((secs, fk, padded, grid));
                    }
                }
            }
            HwMode::Only(name) => {
                for fk in &self.fast {
                    let k = &self.libraries[fk.lib].kernels[fk.kernel];
                    if self.hw.backends[k.backend].name != name {
                        continue;
                    }
                    let (secs, padded, grid) = fk.estimate(c);
                    if best.as_ref().map(|b| secs < b.0).unwrap_or(true) {
                        best = Some((secs, fk, padded, grid));
                    }
                }
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        best.map(|(secs, fk, padded, grid)| Selection {
            lib: fk.lib,
            kernel: fk.kernel,
            padded,
            grid,
            est_secs: secs,
            select_secs: dt,
        })
    }

    pub fn kernel(&self, sel: &Selection) -> &MicroKernel {
        &self.libraries[sel.lib].kernels[sel.kernel]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;
    use crate::util::prop::{forall, prop_assert};

    fn selector_a100() -> Selector {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let f32lib =
            compile(&hw, DType::F32, &cfg, &mut prof, &CompileOpts::default()).library;
        let f16lib =
            compile(&hw, DType::F16, &cfg, &mut prof, &CompileOpts::default()).library;
        Selector::new(hw, vec![f32lib, f16lib])
    }

    fn gemm(m: usize, n: usize, k: usize) -> Contraction {
        Contraction { m, n, k, dtype: DType::F32 }
    }

    #[test]
    fn selects_for_arbitrary_shapes() {
        let s = selector_a100();
        for &(m, n, k) in &[(1, 768, 768), (77, 3072, 768), (4096, 4096, 4096), (5, 5, 5)] {
            let sel = s.select(gemm(m, n, k), HwMode::Adaptive).unwrap();
            // Padding invariants: padded >= shape, exact tile multiples.
            let kern = s.kernel(&sel);
            assert!(sel.padded[0] >= m && sel.padded[1] >= n && sel.padded[2] >= k);
            for d in 0..3 {
                assert_eq!(sel.padded[d] % kern.l1[d], 0);
                assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
            }
            assert!(sel.est_secs > 0.0);
        }
    }

    #[test]
    fn adaptive_beats_or_matches_fixed_modes() {
        // Fig. 16: the adaptive mode's estimate is min over backends.
        let s = selector_a100();
        for &m in &[1usize, 2, 4, 8, 16] {
            let c = gemm(m, 2048, 1024);
            let ad = s.select(c, HwMode::Adaptive).unwrap().est_secs;
            let cc = s.select(c, HwMode::Only("cuda_core_f32")).unwrap().est_secs;
            let tc = s.select(c, HwMode::Only("tensor_core_f16")).unwrap().est_secs;
            assert!(ad <= cc + 1e-12 && ad <= tc + 1e-12);
        }
    }

    #[test]
    fn skinny_shapes_pick_small_m_tiles() {
        let s = selector_a100();
        let sel = s.select(gemm(2, 4096, 1024), HwMode::Adaptive).unwrap();
        let kern = s.kernel(&sel);
        assert!(
            kern.l1[0] <= 32,
            "M=2 should not pick a tall tile, got {:?}",
            kern.l1
        );
    }

    #[test]
    fn selection_is_fast() {
        let s = selector_a100();
        let sel = s.select(gemm(384, 768, 2304), HwMode::Adaptive).unwrap();
        assert!(
            sel.select_secs < 2e-3,
            "selection too slow: {}s over {} kernels",
            sel.select_secs,
            s.libraries.iter().map(|l| l.kernels.len()).sum::<usize>()
        );
    }

    #[test]
    fn fast_path_matches_reference_estimate() {
        // The allocation-free FastKernel::estimate must agree with the
        // reference cost_from-based Selector::estimate exactly.
        let s = selector_a100();
        for &(m, n, k) in &[(1usize, 768usize, 768usize), (77, 2304, 768), (4096, 4096, 4096)] {
            let c = gemm(m, n, k);
            let sel = s.select(c, HwMode::Adaptive).unwrap();
            let kern = s.kernel(&sel);
            let (ref_secs, ref_padded, ref_grid) = s.estimate(sel.lib, kern, c);
            assert!((ref_secs - sel.est_secs).abs() < 1e-12 * ref_secs.max(1e-30));
            assert_eq!(ref_padded, sel.padded);
            assert_eq!(ref_grid, sel.grid);
        }
    }

    #[test]
    fn prop_padding_waste_bounded_by_one_tile() {
        let s = selector_a100();
        forall(
            "padding-bounded",
            60,
            0xBEEF,
            |r, size| {
                (
                    r.usize(1, 64 * size.max(1)),
                    r.usize(1, 4096),
                    r.usize(1, 4096),
                )
            },
            |&(m, n, k)| {
                let sel = s.select(gemm(m, n, k), HwMode::Adaptive).unwrap();
                let kern = s.kernel(&sel);
                prop_assert(
                    sel.padded[0] - m < kern.l1[0]
                        && sel.padded[1] - n < kern.l1[1]
                        && sel.padded[2] - k < kern.l1[2],
                    format!("padding exceeds a tile: {:?} for {:?}", sel.padded, (m, n, k)),
                )
            },
        );
    }
}
