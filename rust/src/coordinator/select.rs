//! Runtime micro-kernel selection + kernel construction (paper §6.2),
//! operator-generic.
//!
//! Given the concrete [`IterSpace`] at request time (op + dims), the
//! selector evaluates every library kernel of that op with the
//! analytical model — the offline stage already folded empirical
//! measurements into each kernel's `base_cost` — and picks the argmin
//! of estimated end-to-end time, including padding waste (the padded
//! problem is the top tile of the chain) and per-launch overhead. Grid
//! configuration falls out of the chosen tile via the op's padding
//! math (`ceil(dim/tile)` per axis).
//!
//! A space whose op has no native library loaded is served through the
//! op's measurement-alias chain, chased to its FIXPOINT: Conv2d →
//! Gemm, GroupedConv2d → BatchedGemm, FusedAttention → BatchedGemm. A
//! conv strategy space IS the (per-group) implicit-GEMM contraction
//! space, so the alias's tiles are directly applicable (the im2col
//! data movement is the runtime's job); an attention chain executes
//! [`crate::ir::OpSpec::chain_kernels`] cost-symmetric alias blocks
//! per tile (the runtime's two `gemm_dynamic` calls per head group),
//! so the alias estimate is scaled by the chain length — there is no
//! attention-specific selection side path.

use std::time::Instant;

use crate::compiler::{MicroKernel, MicroKernelLibrary};
use crate::cost;
use crate::hw::HwSpec;
use crate::ir::{ceil_div, DType, IterSpace, OpKind, Tile};

/// Backend restriction (paper Fig. 16 modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HwMode {
    /// Consider every library (the paper's default "Adaptive").
    Adaptive,
    /// Only libraries whose backend name matches.
    Only(&'static str),
}

/// The constructed kernel for one request.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Index of the owning library in the selector.
    pub lib: usize,
    /// Index of the micro-kernel within that library.
    pub kernel: usize,
    /// Problem shape padded up to L1-tile multiples.
    pub padded: Tile,
    /// Launch grid: blocks per axis (reduction axis = K chain steps).
    pub grid: Tile,
    /// Analytical end-to-end estimate, seconds.
    pub est_secs: f64,
    /// Wall-clock spent selecting (Fig. 14 "scheduling" component).
    pub select_secs: f64,
}

impl Selection {
    /// True when `other` is the same constructed plan: every field
    /// that affects execution (library, kernel, padded problem, grid,
    /// estimate) — everything except the wall-clock `select_secs`.
    /// This is the ONE definition of the equality the serving layer's
    /// plan cache guarantees between cached and fresh selection; keep
    /// it in sync when `Selection` grows an execution-relevant field.
    pub fn same_plan(&self, other: &Selection) -> bool {
        self.lib == other.lib
            && self.kernel == other.kernel
            && self.padded == other.padded
            && self.grid == other.grid
            && self.est_secs == other.est_secs
    }
}

/// Precomputed per-kernel constants for the allocation-free selection
/// hot path (§Perf: one `FastKernel` evaluation is tens of ns, so
/// scanning a few hundred kernels stays well under the smallest kernel
/// time). `Tile` is `Copy`, so the whole evaluation allocates nothing.
///
/// `pub(crate)` because the offline shape-space partitioner
/// ([`crate::dispatch`]) enumerates winners with exactly these
/// evaluations — ONE arithmetic path, so a table answer is bit-
/// identical to a fresh scan.
#[derive(Debug, Clone)]
pub(crate) struct FastKernel {
    pub(crate) lib: usize,
    pub(crate) kernel: usize,
    pub(crate) op: OpKind,
    pub(crate) l1: Tile,
    base_cost: f64,
    /// dtype of the owning library (operand-slab coefficient).
    dtype: DType,
    /// 1 / (top-level bandwidth in B/s).
    inv_bw: f64,
    /// level-1 unit count (parallel units the block grid maps onto).
    units: usize,
    /// launch overhead already scaled by the backend's launch factor.
    launch: f64,
    /// true when one executable call per parallel block is dispatched
    /// (the real PJRT constructor).
    per_block_launch: bool,
}

impl FastKernel {
    /// Eq. 2–4 at the top (grid) level, specialized and allocation-free.
    #[inline]
    pub(crate) fn estimate(&self, dims: Tile) -> (f64, Tile, Tile) {
        let spec = self.op.spec();
        let grid = dims.ceil_div(self.l1);
        let padded = grid.mul(self.l1);
        // Eq. 2 at the grid level: load the input slabs of one reduction
        // step, pipelined against the block subchain.
        let t_load =
            spec.load_bytes_per_step(padded, self.l1, self.dtype) * self.inv_bw;
        let t_store = spec.store_bytes(padded) * self.inv_bw;
        let n_t = spec.reduce_iters(padded, self.l1) as f64;
        let t_temporal = t_load
            + (n_t - 1.0) * t_load.max(self.base_cost)
            + self.base_cost
            + t_store;
        // Eq. 3.
        let blocks = spec.spatial_iters(padded, self.l1);
        let f_parallel = ceil_div(blocks, self.units) as f64;
        let launches = if self.per_block_launch { blocks as f64 } else { 1.0 };
        (f_parallel * t_temporal + self.launch * launches, padded, grid)
    }
}

/// The runtime selector: one or more libraries (one per op x backend x
/// dtype) over a single hardware target.
pub struct Selector {
    pub hw: HwSpec,
    pub libraries: Vec<MicroKernelLibrary>,
    /// Added per grid-block launch (measured on the real testbed;
    /// simulator value on the paper testbeds).
    pub launch_overhead: f64,
    /// Flattened fast-path table over all libraries (crate-visible so
    /// the dispatch-table builder scans the same entries in the same
    /// order).
    pub(crate) fast: Vec<FastKernel>,
}

impl Selector {
    pub fn new(hw: HwSpec, libraries: Vec<MicroKernelLibrary>) -> Selector {
        // Owned by the preset (like `is_real_testbed`): no name
        // string-matching here.
        let launch_overhead = hw.launch_overhead_secs;
        let per_block_launch = hw.is_real_testbed();
        let top_bw = hw.levels.last().unwrap().load_bw_gbps * 1e9;
        let units = hw.level(hw.n_levels() - 2).unit_count as usize;
        let mut fast = Vec::new();
        for (li, lib) in libraries.iter().enumerate() {
            for (ki, k) in lib.kernels.iter().enumerate() {
                fast.push(FastKernel {
                    lib: li,
                    kernel: ki,
                    op: lib.op,
                    l1: k.l1,
                    base_cost: k.base_cost,
                    dtype: lib.dtype,
                    inv_bw: 1.0 / top_bw,
                    units,
                    launch: launch_overhead * hw.backends[k.backend].launch_factor,
                    per_block_launch,
                });
            }
        }
        Selector { hw, libraries, launch_overhead, fast }
    }

    /// True when at least one loaded library serves `op` natively.
    pub fn has_op(&self, op: OpKind) -> bool {
        self.libraries.iter().any(|l| l.op == op)
    }

    /// The op a space is actually served with: exact match when a
    /// native library exists, otherwise the op's measurement-alias
    /// chain chased to its fixpoint — an op whose blocks are the
    /// alias's blocks (exact delegation: Conv2d → Gemm, GroupedConv2d
    /// → BatchedGemm via per-group implicit GEMM; fused chains:
    /// FusedAttention → BatchedGemm, one alias block per constituent
    /// kernel) is servable by the alias's tiles. Invariants: the chain
    /// preserves iteration-space rank (so alias tiles never rank-
    /// mismatch the space), and it terminates because every alias hop
    /// strictly reduces to a self-aliasing op. Ops whose chain ends
    /// with no library loaded make select() return None.
    ///
    /// Public because the serving layer's plan cache
    /// ([`crate::serve::PlanCache`]) derives its bucket key from the
    /// serving op's L1 tile set — the same fixpoint selection scans.
    pub fn serving_op(&self, op: OpKind) -> OpKind {
        let mut op = op;
        while !self.has_op(op) {
            let alias = op.spec().measurement_op();
            if alias == op {
                break;
            }
            op = alias;
        }
        op
    }

    /// Estimated end-to-end seconds for one kernel on one problem —
    /// the readable reference the fast path must agree with.
    pub fn estimate(
        &self,
        lib_idx: usize,
        k: &MicroKernel,
        space: IterSpace,
    ) -> (f64, Tile, Tile) {
        let lib = &self.libraries[lib_idx];
        let spec = lib.op.spec();
        let padded = space.dims.round_up_to(k.l1);
        let grid = space.dims.ceil_div(k.l1);
        let chain = k.chain(lib.op, padded);
        // On GPU/CPU targets one launch covers the whole grid; on the
        // real PJRT path the constructor dispatches one executable call
        // per parallel block, so the overhead scales with the grid.
        let launches = if self.hw.is_real_testbed() {
            spec.spatial_iters(padded, k.l1) as f64
        } else {
            1.0
        };
        let lf = self.hw.backends[k.backend].launch_factor;
        let secs = cost::cost_from(&self.hw, lib.dtype, &chain, 2, k.base_cost)
            .total_secs
            + self.launch_overhead * lf * launches;
        (secs, padded, grid)
    }

    /// Alias-chain estimate multiplier for a requested op: 1.0 when a
    /// native library serves it, otherwise the op's `chain_kernels()`
    /// (a fused chain dispatches one alias block per constituent
    /// kernel). The ONE definition shared by [`Selector::select_plan`]
    /// and the dispatch-table builder/lookup ([`crate::dispatch`]).
    pub fn chain_factor(&self, op: OpKind) -> f64 {
        if self.serving_op(op) == op {
            1.0
        } else {
            op.spec().chain_kernels() as f64
        }
    }

    /// True when `mode` admits this fast-path entry's backend.
    pub(crate) fn mode_admits(&self, fk: &FastKernel, mode: HwMode) -> bool {
        match mode {
            HwMode::Adaptive => true,
            HwMode::Only(name) => {
                let k = &self.libraries[fk.lib].kernels[fk.kernel];
                self.hw.backends[k.backend].name == name
            }
        }
    }

    /// Fast-path indices eligible to serve `(serving op, mode)`, in
    /// scan order — the ONE definition of eligibility shared by
    /// [`Selector::select_plan`]'s scan, the offline dispatch-table
    /// build ([`crate::dispatch`]) and the plan auditor
    /// ([`crate::analysis`]), so a table or audit verdict quantifies
    /// over exactly the kernels the online scan would consider.
    pub(crate) fn eligible_fast(&self, serving: OpKind, mode: HwMode) -> Vec<usize> {
        (0..self.fast.len())
            .filter(|&i| self.fast[i].op == serving && self.mode_admits(&self.fast[i], mode))
            .collect()
    }

    /// Construct the full [`Selection`] of one fast-path entry at a
    /// runtime shape WITHOUT re-scanning the library: the padded
    /// problem, grid and estimate all fall out of `(kernel, grid)` via
    /// the op's padding math. `select_secs` is 0 — the caller owns the
    /// wall-clock (the dispatch table reports its lookup time here).
    pub(crate) fn selection_from(&self, fast_idx: usize, dims: Tile, chain: f64) -> Selection {
        let fk = &self.fast[fast_idx];
        let (secs, padded, grid) = fk.estimate(dims);
        Selection {
            lib: fk.lib,
            kernel: fk.kernel,
            padded,
            grid,
            est_secs: secs * chain,
            select_secs: 0.0,
        }
    }

    /// The pure shape-generic argmin (§6.2): scan every admissible
    /// kernel of the serving op and keep the first strict minimum of
    /// the chain-scaled estimate. Deterministic in the space alone —
    /// `select_secs` is 0. [`Selector::select`] is this plus a timer;
    /// the offline dispatch table ([`crate::dispatch`]) enumerates the
    /// SAME function over padded-tile cells at compile time.
    pub fn select_plan(&self, space: IterSpace, mode: HwMode) -> Option<Selection> {
        let op = self.serving_op(space.op);
        let chain = self.chain_factor(space.op);
        let mut best: Option<(f64, &FastKernel, Tile, Tile)> = None;
        for fk in &self.fast {
            if fk.op != op || !self.mode_admits(fk, mode) {
                continue;
            }
            let (secs, padded, grid) = fk.estimate(space.dims);
            let secs = secs * chain;
            if best.as_ref().map(|b| secs < b.0).unwrap_or(true) {
                best = Some((secs, fk, padded, grid));
            }
        }
        best.map(|(secs, fk, padded, grid)| Selection {
            lib: fk.lib,
            kernel: fk.kernel,
            padded,
            grid,
            est_secs: secs,
            select_secs: 0.0,
        })
    }

    /// Select the best micro-kernel for a runtime space (§6.2) via the
    /// precomputed fast path (no allocation in the scan loop).
    ///
    /// When the space is served through a measurement alias (no native
    /// library), the estimate is scaled by the requested op's
    /// `chain_kernels()`: a fused chain dispatches one alias block
    /// strategy per constituent kernel. (A native library's
    /// `base_cost` already prices the whole chain, including the
    /// softmax micro-measurement, so no scaling applies there.)
    pub fn select<S: Into<IterSpace>>(&self, space: S, mode: HwMode) -> Option<Selection> {
        let space = space.into();
        let t0 = Instant::now();
        let mut sel = self.select_plan(space, mode);
        let dt = t0.elapsed().as_secs_f64();
        if let Some(s) = sel.as_mut() {
            s.select_secs = dt;
        }
        sel
    }

    pub fn kernel(&self, sel: &Selection) -> &MicroKernel {
        &self.libraries[sel.lib].kernels[sel.kernel]
    }

    /// The full runtime strategy chain a selection executes.
    pub fn chain(&self, sel: &Selection) -> crate::cost::Strategy {
        self.kernel(sel).chain(self.libraries[sel.lib].op, sel.padded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::{Contraction, DType};
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;
    use crate::util::prop::{forall, prop_assert};

    fn selector_a100() -> Selector {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let f32lib = compile(
            &hw,
            OpKind::Gemm,
            DType::F32,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let f16lib = compile(
            &hw,
            OpKind::Gemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        Selector::new(hw, vec![f32lib, f16lib])
    }

    fn gemm(m: usize, n: usize, k: usize) -> Contraction {
        Contraction { m, n, k, dtype: DType::F32 }
    }

    #[test]
    fn selects_for_arbitrary_shapes() {
        let s = selector_a100();
        for &(m, n, k) in &[(1, 768, 768), (77, 3072, 768), (4096, 4096, 4096), (5, 5, 5)] {
            let sel = s.select(gemm(m, n, k), HwMode::Adaptive).unwrap();
            // Padding invariants: padded >= shape, exact tile multiples.
            let kern = s.kernel(&sel);
            assert!(sel.padded[0] >= m && sel.padded[1] >= n && sel.padded[2] >= k);
            for d in 0..3 {
                assert_eq!(sel.padded[d] % kern.l1[d], 0);
                assert_eq!(sel.grid[d], sel.padded[d] / kern.l1[d]);
            }
            assert!(sel.est_secs > 0.0);
        }
    }

    #[test]
    fn adaptive_beats_or_matches_fixed_modes() {
        // Fig. 16: the adaptive mode's estimate is min over backends.
        let s = selector_a100();
        for &m in &[1usize, 2, 4, 8, 16] {
            let c = gemm(m, 2048, 1024);
            let ad = s.select(c, HwMode::Adaptive).unwrap().est_secs;
            let cc = s.select(c, HwMode::Only("cuda_core_f32")).unwrap().est_secs;
            let tc = s.select(c, HwMode::Only("tensor_core_f16")).unwrap().est_secs;
            assert!(ad <= cc + 1e-12 && ad <= tc + 1e-12);
        }
    }

    #[test]
    fn skinny_shapes_pick_small_m_tiles() {
        let s = selector_a100();
        let sel = s.select(gemm(2, 4096, 1024), HwMode::Adaptive).unwrap();
        let kern = s.kernel(&sel);
        assert!(
            kern.l1[0] <= 32,
            "M=2 should not pick a tall tile, got {:?}",
            kern.l1
        );
    }

    #[test]
    fn selection_is_fast() {
        // Deflaked: a single wall-clock sample is at the mercy of CI
        // scheduling hiccups, so assert on the MEDIAN of repeated
        // selections — one preempted scan cannot fail the tier-1 gate,
        // while a genuinely slow scan still does.
        let s = selector_a100();
        let mut samples: Vec<f64> = (0..9)
            .map(|_| {
                s.select(gemm(384, 768, 2304), HwMode::Adaptive)
                    .unwrap()
                    .select_secs
            })
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        assert!(
            median < 2e-3,
            "selection too slow: median {}s of {:?} over {} kernels",
            median,
            samples,
            s.libraries.iter().map(|l| l.kernels.len()).sum::<usize>()
        );
    }

    #[test]
    fn fast_path_matches_reference_estimate() {
        // The allocation-free FastKernel::estimate must agree with the
        // reference cost_from-based Selector::estimate exactly.
        let s = selector_a100();
        for &(m, n, k) in &[(1usize, 768usize, 768usize), (77, 2304, 768), (4096, 4096, 4096)] {
            let c = gemm(m, n, k);
            let sel = s.select(c, HwMode::Adaptive).unwrap();
            let kern = s.kernel(&sel);
            let (ref_secs, ref_padded, ref_grid) =
                s.estimate(sel.lib, kern, IterSpace::from(c));
            assert!((ref_secs - sel.est_secs).abs() < 1e-12 * ref_secs.max(1e-30));
            assert_eq!(ref_padded, sel.padded);
            assert_eq!(ref_grid, sel.grid);
        }
    }

    #[test]
    fn conv_space_falls_back_to_gemm_library() {
        let s = selector_a100();
        assert!(!s.has_op(OpKind::Conv2d));
        let space = IterSpace {
            op: OpKind::Conv2d,
            dims: Tile::from3([1352, 128, 576]),
            dtype: DType::F32,
        };
        let sel = s.select(space, HwMode::Adaptive).unwrap();
        // Same contraction dims through a gemm space must pick the same
        // kernel: conv's strategy space IS the contraction space.
        let g = s.select(gemm(1352, 128, 576), HwMode::Adaptive).unwrap();
        assert_eq!((sel.lib, sel.kernel), (g.lib, g.kernel));
        assert_eq!(sel.est_secs, g.est_secs);
    }

    #[test]
    fn batched_space_without_library_returns_none() {
        let s = selector_a100();
        let space = IterSpace::batched_gemm(8, 128, 128, 64, DType::F16);
        assert!(s.select(space, HwMode::Adaptive).is_none());
        // A grouped conv's alias chain ends at BatchedGemm, which has no
        // library here either — still None, never a rank-mismatched tile.
        let grouped = IterSpace {
            op: OpKind::GroupedConv2d,
            dims: Tile::new(&[32, 1568, 4, 288]),
            dtype: DType::F16,
        };
        assert!(s.select(grouped, HwMode::Adaptive).is_none());
    }

    #[test]
    fn grouped_conv_space_falls_back_to_batched_gemm_library() {
        // GroupedConv2d's strategy space IS the per-group batched
        // contraction space, so with only a BatchedGemm library loaded
        // the measurement-alias chain must serve it with the SAME
        // kernel the equivalent batched space picks.
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let lib = compile(
            &hw,
            OpKind::BatchedGemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let s = Selector::new(hw, vec![lib]);
        assert!(!s.has_op(OpKind::GroupedConv2d));
        let dims = Tile::new(&[64, 1568, 2, 18]); // depthwise-ish
        let grouped = IterSpace { op: OpKind::GroupedConv2d, dims, dtype: DType::F16 };
        let batched = IterSpace { op: OpKind::BatchedGemm, dims, dtype: DType::F16 };
        let g = s.select(grouped, HwMode::Adaptive).expect("grouped select");
        let b = s.select(batched, HwMode::Adaptive).expect("batched select");
        assert_eq!((g.lib, g.kernel), (b.lib, b.kernel));
        assert_eq!(g.est_secs, b.est_secs);
        assert_eq!(g.padded, b.padded);
    }

    #[test]
    fn attention_space_serves_through_batched_gemm_at_twice_the_estimate() {
        // The attention chain's blocks ARE batched-gemm blocks, two per
        // tile: through a BatchedGemm-only selector the SAME kernel is
        // picked (uniform 2x scaling preserves the argmin) and the
        // estimate is exactly chain_kernels() x the batched one.
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let lib = compile(
            &hw,
            OpKind::BatchedGemm,
            DType::F16,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let s = Selector::new(hw, vec![lib]);
        assert!(!s.has_op(OpKind::FusedAttention));
        let dims = Tile::new(&[24, 77, 77, 64]); // 2 x 12 heads, seq 77, hd 64
        let att = IterSpace { op: OpKind::FusedAttention, dims, dtype: DType::F16 };
        let bat = IterSpace { op: OpKind::BatchedGemm, dims, dtype: DType::F16 };
        let a = s.select(att, HwMode::Adaptive).expect("attention select");
        let b = s.select(bat, HwMode::Adaptive).expect("batched select");
        assert_eq!((a.lib, a.kernel), (b.lib, b.kernel));
        assert_eq!(a.padded, b.padded);
        assert_eq!(a.grid, b.grid);
        assert!(
            (a.est_secs - 2.0 * b.est_secs).abs() < 1e-12 * a.est_secs,
            "{} != 2 x {}",
            a.est_secs,
            b.est_secs
        );
    }

    #[test]
    fn prop_padding_waste_bounded_by_one_tile() {
        let s = selector_a100();
        forall(
            "padding-bounded",
            60,
            0xBEEF,
            |r, size| {
                (
                    r.usize(1, 64 * size.max(1)),
                    r.usize(1, 4096),
                    r.usize(1, 4096),
                )
            },
            |&(m, n, k)| {
                let sel = s.select(gemm(m, n, k), HwMode::Adaptive).unwrap();
                let kern = s.kernel(&sel);
                prop_assert(
                    sel.padded[0] - m < kern.l1[0]
                        && sel.padded[1] - n < kern.l1[1]
                        && sel.padded[2] - k < kern.l1[2],
                    format!("padding exceeds a tile: {:?} for {:?}", sel.padded, (m, n, k)),
                )
            },
        );
    }

    #[test]
    fn prop_estimate_monotone_in_problem_volume_for_fixed_tiles() {
        // Satellite: with the kernel (tiles) held fixed, the selection
        // estimate must be monotone in problem volume — an elementwise-
        // larger problem can never be estimated cheaper.
        let s = selector_a100();
        let kernels: Vec<(usize, MicroKernel)> = s
            .libraries
            .iter()
            .enumerate()
            .flat_map(|(li, l)| l.kernels.iter().map(move |k| (li, k.clone())))
            .collect();
        forall(
            "estimate-monotone-in-volume",
            80,
            0x1DEA,
            |r, size| {
                let ki = r.usize(0, kernels.len() - 1);
                let m = r.usize(1, 1 + 64 * size);
                let n = r.usize(1, 2048);
                let k = r.usize(1, 2048);
                let grow = (
                    m + r.usize(0, 512),
                    n + r.usize(0, 512),
                    k + r.usize(0, 512),
                );
                (ki, (m, n, k), grow)
            },
            |&(ki, (m, n, k), (gm, gn, gk))| {
                let (li, ref kern) = kernels[ki];
                let dt = s.libraries[li].dtype;
                let (small, _, _) =
                    s.estimate(li, kern, IterSpace::gemm(m, n, k, dt));
                let (large, _, _) =
                    s.estimate(li, kern, IterSpace::gemm(gm, gn, gk, dt));
                prop_assert(
                    large >= small,
                    format!(
                        "est not monotone: {:?} -> {} vs {:?} -> {}",
                        (m, n, k),
                        small,
                        (gm, gn, gk),
                        large
                    ),
                )
            },
        );
    }
}
