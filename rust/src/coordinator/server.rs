//! Dynamic-shape serving coordinator: the legacy single-op (GEMM)
//! request queue + dynamic batcher.
//!
//! This is the system-execution side of the paper's motivation (§2.1:
//! "dynamic adjustment of batch sizes ... demands adaptability in the
//! underlying tensor program"): requests with arbitrary sequence lengths
//! are merged along M (token rows), the merged GEMM takes whatever shape
//! it takes, and Vortex's sample-free selector is what makes serving it
//! efficient without a bucket/sample list.
//!
//! The discrete-event core now lives in the production serving
//! subsystem ([`crate::serve`]): [`serve_trace`] delegates to a
//! one-lane instance of [`crate::serve::serve_mixed_trace`], keeping
//! this GEMM-only API (and the `dynamic_batch_server` example built on
//! it) stable while multi-op traffic goes through `serve::` lanes.
//! The event clock charges a MODELED scheduling overhead
//! ([`crate::serve::SCHED_OVERHEAD_SECS`]) instead of this machine's
//! wall-clock selection time, so replay is deterministic; the measured
//! selection wall-clock still lands in [`Metrics`] as the scheduling
//! component.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::ir::{Contraction, DType, TensorProgram};
use crate::serve::{
    serve_mixed_trace, LaneClass, LaneConfig, LaneEngine, ServeConfig, ServeRequest,
};

/// One inference request: `rows` token rows to push through a GEMM of
/// width (n, k) — e.g. a BERT layer's QKV projection for one sequence.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: u64,
    pub rows: usize,
    /// Arrival time, seconds from trace start.
    pub arrive: f64,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// Max time the batcher waits after the first queued request.
    pub batch_window: f64,
    pub mode: HwMode,
    /// GEMM width shared by all requests (N, K of the served operator).
    pub n: usize,
    pub k: usize,
    /// Element type of the served requests. This is the REQUEST dtype
    /// the merged contraction is built with — previously the loop
    /// silently used `selector.libraries[0].dtype` regardless of which
    /// library selection actually picked.
    pub dtype: DType,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            batch_window: 2e-3,
            mode: HwMode::Adaptive,
            n: 768,
            k: 768,
            dtype: DType::F32,
        }
    }
}

/// Execution backend for the legacy GEMM serving loop.
pub trait Engine {
    /// Run the selected kernel on the (unpadded) problem; return the
    /// service time in seconds. May actually execute (real engine) or
    /// evaluate the simulator (paper testbeds).
    fn execute(&mut self, c: Contraction, sel: &Selection, selector: &Selector) -> f64;
    fn name(&self) -> &'static str;
}

/// Simulator-backed engine.
pub struct SimEngine {
    pub sim: crate::sim::Simulator,
}

impl Engine for SimEngine {
    fn execute(&mut self, _c: Contraction, sel: &Selection, selector: &Selector) -> f64 {
        // Service time is the padded chain's simulated execution.
        let lib = &selector.libraries[sel.lib];
        self.sim.execute(lib.dtype, &selector.chain(sel))
    }
    fn name(&self) -> &'static str {
        "sim"
    }
}

#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub id: u64,
    pub latency: f64,
    pub batch_size: usize,
}

#[derive(Debug, Default, Clone)]
pub struct ServingStats {
    pub metrics: Metrics,
    pub batches: usize,
    pub total_rows: usize,
    pub outcomes: Vec<ServeOutcome>,
}

impl ServingStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.metrics.count() as f64 / self.batches as f64
        }
    }
}

/// Deterministic discrete-event serving loop over a GEMM request
/// trace: a one-lane instance of [`crate::serve::serve_mixed_trace`].
/// Requests must be sorted by arrival time.
pub fn serve_trace(
    engine: &mut dyn Engine,
    selector: &Selector,
    cfg: &ServerConfig,
    requests: &[Request],
) -> ServingStats {
    // Adapt the legacy contraction-view engine onto the lane trait.
    struct Adapter<'a> {
        inner: &'a mut dyn Engine,
    }
    impl LaneEngine for Adapter<'_> {
        fn execute(
            &mut self,
            space: crate::ir::IterSpace,
            sel: &Selection,
            selector: &Selector,
        ) -> f64 {
            self.inner.execute(space.contraction(), sel, selector)
        }
        fn name(&self) -> &'static str {
            self.inner.name()
        }
    }

    let reqs: Vec<ServeRequest> = requests
        .iter()
        .map(|r| ServeRequest {
            id: r.id,
            program: TensorProgram::Gemm { m: r.rows, n: cfg.n, k: cfg.k, dtype: cfg.dtype },
            arrive: r.arrive,
            steps: 1,
        })
        .collect();
    let mut serve_cfg = ServeConfig { plan_cache: None, ..ServeConfig::default() };
    serve_cfg.lanes[LaneClass::Gemm.index()] = LaneConfig {
        max_batch: cfg.max_batch,
        batch_window: cfg.batch_window,
        mode: cfg.mode,
        ..LaneConfig::default()
    };
    let mixed = serve_mixed_trace(&mut Adapter { inner: engine }, selector, &serve_cfg, &reqs);

    let mut stats = ServingStats {
        outcomes: mixed
            .outcomes
            .iter()
            .map(|o| ServeOutcome { id: o.id, latency: o.latency, batch_size: o.batch_size })
            .collect(),
        ..ServingStats::default()
    };
    if let Some(lane) = mixed.lanes.into_iter().next() {
        stats.metrics = lane.metrics;
        stats.batches = lane.batches;
        stats.total_rows = lane.total_units;
    }
    stats
}

/// Generate a Poisson-ish request trace with varying sequence lengths
/// (the paper's BERT evaluation uses seq lens 1..476).
pub fn gen_trace(
    n_requests: usize,
    mean_interarrival: f64,
    rows_lo: usize,
    rows_hi: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut t = 0.0;
    (0..n_requests as u64)
        .map(|id| {
            t += rng.exp(mean_interarrival);
            Request { id, rows: rng.usize(rows_lo, rows_hi), arrive: t }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn setup() -> (Selector, SimEngine) {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let lib = compile(
            &hw,
            crate::ir::OpKind::Gemm,
            DType::F32,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let sel = Selector::new(hw.clone(), vec![lib]);
        (sel, SimEngine { sim: Simulator::new(hw, 5) })
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let (sel, mut eng) = setup();
        let trace = gen_trace(40, 1e-3, 1, 128, 9);
        let stats = serve_trace(&mut eng, &sel, &ServerConfig::default(), &trace);
        assert_eq!(stats.metrics.count(), 40);
        let mut ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
        ids.sort();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn latencies_nonnegative_and_span_positive() {
        let (sel, mut eng) = setup();
        let trace = gen_trace(25, 5e-4, 1, 64, 3);
        let stats = serve_trace(&mut eng, &sel, &ServerConfig::default(), &trace);
        assert!(stats.outcomes.iter().all(|o| o.latency >= 0.0));
        assert!(stats.metrics.span_secs > 0.0);
    }

    #[test]
    fn batching_respects_max_batch() {
        let (sel, mut eng) = setup();
        // All arrive at ~the same instant: batches must cap at max_batch.
        let trace: Vec<Request> =
            (0..20).map(|id| Request { id, rows: 16, arrive: 1e-6 * id as f64 }).collect();
        let cfg = ServerConfig { max_batch: 4, ..ServerConfig::default() };
        let stats = serve_trace(&mut eng, &sel, &cfg, &trace);
        assert!(stats.outcomes.iter().all(|o| o.batch_size <= 4));
        assert_eq!(stats.batches, 5);
    }

    #[test]
    fn bigger_batches_improve_throughput_under_load() {
        let (sel, mut eng1) = setup();
        let trace = gen_trace(60, 1e-5, 8, 64, 11);
        let solo = serve_trace(
            &mut eng1,
            &sel,
            &ServerConfig { max_batch: 1, ..ServerConfig::default() },
            &trace,
        );
        let (_, mut eng2) = setup();
        let batched = serve_trace(
            &mut eng2,
            &sel,
            &ServerConfig { max_batch: 16, ..ServerConfig::default() },
            &trace,
        );
        assert!(
            batched.metrics.span_secs < solo.metrics.span_secs,
            "batched {} !< solo {}",
            batched.metrics.span_secs,
            solo.metrics.span_secs
        );
    }

    #[test]
    fn request_dtype_threads_through_to_the_engine() {
        // The dtype-bug regression test: the merged contraction must be
        // built with the CONFIGURED request dtype, not whatever dtype
        // `selector.libraries[0]` happens to have.
        struct Probe {
            inner: SimEngine,
            dtypes: Vec<DType>,
        }
        impl Engine for Probe {
            fn execute(&mut self, c: Contraction, sel: &Selection, s: &Selector) -> f64 {
                self.dtypes.push(c.dtype);
                self.inner.execute(c, sel, s)
            }
            fn name(&self) -> &'static str {
                "probe"
            }
        }
        let hw = presets::a100();
        let acfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        // libraries[0] is F32 — the old code leaked F32 into every
        // request regardless of the served stream's dtype.
        let f32lib = compile(
            &hw,
            crate::ir::OpKind::Gemm,
            DType::F32,
            &acfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let f16lib = compile(
            &hw,
            crate::ir::OpKind::Gemm,
            DType::F16,
            &acfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let sel = Selector::new(hw.clone(), vec![f32lib, f16lib]);
        let mut probe =
            Probe { inner: SimEngine { sim: Simulator::new(hw, 5) }, dtypes: Vec::new() };
        let cfg = ServerConfig { dtype: DType::F16, ..ServerConfig::default() };
        let trace = gen_trace(10, 1e-3, 1, 64, 4);
        let stats = serve_trace(&mut probe, &sel, &cfg, &trace);
        assert_eq!(stats.metrics.count(), 10);
        assert!(!probe.dtypes.is_empty());
        assert!(
            probe.dtypes.iter().all(|&d| d == DType::F16),
            "request dtype not threaded: {:?}",
            probe.dtypes
        );
    }

    #[test]
    fn trace_generator_is_sorted_and_in_range() {
        let t = gen_trace(100, 1e-3, 5, 128, 1);
        assert!(t.windows(2).all(|w| w[0].arrive <= w[1].arrive));
        assert!(t.iter().all(|r| (5..=128).contains(&r.rows)));
    }
}
