//! Dynamic-shape serving coordinator: request queue + dynamic batcher.
//!
//! This is the system-execution side of the paper's motivation (§2.1:
//! "dynamic adjustment of batch sizes ... demands adaptability in the
//! underlying tensor program"): requests with arbitrary sequence lengths
//! are merged along M (token rows), the merged GEMM takes whatever shape
//! it takes, and Vortex's sample-free selector is what makes serving it
//! efficient without a bucket/sample list.
//!
//! The core is a deterministic discrete-event loop (`serve_trace`) usable
//! with both the simulated engines and the real PJRT engine; the
//! `dynamic_batch_server` example wraps it with real threads + channels.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::ir::Contraction;

/// One inference request: `rows` token rows to push through a GEMM of
/// width (n, k) — e.g. a BERT layer's QKV projection for one sequence.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: u64,
    pub rows: usize,
    /// Arrival time, seconds from trace start.
    pub arrive: f64,
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_batch: usize,
    /// Max time the batcher waits after the first queued request.
    pub batch_window: f64,
    pub mode: HwMode,
    /// GEMM width shared by all requests (N, K of the served operator).
    pub n: usize,
    pub k: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            batch_window: 2e-3,
            mode: HwMode::Adaptive,
            n: 768,
            k: 768,
        }
    }
}

/// Execution backend for the serving loop.
pub trait Engine {
    /// Run the selected kernel on the (unpadded) problem; return the
    /// service time in seconds. May actually execute (real engine) or
    /// evaluate the simulator (paper testbeds).
    fn execute(&mut self, c: Contraction, sel: &Selection, selector: &Selector) -> f64;
    fn name(&self) -> &'static str;
}

/// Simulator-backed engine.
pub struct SimEngine {
    pub sim: crate::sim::Simulator,
}

impl Engine for SimEngine {
    fn execute(&mut self, _c: Contraction, sel: &Selection, selector: &Selector) -> f64 {
        // Service time is the padded chain's simulated execution.
        let lib = &selector.libraries[sel.lib];
        self.sim.execute(lib.dtype, &selector.chain(sel))
    }
    fn name(&self) -> &'static str {
        "sim"
    }
}

#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub id: u64,
    pub latency: f64,
    pub batch_size: usize,
}

#[derive(Debug, Default, Clone)]
pub struct ServingStats {
    pub metrics: Metrics,
    pub batches: usize,
    pub total_rows: usize,
    pub outcomes: Vec<ServeOutcome>,
}

impl ServingStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.metrics.count() as f64 / self.batches as f64
        }
    }
}

/// Deterministic discrete-event serving loop over a request trace.
/// Requests must be sorted by arrival time.
pub fn serve_trace(
    engine: &mut dyn Engine,
    selector: &Selector,
    cfg: &ServerConfig,
    requests: &[Request],
) -> ServingStats {
    debug_assert!(requests.windows(2).all(|w| w[0].arrive <= w[1].arrive));
    let mut stats = ServingStats::default();
    let mut clock = 0.0f64;
    let mut i = 0;
    while i < requests.len() {
        // Server becomes free at `clock`; next batch forms from the
        // first pending request.
        let first = &requests[i];
        let open = clock.max(first.arrive);
        let close = open + cfg.batch_window;
        let mut batch = vec![*first];
        let mut j = i + 1;
        while j < requests.len()
            && batch.len() < cfg.max_batch
            && requests[j].arrive <= close
        {
            batch.push(requests[j]);
            j += 1;
        }
        // Batch launch time: when the window closes or the batch fills,
        // but never before the server is free.
        let launch = if batch.len() == cfg.max_batch {
            batch.last().unwrap().arrive.max(open)
        } else if j < requests.len() {
            close
        } else {
            batch.last().unwrap().arrive.max(open)
        };

        let rows: usize = batch.iter().map(|r| r.rows).sum();
        let c = Contraction {
            m: rows,
            n: cfg.n,
            k: cfg.k,
            dtype: selector.libraries[0].dtype,
        };
        let sel = selector
            .select(c, cfg.mode)
            .expect("selector must handle any shape (sample-free)");
        let service = engine.execute(c, &sel, selector);
        let done = launch + sel.select_secs + service;
        for r in &batch {
            let latency = done - r.arrive;
            stats.metrics.record(
                latency,
                sel.select_secs / batch.len() as f64,
                service / batch.len() as f64,
                c.flops() * (r.rows as f64 / rows as f64),
            );
            stats.outcomes.push(ServeOutcome {
                id: r.id,
                latency,
                batch_size: batch.len(),
            });
        }
        stats.batches += 1;
        stats.total_rows += rows;
        clock = done;
        i = j;
    }
    stats.metrics.span_secs = clock;
    stats
}

/// Generate a Poisson-ish request trace with varying sequence lengths
/// (the paper's BERT evaluation uses seq lens 1..476).
pub fn gen_trace(
    n_requests: usize,
    mean_interarrival: f64,
    rows_lo: usize,
    rows_hi: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut t = 0.0;
    (0..n_requests as u64)
        .map(|id| {
            t += rng.exp(mean_interarrival);
            Request { id, rows: rng.usize(rows_lo, rows_hi), arrive: t }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn setup() -> (Selector, SimEngine) {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let lib = compile(
            &hw,
            crate::ir::OpKind::Gemm,
            DType::F32,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        let sel = Selector::new(hw.clone(), vec![lib]);
        (sel, SimEngine { sim: Simulator::new(hw, 5) })
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let (sel, mut eng) = setup();
        let trace = gen_trace(40, 1e-3, 1, 128, 9);
        let stats = serve_trace(&mut eng, &sel, &ServerConfig::default(), &trace);
        assert_eq!(stats.metrics.count(), 40);
        let mut ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
        ids.sort();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn latencies_nonnegative_and_span_positive() {
        let (sel, mut eng) = setup();
        let trace = gen_trace(25, 5e-4, 1, 64, 3);
        let stats = serve_trace(&mut eng, &sel, &ServerConfig::default(), &trace);
        assert!(stats.outcomes.iter().all(|o| o.latency >= 0.0));
        assert!(stats.metrics.span_secs > 0.0);
    }

    #[test]
    fn batching_respects_max_batch() {
        let (sel, mut eng) = setup();
        // All arrive at ~the same instant: batches must cap at max_batch.
        let trace: Vec<Request> =
            (0..20).map(|id| Request { id, rows: 16, arrive: 1e-6 * id as f64 }).collect();
        let cfg = ServerConfig { max_batch: 4, ..ServerConfig::default() };
        let stats = serve_trace(&mut eng, &sel, &cfg, &trace);
        assert!(stats.outcomes.iter().all(|o| o.batch_size <= 4));
        assert_eq!(stats.batches, 5);
    }

    #[test]
    fn bigger_batches_improve_throughput_under_load() {
        let (sel, mut eng1) = setup();
        let trace = gen_trace(60, 1e-5, 8, 64, 11);
        let solo = serve_trace(
            &mut eng1,
            &sel,
            &ServerConfig { max_batch: 1, ..ServerConfig::default() },
            &trace,
        );
        let (_, mut eng2) = setup();
        let batched = serve_trace(
            &mut eng2,
            &sel,
            &ServerConfig { max_batch: 16, ..ServerConfig::default() },
            &trace,
        );
        assert!(
            batched.metrics.span_secs < solo.metrics.span_secs,
            "batched {} !< solo {}",
            batched.metrics.span_secs,
            solo.metrics.span_secs
        );
    }

    #[test]
    fn trace_generator_is_sorted_and_in_range() {
        let t = gen_trace(100, 1e-3, 5, 128, 1);
        assert!(t.windows(2).all(|w| w[0].arrive <= w[1].arrive));
        assert!(t.iter().all(|r| (5..=128).contains(&r.rows)));
    }
}
