//! Bottom-up hardware-aware candidate generation (paper §5.1,
//! Algorithm 2), operator-generic.
//!
//! For each backend of a hardware target, generate micro-kernel tile
//! candidates over the op's iteration-space axes, level by level:
//!
//! * **L0** — tiles are multiples of the backend's ISA granularity
//!   lifted onto the op's axes (`FilterByISA`; batch axes have
//!   granularity 1), with the op's working set inside the level-0
//!   budget.
//! * **L ≥ 1** — `FilterByMultiples`: the sieve over the previous
//!   layer's candidates; every candidate is an elementwise integer
//!   multiple of at least one child, working set inside the level's
//!   budget, and within the utilization window (§2.3: extremely
//!   low/high usage is pruned).
//!
//! Per-axis multiplier ladders come from the axis ROLE: spatial axes
//! use the wide ladder, the reduction axis the deep-K ladder, and batch
//! axes a short ladder (batch tiling only aids occupancy — there is no
//! operand reuse across it — so a handful of extents suffices).
//!
//! The cross-level `children` map (the paper's "mapping mechanism") is
//! kept for the analyzer: each (parent, child) edge is one scheduling
//! strategy to cost.
//!
//! Offline candidates cover levels 0..n-1; the top (grid/process) level
//! is configured at runtime from the concrete shape (§6.2).

use std::collections::HashMap;

use crate::hw::HwSpec;
use crate::ir::{AxisRole, DType, OpKind, Tile};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Candidate {
    pub level: usize,
    /// Tile over the op's iteration-space axes.
    pub tile: Tile,
    /// Index into `HwSpec::backends`.
    pub backend: usize,
}

#[derive(Debug, Clone, Default)]
pub struct CandidateSet {
    /// `levels[l]` = candidates at hierarchy level l (0 and 1 offline).
    pub levels: Vec<Vec<Candidate>>,
    /// `children[l][i]` = indices into `levels[l-1]` compatible with
    /// `levels[l][i]` (`children[0]` is empty).
    pub children: Vec<Vec<Vec<usize>>>,
}

impl CandidateSet {
    pub fn total(&self) -> usize {
        self.levels.iter().map(|v| v.len()).sum()
    }

    /// Strategy chains at the top offline level: (parent, child) pairs.
    pub fn chains(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let top = self.levels.len() - 1;
        self.children[top]
            .iter()
            .enumerate()
            .flat_map(|(p, kids)| kids.iter().map(move |&c| (p, c)))
    }
}

/// Multiplier ladder used for tile enumeration: dense early, geometric
/// later — mirrors how hand tuners explore tiles, keeps counts bounded.
pub fn ladder(max: usize) -> Vec<usize> {
    const BASE: [usize; 18] =
        [1, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 96, 128, 192];
    let mut v: Vec<usize> = BASE.iter().copied().take_while(|&x| x <= max).collect();
    let mut x = 256;
    while x <= max {
        v.push(x);
        x *= 2;
    }
    v
}

/// Per-axis multiplier ladder for one (role, level).
fn axis_ladder(role: AxisRole, level: usize) -> Vec<usize> {
    match (role, level) {
        // Batch: no reuse across it, small extents suffice.
        (AxisRole::Batch, _) => vec![1, 2, 4],
        (AxisRole::Spatial, 0) => ladder(64),
        (AxisRole::Spatial, _) => ladder(256),
        (AxisRole::Reduction, _) => ladder(64),
    }
}

/// Visit every tile `base * mults` over the per-axis ladders, last axis
/// innermost. The visitor returns `false` to break the innermost loop
/// (ascending reduction ladder + working set monotone in the reduction
/// extent ⇒ once over capacity, the rest of the innermost ladder is
/// too).
fn for_each_tile(
    base: Tile,
    ladders: &[Vec<usize>],
    f: &mut impl FnMut(Tile, &[usize]) -> bool,
) {
    fn rec(
        axis: usize,
        base: Tile,
        ladders: &[Vec<usize>],
        mults: &mut [usize],
        tile: &mut Tile,
        f: &mut impl FnMut(Tile, &[usize]) -> bool,
    ) {
        for &m in &ladders[axis] {
            mults[axis] = m;
            tile[axis] = base[axis] * m;
            if axis + 1 == ladders.len() {
                if !f(*tile, mults) {
                    break;
                }
            } else {
                rec(axis + 1, base, ladders, mults, tile, f);
            }
        }
    }
    let mut mults = vec![1usize; ladders.len()];
    let mut tile = base;
    rec(0, base, ladders, &mut mults, &mut tile, f);
}

/// Generate candidates for one (hardware, op, dtype) triple. Backends
/// whose element width does not match the dtype are skipped (the
/// adaptive runtime generates one set per dtype and picks between them,
/// §6.2).
pub fn generate(hw: &HwSpec, op: OpKind, dtype: DType) -> CandidateSet {
    let spec = op.spec();
    debug_assert_eq!(
        spec.axes().last().map(|a| a.role),
        Some(AxisRole::Reduction),
        "candgen requires the reduction axis last"
    );
    let n_offline = hw.n_levels() - 1;
    let mut set = CandidateSet {
        levels: vec![Vec::new(); n_offline],
        children: vec![Vec::new(); n_offline],
    };
    for (bi, backend) in hw.backends.iter().enumerate() {
        if backend.dtype_bytes != dtype.bytes() {
            continue;
        }
        // ---- L0: InitCands + FilterByISA ---------------------------------
        let cap0 = hw.level(0).capacity_bytes;
        let isa = spec.isa_tile(backend.isa);
        let l0_ladders: Vec<Vec<usize>> =
            spec.axes().iter().map(|a| axis_ladder(a.role, 0)).collect();
        let mut l0: Vec<Candidate> = Vec::new();
        for_each_tile(isa, &l0_ladders, &mut |tile, _| {
            if spec.working_set(tile, backend.dtype_bytes) > cap0 {
                return false;
            }
            l0.push(Candidate { level: 0, tile, backend: bi });
            true
        });
        let l0_offset = set.levels[0].len();
        set.levels[0].extend(l0.iter().copied());
        set.children[0].extend(std::iter::repeat(Vec::new()).take(l0.len()));

        // ---- L >= 1: FilterByMultiples (sieve) ----------------------------
        let mut prev: Vec<(usize, Candidate)> =
            l0.iter().enumerate().map(|(i, c)| (l0_offset + i, *c)).collect();
        for level in 1..n_offline {
            let cap = hw.level(level).capacity_bytes;
            let min_ws = (cap as f64 * hw.min_util) as u64;
            let ladders: Vec<Vec<usize>> = spec
                .axes()
                .iter()
                .map(|a| axis_ladder(a.role, level))
                .collect();
            // tile -> contributing child indices (the paper's map table)
            let mut table: HashMap<Tile, Vec<usize>> = HashMap::new();
            for &(child_idx, child) in &prev {
                let elem = hw.backends[child.backend].dtype_bytes;
                for_each_tile(child.tile, &ladders, &mut |tile, mults| {
                    // threads-per-block analog: parallel (batch+spatial)
                    // child tiles running concurrently inside one L1 unit.
                    if level == 1 {
                        let conc: usize = spec
                            .axes()
                            .iter()
                            .zip(mults)
                            .filter(|(a, _)| a.role != AxisRole::Reduction)
                            .map(|(_, &m)| m)
                            .product();
                        if conc > hw.max_l0_per_l1 as usize {
                            return true;
                        }
                    }
                    let ws = spec.working_set(tile, elem);
                    if ws > cap {
                        return false; // reduction ladder is ascending
                    }
                    if ws < min_ws {
                        return true;
                    }
                    table.entry(tile).or_default().push(child_idx);
                    true
                });
            }
            let mut tiles: Vec<Tile> = table.keys().copied().collect();
            tiles.sort();
            let mut next_prev = Vec::with_capacity(tiles.len());
            for tile in tiles {
                let mut kids = table.remove(&tile).unwrap();
                kids.sort_unstable();
                kids.dedup();
                let cand = Candidate { level, tile, backend: bi };
                let idx = set.levels[level].len();
                set.levels[level].push(cand);
                set.children[level].push(kids);
                next_prev.push((idx, cand));
            }
            prev = next_prev;
        }
    }
    set
}

/// Check a single (parent, child) pair against the Algorithm-2
/// constraints — used by tests and by the manifest cross-check.
pub fn is_valid_pair(
    hw: &HwSpec,
    op: OpKind,
    parent: &Candidate,
    child: &Candidate,
) -> bool {
    if parent.backend != child.backend || parent.level != child.level + 1 {
        return false;
    }
    let spec = op.spec();
    let backend = &hw.backends[parent.backend];
    let isa = spec.isa_tile(backend.isa);
    let isa_ok = child.tile.is_multiple_of(isa);
    parent.tile.is_multiple_of(child.tile)
        && isa_ok
        && spec.working_set(parent.tile, backend.dtype_bytes)
            <= hw.level(parent.level).capacity_bytes
        && spec.working_set(child.tile, backend.dtype_bytes)
            <= hw.level(child.level).capacity_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::util::prop::{forall, prop_assert};

    #[test]
    fn ladder_is_sorted_unique() {
        let l = ladder(512);
        assert!(l.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(l[0], 1);
        assert!(l.contains(&512));
    }

    #[test]
    fn l0_candidates_respect_isa_and_capacity() {
        let hw = presets::a100();
        let set = generate(&hw, OpKind::Gemm, DType::F16);
        assert!(!set.levels[0].is_empty());
        for c in &set.levels[0] {
            let b = &hw.backends[c.backend];
            assert_eq!(b.name, "tensor_core_f16");
            for (t, g) in c.tile.iter().zip(b.isa.iter()) {
                assert_eq!(t % g, 0, "ISA granularity violated: {:?}", c.tile);
            }
            assert!(
                HwSpec::gemm_working_set(c.tile.to3(), b.dtype_bytes)
                    <= hw.level(0).capacity_bytes
            );
        }
    }

    #[test]
    fn l1_candidates_are_multiples_of_some_child() {
        let hw = presets::a100();
        let set = generate(&hw, OpKind::Gemm, DType::F16);
        assert!(!set.levels[1].is_empty());
        for (i, c) in set.levels[1].iter().enumerate() {
            let kids = &set.children[1][i];
            assert!(!kids.is_empty(), "orphan L1 candidate {:?}", c.tile);
            for &k in kids {
                assert!(
                    is_valid_pair(&hw, OpKind::Gemm, c, &set.levels[0][k]),
                    "invalid pair {:?} -> {:?}",
                    c.tile,
                    set.levels[0][k].tile
                );
            }
        }
    }

    #[test]
    fn utilization_window_prunes_tiny_l1_tiles() {
        let hw = presets::a100();
        let set = generate(&hw, OpKind::Gemm, DType::F16);
        let min_ws = (hw.level(1).capacity_bytes as f64 * hw.min_util) as u64;
        for c in &set.levels[1] {
            let ws = HwSpec::gemm_working_set(c.tile.to3(), 2);
            assert!(ws >= min_ws, "under-utilizing tile survived: {:?}", c.tile);
        }
    }

    #[test]
    fn candidate_counts_track_isa_granularity() {
        // Paper §7.4: CPU >> GPU-CudaCore > GPU-TensorCore candidate counts
        // (17731 vs 2332 vs 392) because finer ISA granularity => larger
        // space. The same ordering must emerge here.
        let cpu = generate(&presets::xeon_8255c(), OpKind::Gemm, DType::F32).total();
        let gpu_cc = generate(&presets::a100(), OpKind::Gemm, DType::F32).total();
        let gpu_tc = generate(&presets::a100(), OpKind::Gemm, DType::F16).total();
        assert!(cpu > gpu_cc, "cpu {} !> gpu_cc {}", cpu, gpu_cc);
        assert!(gpu_cc > gpu_tc, "gpu_cc {} !> gpu_tc {}", gpu_cc, gpu_tc);
    }

    #[test]
    fn dtype_filters_backends() {
        let set = generate(&presets::a100(), OpKind::Gemm, DType::F32);
        let hw = presets::a100();
        for level in &set.levels {
            for c in level {
                assert_eq!(hw.backends[c.backend].name, "cuda_core_f32");
            }
        }
    }

    #[test]
    fn real_testbed_generates_manifest_like_tiles() {
        let hw = presets::cpu_pjrt();
        let set = generate(&hw, OpKind::Gemm, DType::F32);
        // The checked-in python manifest's L1 blocks must be producible.
        for want in [[64usize, 256, 512], [128, 512, 512], [128, 768, 768]] {
            assert!(
                set.levels[1].iter().any(|c| c.tile == Tile::from3(want)),
                "manifest block {:?} not generated",
                want
            );
        }
    }

    #[test]
    fn batched_gemm_candidates_have_rank_four_and_batch_extents() {
        let hw = presets::a100();
        let set = generate(&hw, OpKind::BatchedGemm, DType::F16);
        assert!(!set.levels[0].is_empty());
        assert!(!set.levels[1].is_empty());
        for level in &set.levels {
            for c in level {
                assert_eq!(c.tile.rank(), 4, "{:?}", c.tile);
            }
        }
        // The short batch ladder must actually surface b > 1 tiles.
        assert!(
            set.levels[0].iter().any(|c| c.tile[0] > 1),
            "no batched L0 tile generated"
        );
    }

    #[test]
    fn conv_space_equals_gemm_space() {
        // Conv2d optimizes over the implicit-GEMM contraction space, so
        // Algorithm 2 must produce the identical tile set.
        let hw = presets::a100();
        let g = generate(&hw, OpKind::Gemm, DType::F16);
        let c = generate(&hw, OpKind::Conv2d, DType::F16);
        assert_eq!(g.total(), c.total());
        assert_eq!(
            g.levels[1].iter().map(|x| x.tile).collect::<Vec<_>>(),
            c.levels[1].iter().map(|x| x.tile).collect::<Vec<_>>()
        );
    }

    #[test]
    fn prop_children_divide_parents() {
        let hw = presets::a100();
        let set = generate(&hw, OpKind::Gemm, DType::F16);
        forall(
            "children-divide-parents",
            200,
            0xC0FFEE,
            |r, _| {
                let i = r.usize(0, set.levels[1].len() - 1);
                let kids = &set.children[1][i];
                let k = kids[r.usize(0, kids.len() - 1)];
                (i, k)
            },
            |&(i, k)| {
                let p = set.levels[1][i].tile;
                let c = set.levels[0][k].tile;
                prop_assert(
                    p.is_multiple_of(c),
                    format!("{:?} not multiple of {:?}", p, c),
                )
            },
        );
    }

    #[test]
    fn prop_every_op_chain_satisfies_pair_invariants() {
        // Satellite: for EVERY op, random (parent, child) edges from the
        // generated set satisfy the Algorithm-2 invariants — children
        // divide parents, ISA granularity holds, working sets fit the
        // level capacities.
        let hw = presets::a100();
        for op in OpKind::ALL {
            let set = generate(&hw, op, DType::F16);
            assert!(!set.levels[1].is_empty(), "{} produced no L1 tiles", op);
            forall(
                "op-chain-invariants",
                120,
                0x5EED,
                |r, _| {
                    let i = r.usize(0, set.levels[1].len() - 1);
                    let kids = &set.children[1][i];
                    let k = kids[r.usize(0, kids.len() - 1)];
                    (i, k)
                },
                |&(i, k)| {
                    let p = &set.levels[1][i];
                    let c = &set.levels[0][k];
                    prop_assert(
                        is_valid_pair(&hw, op, p, c),
                        format!("{}: invalid pair {:?} -> {:?}", op, p.tile, c.tile),
                    )?;
                    let ws = op.spec().working_set(
                        p.tile,
                        hw.backends[p.backend].dtype_bytes,
                    );
                    prop_assert(
                        ws <= hw.level(1).capacity_bytes,
                        format!("{}: L1 working set {} spills", op, ws),
                    )
                },
            );
        }
    }
}
