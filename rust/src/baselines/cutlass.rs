//! CUTLASS analog: a template library. The integration (as in the
//! paper's harness) instantiates a small set of tile templates tuned
//! for large steady-state GEMMs; dispatch picks the template minimizing
//! padded work, with no shape-specific tuning and no utilization
//! reasoning — which is why the paper sees both very good CUTLASS cases
//! (template happens to fit) and very bad ones (7.65x avg on skinny
//! f32 GEMMs, Table 5).

use super::{padded_chain, PlanEngine};
use crate::baselines::vendor::tuned_table;
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::{round_up, Contraction};
use crate::sim::Simulator;

pub struct Cutlass {
    backend: usize,
    templates: Vec<([usize; 3], [usize; 3])>, // (l0, l1)
}

impl Cutlass {
    pub fn new(hw: &HwSpec, backend_name: &str) -> Cutlass {
        let backend = hw.backend_idx(backend_name).expect("backend");
        // Two large-GEMM templates only — the default instantiation a
        // framework integration ships with.
        let sim = Simulator::new(hw.clone(), 0xC071);
        let canonical: &[[usize; 3]] = &[[4096, 4096, 4096], [1024, 1024, 1024]];
        let templates = tuned_table(hw, backend_name, canonical, &sim)
            .into_iter()
            .map(|k| (k.l0, k.l1))
            .collect();
        Cutlass { backend, templates }
    }
}

impl PlanEngine for Cutlass {
    fn name(&self) -> &'static str {
        "cutlass"
    }

    /// Template dispatch: minimize padded FLOPs (no perf model at all).
    fn plan(&self, c: Contraction) -> Strategy {
        let best = self
            .templates
            .iter()
            .min_by(|a, b| {
                let work = |t: &([usize; 3], [usize; 3])| {
                    (round_up(c.m, t.1[0]) as f64)
                        * (round_up(c.n, t.1[1]) as f64)
                        * (round_up(c.k, t.1[2]) as f64)
                };
                work(a).partial_cmp(&work(b)).unwrap()
            })
            .unwrap();
        padded_chain(best.0, best.1, c, self.backend)
    }

    fn dispatch_overhead(&self) -> f64 {
        1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;

    #[test]
    fn has_few_templates_that_fit() {
        let hw = presets::a100();
        let ct = Cutlass::new(&hw, "cuda_core_f32");
        assert!(ct.templates.len() <= 2);
        for (_, l1) in &ct.templates {
            assert!(
                crate::hw::HwSpec::gemm_working_set(*l1, 4)
                    <= hw.level(1).capacity_bytes
            );
        }
    }

    #[test]
    fn skinny_m_pays_full_template_rows() {
        let hw = presets::a100();
        let ct = Cutlass::new(&hw, "cuda_core_f32");
        let s = ct.plan(Contraction { m: 1, n: 4096, k: 1024, dtype: DType::F32 });
        // No skinny template exists: M=1 pads to the template row count.
        assert!(s.tiles[2][0] >= s.tiles[1][0]);
        assert!(s.tiles[1][0] > 1);
    }
}
