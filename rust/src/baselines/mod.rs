//! Baseline engines (paper §7.1): vendor-library analogs (cuBLAS /
//! cuDNN / oneDNN / ONNX Runtime / CUTLASS) and the sample-driven
//! dynamic-shape compiler DietCode.
//!
//! Every engine implements [`PlanEngine`]: given a runtime contraction
//! it produces the strategy chain it would execute. All engines are
//! timed by the *same* simulator (or the same real runtime), so the
//! comparisons isolate exactly what the paper compares — configuration
//! quality and shape adaptivity — not simulator favoritism.

pub mod cutlass;
pub mod dietcode;
pub mod vendor;

use crate::cost::Strategy;
use crate::ir::Contraction;

/// A runtime planning engine: shape -> strategy chain.
pub trait PlanEngine {
    fn name(&self) -> &'static str;
    /// Plan the kernel for a concrete shape. The returned chain's top
    /// tile is the padded problem.
    fn plan(&self, c: Contraction) -> Strategy;
    /// Fixed extra overhead per dispatched call (framework layers etc.).
    fn dispatch_overhead(&self) -> f64 {
        0.0
    }
}

/// Helper: wrap an (l0, l1) pair and a problem into a padded chain.
pub fn padded_chain(
    l0: [usize; 3],
    l1: [usize; 3],
    c: Contraction,
    backend: usize,
) -> Strategy {
    let padded = [
        crate::ir::round_up(c.m, l1[0]),
        crate::ir::round_up(c.n, l1[1]),
        crate::ir::round_up(c.k, l1[2]),
    ];
    Strategy::new(vec![l0, l1, padded], backend)
}
