//! DietCode analog (paper §2.2, Fig. 2): the sample-driven dynamic-shape
//! compiler baseline.
//!
//! Faithful to the published workflow:
//!
//! 1. **Offline**: the user supplies a *sample list* of shapes. For each
//!    sample, the auto-tuner searches a shape-generic space of tile
//!    chains (power-of-two enumeration — NO hardware-limit pruning,
//!    that's Vortex's contribution) by *profiling on the hardware*
//!    (simulator queries with tuning-time accounting). The best kernel
//!    per sample is kept.
//! 2. **Runtime**: a decision-tree selector maps the runtime shape to
//!    the nearest sample's micro-kernel; the kernel constructor pads the
//!    shape to that kernel's tile. Out-of-sample shapes inherit a
//!    mismatched tile -> padding loss and suboptimal configs (Fig. 3,
//!    Table 6 geometry).

use super::{padded_chain, PlanEngine};
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::Contraction;
use crate::profiler::Profiler;
use crate::util::rng::Rng;

/// One tuned micro-kernel bound to its sample shape.
#[derive(Debug, Clone)]
struct TunedKernel {
    sample: [usize; 3],
    l0: [usize; 3],
    l1: [usize; 3],
}

pub struct DietCode {
    backend: usize,
    kernels: Vec<TunedKernel>,
    pub tuning_secs: f64,
    pub trials_total: usize,
}

/// Largest divisor of `dim` that is <= ceil(dim/d), preferring
/// vector-aligned (multiple-of-4) divisors — TVM split factors always
/// divide the axis extent.
fn split_dim(dim: usize, d: usize) -> usize {
    let target = (dim / d).max(1);
    let mut best = 1;
    let mut best_aligned = 0;
    for x in 1..=target {
        if dim % x == 0 {
            best = x;
            if x % 4 == 0 {
                best_aligned = x;
            }
        }
    }
    if best_aligned > 0 {
        best_aligned
    } else {
        best
    }
}

/// Shape-generic search space (TVM-style): a rich tile enumeration with
/// NO hardware-limit pruning — sample-driven compilers treat the
/// hardware as a black box and rely on profiling feedback to sort good
/// configurations from bad (paper §2.3). This is deliberately the same
/// ladder granularity Vortex enumerates, minus Algorithm 2's ISA /
/// capacity / utilization filters and minus the multiple sieve.
fn generic_space(max_l1: usize) -> Vec<([usize; 3], [usize; 3])> {
    let mut out = Vec::new();
    let lad = crate::candgen::ladder(max_l1);
    let kl = crate::candgen::ladder(256);
    for &m1 in &lad {
        for &n1 in &lad {
            for &k1 in &kl {
                let l1 = [m1, n1, k1];
                // A few register-blocking splits per tile (the classic
                // TVM split-factor axis). Split factors always divide
                // the axis extent, preferring vectorize-aligned ones,
                // but are otherwise unvalidated against hardware limits.
                for &(dm, dn, dk) in
                    &[(4usize, 4usize, 4usize), (8, 8, 8), (2, 8, 4), (1, 1, 1)]
                {
                    let l0 =
                        [split_dim(m1, dm), split_dim(n1, dn), split_dim(k1, dk)];
                    out.push((l0, l1));
                }
            }
        }
    }
    out
}

impl DietCode {
    /// Offline tuning over the sample list. `trials` random configs per
    /// sample are profiled (evolutionary-search budget analog).
    pub fn tune(
        hw: &HwSpec,
        backend_name: &str,
        samples: &[[usize; 3]],
        trials: usize,
        profiler: &mut dyn Profiler,
        seed: u64,
    ) -> DietCode {
        let backend = hw.backend_idx(backend_name).expect("backend");
        let dtype = if hw.backends[backend].dtype_bytes == 2 {
            crate::ir::DType::F16
        } else {
            crate::ir::DType::F32
        };
        let space = generic_space(256);
        let mut rng = Rng::new(seed);
        let tuning0 = profiler.tuning_secs();
        let mut kernels = Vec::with_capacity(samples.len());
        let mut trials_total = 0;
        for &sample in samples {
            let c = Contraction { m: sample[0], n: sample[1], k: sample[2], dtype };
            let mut measure = |cfg: ([usize; 3], [usize; 3]),
                               trials_total: &mut usize| {
                *trials_total += 1;
                let chain = padded_chain(cfg.0, cfg.1, c, backend);
                profiler.measure_full(dtype, &chain)
            };
            // Random exploration phase.
            let mut best: Option<(f64, usize)> = None;
            for _ in 0..trials {
                let idx = rng.usize(0, space.len() - 1);
                let t = measure(space[idx], &mut trials_total);
                if best.map(|(b, _)| t < b).unwrap_or(true) {
                    best = Some((t, idx));
                }
            }
            // Refinement phase (evolutionary-search analog): coordinate
            // descent over the tile axes — for each of m1/n1/k1/split in
            // turn, measure every ladder value with the other axes fixed
            // and keep the best; sweep until converged. This is what
            // lets the real DietCode reach near-parity with the vendor
            // library ON its samples (Fig. 3's DietCode-I series).
            let (mut bt, bi) = best.unwrap();
            let mut cur = space[bi];
            let lad = crate::candgen::ladder(256);
            let splits: [[usize; 3]; 4] =
                [[4, 4, 4], [8, 8, 8], [2, 8, 4], [1, 1, 1]];
            loop {
                let mut improved = false;
                for axis in 0..4 {
                    if axis < 3 {
                        for &v in &lad {
                            let mut cand = cur;
                            cand.1[axis] = v;
                            // keep roughly the same split ratio on that axis
                            let ratio =
                                (cur.1[axis] / cur.0[axis].max(1)).max(1);
                            cand.0[axis] = split_dim(v, ratio);
                            let t = measure(cand, &mut trials_total);
                            if t < bt {
                                bt = t;
                                cur = cand;
                                improved = true;
                            }
                        }
                    } else {
                        for sp in splits {
                            let cand = (
                                [
                                    split_dim(cur.1[0], sp[0]),
                                    split_dim(cur.1[1], sp[1]),
                                    split_dim(cur.1[2], sp[2]),
                                ],
                                cur.1,
                            );
                            let t = measure(cand, &mut trials_total);
                            if t < bt {
                                bt = t;
                                cur = cand;
                                improved = true;
                            }
                        }
                    }
                }
                if !improved {
                    break;
                }
            }
            let (l0, l1) = cur;
            kernels.push(TunedKernel { sample, l0, l1 });
        }
        DietCode {
            backend,
            kernels,
            tuning_secs: profiler.tuning_secs() - tuning0,
            trials_total,
        }
    }

    /// Decision-tree selector: nearest sample in log-space over (m, n, k)
    /// with M dominant (the dynamic dimension in the paper's setup).
    fn nearest(&self, c: Contraction) -> &TunedKernel {
        self.kernels
            .iter()
            .min_by(|a, b| {
                let d = |t: &TunedKernel| {
                    let lm =
                        ((t.sample[0] as f64).ln() - (c.m as f64).ln()).abs() * 4.0;
                    let ln = ((t.sample[1] as f64).ln() - (c.n as f64).ln()).abs();
                    let lk = ((t.sample[2] as f64).ln() - (c.k as f64).ln()).abs();
                    lm + ln + lk
                };
                d(a).partial_cmp(&d(b)).unwrap()
            })
            .expect("DietCode requires a non-empty sample list")
    }

    /// True if the runtime shape was in the tuning sample list.
    pub fn in_sample(&self, c: Contraction) -> bool {
        self.kernels.iter().any(|k| k.sample == [c.m, c.n, c.k])
    }
}

impl PlanEngine for DietCode {
    fn name(&self) -> &'static str {
        "dietcode"
    }

    fn plan(&self, c: Contraction) -> Strategy {
        let k = self.nearest(c);
        padded_chain(k.l0, k.l1, c, self.backend)
    }

    fn dispatch_overhead(&self) -> f64 {
        0.5e-6 // decision-tree walk
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn tuned(samples: &[[usize; 3]], trials: usize) -> (DietCode, Simulator) {
        let hw = presets::a100();
        let sim = Simulator::new(hw.clone(), 5);
        let mut prof = SimProfiler::new(sim.clone());
        let dc = DietCode::tune(&hw, "cuda_core_f32", samples, trials, &mut prof, 1);
        (dc, sim)
    }

    fn gemm(m: usize, n: usize, k: usize) -> Contraction {
        Contraction { m, n, k, dtype: DType::F32 }
    }

    #[test]
    fn tunes_one_kernel_per_sample() {
        let (dc, _) = tuned(&[[128, 768, 2304], [256, 768, 2304]], 40);
        assert_eq!(dc.kernels.len(), 2);
        // random phase + coordinate-descent refinement measurements
        assert!(dc.trials_total >= 80);
        assert!(dc.tuning_secs > 0.0);
    }

    #[test]
    fn in_sample_detection() {
        let (dc, _) = tuned(&[[128, 768, 2304]], 20);
        assert!(dc.in_sample(gemm(128, 768, 2304)));
        assert!(!dc.in_sample(gemm(100, 768, 2304)));
    }

    #[test]
    fn out_of_sample_uses_nearest_sample_kernel() {
        let (dc, _) = tuned(&[[16, 768, 2304], [256, 768, 2304]], 40);
        let near_small = dc.nearest(gemm(20, 768, 2304));
        assert_eq!(near_small.sample, [16, 768, 2304]);
        let near_big = dc.nearest(gemm(300, 768, 2304));
        assert_eq!(near_big.sample, [256, 768, 2304]);
    }

    #[test]
    fn more_trials_rarely_hurt_tuned_performance() {
        // With the coordinate-descent refinement, different random
        // starts can settle in different local optima, so strict
        // monotonicity in the trial budget does not hold — but a 24x
        // budget must not end up significantly worse.
        let sample = [128usize, 768, 2304];
        let (dc_few, sim) = tuned(&[sample], 5);
        let (dc_many, _) = tuned(&[sample], 120);
        let c = gemm(128, 768, 2304);
        let t_few = sim.execute(DType::F32, &dc_few.plan(c));
        let t_many = sim.execute(DType::F32, &dc_many.plan(c));
        assert!(t_many <= t_few * 1.15, "{} !<= {}", t_many, t_few);
    }

    #[test]
    fn plans_are_valid_chains() {
        let (dc, _) = tuned(&[[64, 512, 512]], 30);
        let s = dc.plan(gemm(77, 512, 512));
        assert!(s.is_nested());
        assert!(s.tiles[2][0] >= 77);
    }
}
