//! Vendor-library analogs: cuBLAS / cuDNN (GPU) and oneDNN (CPU), plus
//! the ONNX Runtime wrapper.
//!
//! Modeled as what those libraries are: a *fixed, hand-tuned* kernel
//! table with a heuristic shape-class dispatcher. The table is built by
//! an oracle search on a handful of canonical shapes — the analog of
//! vendor engineers tuning on real hardware (they see ground truth,
//! including the micro-architectural effects the analytical model can't
//! predict). At runtime the table is frozen: excellent when the runtime
//! shape matches a sweet spot, increasingly wasteful for skinny / odd
//! shapes — exactly the gap the paper's Fig. 3 / Table 5 exploit.
//! ONNX Runtime wraps a smaller table with framework dispatch overhead.

use super::{padded_chain, PlanEngine};
use crate::compiler::{compile, CompileOpts};
use crate::cost::hybrid::AnalyzerConfig;
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::{round_up, Contraction};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;

/// One hand-tuned kernel in the vendor table.
#[derive(Debug, Clone, Copy)]
pub struct VendorKernel {
    pub l0: [usize; 3],
    pub l1: [usize; 3],
}

pub struct VendorLib {
    name: &'static str,
    backend: usize,
    table: Vec<VendorKernel>,
    overhead: f64,
}

/// Oracle-tune one kernel per canonical shape: scan the hardware's
/// feasible chain space (candgen + compile, analytical config — the
/// space only, no Vortex-specific measurements) and keep the chain with
/// the best TRUE simulated time on that shape. This is "engineers
/// hand-tuning on the real device".
pub fn tuned_table(
    hw: &HwSpec,
    backend_name: &str,
    canonical: &[[usize; 3]],
    sim: &Simulator,
) -> Vec<VendorKernel> {
    let backend = hw.backend_idx(backend_name).expect("backend");
    let dtype = if hw.backends[backend].dtype_bytes == 2 {
        crate::ir::DType::F16
    } else {
        crate::ir::DType::F32
    };
    let mut prof = SimProfiler::new(sim.clone());
    let lib = compile(
        hw,
        crate::ir::OpKind::Gemm,
        dtype,
        &AnalyzerConfig::analytical_only(),
        &mut prof,
        &CompileOpts::default(),
    )
    .library;
    let mut table = Vec::with_capacity(canonical.len());
    for &shape in canonical {
        let c = Contraction { m: shape[0], n: shape[1], k: shape[2], dtype };
        let best = lib
            .kernels
            .iter()
            .filter(|k| k.backend == backend)
            .min_by(|a, b| {
                let t = |k: &crate::compiler::MicroKernel| {
                    let padded = crate::ir::Tile::from3([
                        round_up(c.m, k.l1[0]),
                        round_up(c.n, k.l1[1]),
                        round_up(c.k, k.l1[2]),
                    ]);
                    sim.execute(dtype, &k.chain(crate::ir::OpKind::Gemm, padded))
                };
                t(a).partial_cmp(&t(b)).unwrap()
            })
            .expect("non-empty library");
        table.push(VendorKernel { l0: best.l0.to3(), l1: best.l1.to3() });
    }
    // Sort biggest-first so the dispatcher prefers steady-state kernels.
    table.sort_by_key(|k| std::cmp::Reverse(k.l1[0] * k.l1[1] * k.l1[2]));
    table.dedup_by_key(|k| k.l1);
    table
}

impl VendorLib {
    /// cuBLAS on A100: tuned for the classic library sweet spots (large
    /// squares, medium squares, deep-K skinny panels).
    pub fn cublas(hw: &HwSpec, backend_name: &str) -> VendorLib {
        let sim = Simulator::new(hw.clone(), 0xB1A5);
        let canonical: &[[usize; 3]] = &[
            [4096, 4096, 4096],
            [1024, 1024, 1024],
            [256, 256, 1024],
            [64, 256, 1024],
            [32, 128, 512],
            // GEMV-class skinny kernels (huge-M tiny-N and vice versa).
            [1_000_000, 8, 64],
            [8, 4096, 1024],
        ];
        VendorLib {
            name: "cublas",
            backend: hw.backend_idx(backend_name).expect("backend"),
            table: tuned_table(hw, backend_name, canonical, &sim),
            overhead: 2e-6,
        }
    }

    /// cuDNN: same engine family, conv-flavoured canonical shapes
    /// (implicit-GEMM views: huge M from spatial, modest N/K).
    pub fn cudnn(hw: &HwSpec, backend_name: &str) -> VendorLib {
        let sim = Simulator::new(hw.clone(), 0xCD01);
        let canonical: &[[usize; 3]] = &[
            [12544, 256, 1152],
            [3136, 512, 2304],
            [784, 512, 4608],
            [50176, 64, 147],
            // small-batch / first-layer cases
            [196, 512, 4608],
            [3136, 64, 27],
        ];
        VendorLib {
            name: "cudnn",
            backend: hw.backend_idx(backend_name).expect("backend"),
            table: tuned_table(hw, backend_name, canonical, &sim),
            overhead: 4e-6, // descriptor/algorithm dispatch
        }
    }

    /// oneDNN on the Xeon (AVX512 register-blocked kernels).
    pub fn onednn(hw: &HwSpec) -> VendorLib {
        let sim = Simulator::new(hw.clone(), 0x1D88);
        let canonical: &[[usize; 3]] = &[
            [2048, 2048, 2048],
            [512, 512, 512],
            [128, 512, 1024],
            [32, 256, 512],
            [1_000_000, 8, 64],
            [8, 2048, 512],
        ];
        VendorLib {
            name: "onednn",
            backend: hw.backend_idx("avx512_f32").expect("backend"),
            table: tuned_table(hw, "avx512_f32", canonical, &sim),
            overhead: 1e-6,
        }
    }

    /// ONNX Runtime: a smaller tuned table + framework overhead.
    pub fn onnxruntime(hw: &HwSpec) -> VendorLib {
        let sim = Simulator::new(hw.clone(), 0x0887);
        let canonical: &[[usize; 3]] = &[[1024, 1024, 1024], [128, 512, 512]];
        VendorLib {
            name: "onnxruntime",
            backend: hw.backend_idx("avx512_f32").expect("backend"),
            table: tuned_table(hw, "avx512_f32", canonical, &sim),
            overhead: 25e-6,
        }
    }

    pub fn table_len(&self) -> usize {
        self.table.len()
    }
}

impl PlanEngine for VendorLib {
    fn name(&self) -> &'static str {
        self.name
    }

    /// Heuristic dispatcher (cublasLt-style size-class heuristic):
    /// among table kernels whose padded work is within 10% of the
    /// minimum, pick the largest tile (best steady-state efficiency).
    /// No perf model, no shape specialization beyond the frozen table.
    fn plan(&self, c: Contraction) -> Strategy {
        let work = |k: &VendorKernel| {
            (round_up(c.m, k.l1[0]) as f64)
                * (round_up(c.n, k.l1[1]) as f64)
                * (round_up(c.k, k.l1[2]) as f64)
        };
        let min_work =
            self.table.iter().map(work).fold(f64::INFINITY, f64::min);
        let best = self
            .table
            .iter()
            .filter(|k| work(k) <= 1.10 * min_work)
            .max_by_key(|k| k.l1[0] * k.l1[1] * k.l1[2])
            .unwrap();
        padded_chain(best.l0, best.l1, c, self.backend)
    }

    fn dispatch_overhead(&self) -> f64 {
        self.overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;

    fn gemm(m: usize, n: usize, k: usize) -> Contraction {
        Contraction { m, n, k, dtype: DType::F32 }
    }

    #[test]
    fn tables_fit_hardware_budgets() {
        // The tuned tables must not spill the staging tier — vendor
        // kernels are excellent configurations, not strawmen.
        let hw = presets::a100();
        for lib in [
            VendorLib::cublas(&hw, "cuda_core_f32"),
            VendorLib::cudnn(&hw, "cuda_core_f32"),
        ] {
            for k in &lib.table {
                let ws = crate::hw::HwSpec::gemm_working_set(k.l1, 4);
                assert!(
                    ws <= hw.level(1).capacity_bytes,
                    "{}: tile {:?} spills",
                    lib.name,
                    k.l1
                );
            }
        }
    }

    #[test]
    fn vendor_is_near_oracle_on_its_canonical_shape() {
        let hw = presets::a100();
        let sim = Simulator::new(hw.clone(), 0xB1A5);
        let lib = VendorLib::cublas(&hw, "cuda_core_f32");
        let c = gemm(4096, 4096, 4096);
        let t = sim.execute(DType::F32, &lib.plan(c));
        // Sanity: within 3x of compute roofline on its home turf.
        let rl = crate::cost::roofline_secs(
            &hw,
            hw.backend("cuda_core_f32").unwrap(),
            c,
        );
        assert!(t < 3.0 * rl, "vendor too slow at home: {} vs roofline {}", t, rl);
    }

    #[test]
    fn skinny_shape_avoids_tall_tiles() {
        let hw = presets::a100();
        let lib = VendorLib::cublas(&hw, "cuda_core_f32");
        let s = lib.plan(gemm(3, 4096, 1024));
        // M=3 must not dispatch to a tile with many rows (padded work
        // dominates the work-minimizing heuristic).
        assert!(s.tiles[1][0] <= 32, "picked {:?}", s.tiles[1]);
    }

    #[test]
    fn padded_problem_is_tile_multiple() {
        let hw = presets::xeon_8255c();
        let lib = VendorLib::onednn(&hw);
        let s = lib.plan(gemm(100, 333, 777));
        let l1 = s.tiles[1];
        let top = s.tiles[2];
        for d in 0..3 {
            assert_eq!(top[d] % l1[d], 0);
        }
    }

    #[test]
    fn onnxruntime_is_smaller_and_slower_to_dispatch() {
        let hw = presets::xeon_8255c();
        let ort = VendorLib::onnxruntime(&hw);
        let dnn = VendorLib::onednn(&hw);
        assert!(ort.dispatch_overhead() > dnn.dispatch_overhead());
        assert!(ort.table_len() <= dnn.table_len());
    }
}
