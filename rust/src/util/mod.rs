//! In-tree substrates for the offline environment (DESIGN.md §4):
//! JSON, PRNG, CLI parsing, bench harness, tables, property testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
