//! Tiny CLI argument parser substrate (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["bench", "--out=x.csv", "--iters", "5", "--verbose"]);
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.get("out"), Some("x.csv"));
        assert_eq!(a.get_usize("iters", 0), 5);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn trailing_flag_not_eaten() {
        let a = parse(&["--dry-run"]);
        assert!(a.has_flag("dry-run"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }
}
