//! Minimal JSON parser/serializer (substrate — no serde in this
//! offline environment; see Cargo.toml note).
//!
//! Supports the full JSON grammar minus `\u` surrogate pairs outside the
//! BMP. Used for `artifacts/manifest.json`, micro-kernel library
//! serialization, and benchmark result dumps.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact serialization (deterministic: object keys are sorted).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xc0 == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"k":[1,2.5,"s",true,null],"z":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".to_string())
        );
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::parse("2.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
