//! Micro-benchmark harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`]
//! directly. Reports min/median/mean/p95 over timed iterations after a
//! warm-up phase, and supports throughput annotation (flops or items).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Stats {
    fn from_samples(mut samples: Vec<Duration>) -> Stats {
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Stats {
            iters: n,
            min: samples[0],
            median: samples[n / 2],
            mean,
            p95: samples[(n * 95 / 100).min(n - 1)],
        }
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    /// Stop early once this much wall time has been spent measuring.
    pub max_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 30, max_time: Duration::from_secs(10) }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, iters: 10, max_time: Duration::from_secs(3) }
    }

    /// Time `f`, print a one-line report, return the stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time && samples.len() >= 5 {
                break;
            }
        }
        let stats = Stats::from_samples(samples);
        println!(
            "bench {name:<44} min {:>10} med {:>10} mean {:>10} p95 {:>10} ({} iters)",
            fmt_dur(stats.min),
            fmt_dur(stats.median),
            fmt_dur(stats.mean),
            fmt_dur(stats.p95),
            stats.iters,
        );
        stats
    }

    /// Like `run`, additionally reporting GFLOP/s from `flops` per call.
    pub fn run_flops<F: FnMut()>(&self, name: &str, flops: f64, f: F) -> Stats {
        let stats = self.run(name, f);
        let gflops = flops / stats.median.as_secs_f64() / 1e9;
        println!("      {name:<44} {:.2} GFLOP/s (median)", gflops);
        stats
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{}ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_micros(5),
            Duration::from_micros(1),
            Duration::from_micros(3),
        ]);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.median, Duration::from_micros(3));
        assert!(s.p95 >= s.median);
    }

    #[test]
    fn run_counts_iters() {
        let b = Bench { warmup: 1, iters: 7, max_time: Duration::from_secs(60) };
        let mut n = 0;
        let s = b.run("test", || n += 1);
        assert_eq!(s.iters, 7);
        assert_eq!(n, 8); // warmup + iters
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12ns");
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
    }
}
