//! Aligned text-table printer used by the benchmark harness to emit the
//! paper's tables/figure series, plus a CSV writer for plotting.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                let _ = write!(s, "{:<width$}", cells[i], width = widths[i]);
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write rows as CSV (headers first). Creates parent dirs.
    pub fn write_csv(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                s,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        fs::write(path, s)
    }
}

/// Format a speedup like the paper: "2.53x".
pub fn fmt_x(v: f64) -> String {
    format!("{:.2}x", v)
}

/// Format seconds adaptively (us/ms/s/h).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 600.0 {
        format!("{:.1}s", s)
    } else {
        format!("{:.1}h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbb"]);
        t.row(vec!["xx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("a   bbb"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_width() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x".into(), "y".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a,b"]);
        t.row(vec!["x\"y".into()]);
        let tmp = std::env::temp_dir().join("vortex_table_test.csv");
        t.write_csv(&tmp).unwrap();
        let s = std::fs::read_to_string(&tmp).unwrap();
        assert!(s.contains("\"a,b\""));
        assert!(s.contains("\"x\"\"y\""));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_x(2.534), "2.53x");
        assert_eq!(fmt_secs(7200.0), "2.0h");
        assert!(fmt_secs(0.005).ends_with("ms"));
    }
}
