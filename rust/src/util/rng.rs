//! Deterministic PRNG substrate (SplitMix64 + helpers).
//!
//! No `rand` crate offline; every stochastic component in the repo
//! (simulator noise, workload generators, property tests) goes through
//! this so runs are reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// SplitMix64 step.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi] inclusive. Requires lo <= hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// f32 samples roughly N(0, 1) for literal building.
    pub fn normal_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Exponential with the given mean (arrival-time generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-12).ln()
    }
}

/// Stable 64-bit hash (FNV-1a) — used to derive deterministic per-config
/// simulator noise without carrying RNG state through the cost path.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash any display-able key list.
pub fn hash_key(parts: &[u64]) -> u64 {
    let mut bytes = Vec::with_capacity(parts.len() * 8);
    for p in parts {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(1);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            match r.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn fnv_distinct() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
        assert_ne!(hash_key(&[1, 2]), hash_key(&[2, 1]));
    }
}
