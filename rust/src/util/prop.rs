//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, check)` draws `cases` random inputs from
//! `gen` and asserts `check` on each; on failure it retries with shrunk
//! integer fields via the generator's own size parameter and reports the
//! failing seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Run `check` on `cases` generated inputs. `gen` receives an Rng and a
/// size hint in [0, 100] that grows over the run (small cases first, like
/// proptest's sizing), so early failures are already small.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cases {
        let case_seed = seed.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let size = 1 + (i * 100) / cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed on case {i} (seed {case_seed}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Convenience: assert closure form.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            "add-commutes",
            50,
            1,
            |r, size| (r.usize(0, size), r.usize(0, size)),
            |&(a, b)| prop_assert(a + b == b + a, "commutativity"),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn reports_failure_with_seed() {
        forall(
            "always-false",
            10,
            2,
            |r, _| r.usize(0, 10),
            |_| prop_assert(false, "nope"),
        );
    }

    #[test]
    fn sizes_grow() {
        let mut max_early = 0;
        let mut max_late = 0;
        forall(
            "sizing",
            100,
            3,
            |r, size| (size, r.usize(0, size)),
            |&(size, v)| {
                if size < 20 {
                    max_early = max_early.max(v);
                } else {
                    max_late = max_late.max(v);
                }
                prop_assert(v <= size, "bounded")
            },
        );
        assert!(max_early <= 20);
        assert!(max_late >= max_early);
    }
}
