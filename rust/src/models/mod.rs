//! Model-level workloads (paper §7.3, Fig. 13): per-model operator
//! traces parameterized by the dynamic dimension (sequence length for
//! language models, batch size for CNNs).
//!
//! Each trace is the list of [`TensorProgram`]s one forward pass
//! executes; the benchmark harness runs a trace through any engine
//! (Vortex selector or a baseline planner) and sums simulated — or
//! real — per-op times.

use crate::ir::{DType, TensorProgram};

/// A named dynamic-shape model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Model {
    Bert,
    BertLarge,
    Gpt2,
    AlexNet,
    ResNet50,
    GoogleNet,
    MobileNet,
}

impl Model {
    pub fn name(&self) -> &'static str {
        match self {
            Model::Bert => "bert",
            Model::BertLarge => "bert-large",
            Model::Gpt2 => "gpt2",
            Model::AlexNet => "alexnet",
            Model::ResNet50 => "resnet50",
            Model::GoogleNet => "googlenet",
            Model::MobileNet => "mobilenet-v1",
        }
    }

    pub fn is_language_model(&self) -> bool {
        matches!(self, Model::Bert | Model::BertLarge | Model::Gpt2)
    }

    pub fn all() -> [Model; 7] {
        [
            Model::Bert,
            Model::BertLarge,
            Model::Gpt2,
            Model::AlexNet,
            Model::ResNet50,
            Model::GoogleNet,
            Model::MobileNet,
        ]
    }
}

fn gemm(m: usize, n: usize, k: usize, dtype: DType) -> TensorProgram {
    TensorProgram::Gemm { m, n, k, dtype }
}

/// Square conv with explicit (stride, pad, groups) geometry.
fn conv_g(
    n: usize,
    hw_: usize,
    cin: usize,
    cout: usize,
    k: usize,
    (stride, pad, groups): (usize, usize, usize),
    dtype: DType,
) -> TensorProgram {
    TensorProgram::conv2d((n, hw_, hw_, cin), (k, k, cout), (stride, pad, groups), dtype)
        .expect("model conv geometry is valid by construction")
}

/// Same-padded stride-1 ungrouped conv (the common CNN body layer):
/// pad = k/2 keeps the spatial extent for odd k.
fn conv(
    n: usize,
    hw_: usize,
    cin: usize,
    cout: usize,
    k: usize,
    dtype: DType,
) -> TensorProgram {
    conv_g(n, hw_, cin, cout, k, (1, k / 2, 1), dtype)
}

/// Depthwise 3x3 conv (groups == cin), MobileNet style.
fn dwconv(n: usize, hw_: usize, c: usize, stride: usize, dtype: DType) -> TensorProgram {
    conv_g(n, hw_, c, c, 3, (stride, 1, c), dtype)
}

/// Transformer encoder/decoder stack trace. `m` = batch * seq rows.
fn transformer_trace(
    layers: usize,
    d: usize,
    ff: usize,
    heads: usize,
    seq: usize,
    batch: usize,
    dtype: DType,
) -> Vec<TensorProgram> {
    let m = batch * seq;
    let mut ops = Vec::new();
    for _ in 0..layers {
        // Fused QKV projection (the paper's "first GEMM of Bert":
        // M = batch x seq, K = d, N = 3d — reported there transposed).
        ops.push(gemm(m, 3 * d, d, dtype));
        // Attention-fused chain (score · softmax · context) over the
        // head groups — ONE FusedAttention program with the dynamic
        // sequence length, not two flat GEMMs with a materialized
        // intermediate.
        ops.push(
            TensorProgram::attention((batch, seq), (d, heads), dtype)
                .expect("model attention geometry is valid by construction"),
        );
        // Output projection + MLP.
        ops.push(gemm(m, d, d, dtype));
        ops.push(gemm(m, ff, d, dtype));
        ops.push(gemm(m, d, ff, dtype));
    }
    ops
}

/// Operator trace of one forward pass. `dynamic` is the sequence length
/// (language models, batch fixed at 1 as in Fig. 13) or the batch size
/// (CNNs).
pub fn trace(model: Model, dynamic: usize, dtype: DType) -> Vec<TensorProgram> {
    match model {
        Model::Bert => transformer_trace(12, 768, 3072, 12, dynamic, 1, dtype),
        Model::BertLarge => transformer_trace(24, 1024, 4096, 16, dynamic, 1, dtype),
        Model::Gpt2 => transformer_trace(12, 768, 3072, 12, dynamic, 1, dtype),
        Model::AlexNet => {
            let b = dynamic;
            vec![
                // Honest stem geometry: 224x224, 11x11, stride 4, pad 2
                // -> 55x55; body layers are same-padded.
                conv_g(b, 224, 3, 64, 11, (4, 2, 1), dtype),
                conv(b, 27, 64, 192, 5, dtype),
                conv(b, 13, 192, 384, 3, dtype),
                conv(b, 13, 384, 256, 3, dtype),
                conv(b, 13, 256, 256, 3, dtype),
                gemm(b, 4096, 9216, dtype),
                gemm(b, 4096, 4096, dtype),
                gemm(b, 1000, 4096, dtype),
            ]
        }
        Model::ResNet50 => {
            let b = dynamic;
            // Honest stem: 224x224, 7x7, stride 2, pad 3 -> 112x112.
            let mut ops = vec![conv_g(b, 224, 3, 64, 7, (2, 3, 1), dtype)];
            // One representative bottleneck per stage x repeats
            // (1x1 / same-padded 3x3 / 1x1).
            for &(hw_, cin, cmid, reps) in
                &[(56, 64, 64, 3), (28, 256, 128, 4), (14, 512, 256, 6), (7, 1024, 512, 3)]
            {
                for _ in 0..reps {
                    ops.push(conv(b, hw_, cin, cmid, 1, dtype));
                    ops.push(conv(b, hw_, cmid, cmid, 3, dtype));
                    ops.push(conv(b, hw_, cmid, cmid * 4, 1, dtype));
                }
            }
            ops.push(gemm(b, 1000, 2048, dtype));
            ops
        }
        Model::GoogleNet => {
            let b = dynamic;
            let mut ops = vec![
                conv_g(b, 224, 3, 64, 7, (2, 3, 1), dtype),
                conv(b, 56, 64, 192, 3, dtype),
            ];
            // Inception blocks: mixed 1x1 / 3x3 / 5x5 branches.
            for &(hw_, cin) in &[(28usize, 192usize), (28, 256), (14, 480), (14, 512), (14, 528), (7, 832)]
            {
                ops.push(conv(b, hw_, cin, 64, 1, dtype));
                ops.push(conv(b, hw_, cin, 96, 1, dtype));
                ops.push(conv(b, hw_, 96, 128, 3, dtype));
                ops.push(conv(b, hw_, cin, 16, 1, dtype));
                ops.push(conv(b, hw_, 16, 32, 5, dtype));
            }
            ops.push(gemm(b, 1000, 1024, dtype));
            ops
        }
        Model::MobileNet => {
            // MobileNetV1: depthwise-separable blocks — the grouped /
            // depthwise half of the conv family (group axis = batch).
            let b = dynamic;
            let mut ops = vec![conv_g(b, 224, 3, 32, 3, (2, 1, 1), dtype)];
            let blocks: [(usize, usize, usize, usize); 13] = [
                // (hw_in, cin, dw_stride, pw_cout)
                (112, 32, 1, 64),
                (112, 64, 2, 128),
                (56, 128, 1, 128),
                (56, 128, 2, 256),
                (28, 256, 1, 256),
                (28, 256, 2, 512),
                (14, 512, 1, 512),
                (14, 512, 1, 512),
                (14, 512, 1, 512),
                (14, 512, 1, 512),
                (14, 512, 1, 512),
                (14, 512, 2, 1024),
                (7, 1024, 1, 1024),
            ];
            for &(hw_, cin, s, cout) in &blocks {
                ops.push(dwconv(b, hw_, cin, s, dtype));
                let hw_out = if s == 2 { hw_ / 2 } else { hw_ };
                ops.push(conv(b, hw_out, cin, cout, 1, dtype));
            }
            ops.push(gemm(b, 1000, 1024, dtype));
            ops
        }
    }
}

/// Serving-request templates of a model at one dynamic-dim value: the
/// distinct operator shapes a request stream for this model emits,
/// consumed by the serving scenario generator
/// (`serve::scenario::mixed_trace`). Language models request their QKV
/// projection and attention chain at the dynamic sequence length; CNNs
/// request their stem convolution — and depthwise-separable models
/// additionally their first depthwise block — at the dynamic batch.
pub fn request_ops(model: Model, dynamic: usize, dtype: DType) -> Vec<TensorProgram> {
    let t = trace(model, dynamic, dtype);
    if model.is_language_model() {
        // [QKV projection, attention chain] of layer 0.
        t.into_iter().take(2).collect()
    } else {
        let mut out = vec![t[0].clone()];
        let depthwise = t.iter().find(
            |p| matches!(p, TensorProgram::Conv2d { cin, groups, .. } if groups == cin),
        );
        if let Some(dw) = depthwise {
            out.push(dw.clone());
        }
        out
    }
}

/// The paper's dynamic ranges: 17 sequence lengths in [1, 476] for LLMs;
/// batch sizes 1, 4, 8, ..., 64 for CNNs (§7.1).
pub fn dynamic_range(model: Model) -> Vec<usize> {
    if model.is_language_model() {
        let mut v: Vec<usize> = (0..17).map(|i| 1 + i * 475 / 16).collect();
        v.dedup();
        v
    } else {
        let mut v = vec![1];
        v.extend((1..=16).map(|i| i * 4));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_nonempty_and_flops_scale_with_dynamic_dim() {
        for m in Model::all() {
            let small: f64 = trace(m, 4, DType::F32).iter().map(|p| p.flops()).sum();
            let large: f64 = trace(m, 64, DType::F32).iter().map(|p| p.flops()).sum();
            assert!(small > 0.0, "{:?}", m);
            assert!(large > 2.0 * small, "{:?}: {} !> 2*{}", m, large, small);
        }
    }

    #[test]
    fn bert_trace_has_five_ops_per_layer_with_fused_attention() {
        let ops = trace(Model::Bert, 128, DType::F32);
        // QKV + attention chain + output proj + 2 MLP GEMMs per layer.
        assert_eq!(ops.len(), 12 * 5);
        // QKV projection of layer 0.
        assert_eq!(
            ops[0],
            TensorProgram::Gemm { m: 128, n: 2304, k: 768, dtype: DType::F32 }
        );
        // The attention chain carries the dynamic seq into a rank-4
        // FusedAttention space over 12 head groups of dim 64.
        assert_eq!(
            ops[1],
            TensorProgram::Attention { batch: 1, seq: 128, d: 768, heads: 12, dtype: DType::F32 }
        );
        let s = ops[1].space();
        assert_eq!(s.op, crate::ir::OpKind::FusedAttention);
        assert_eq!(s.dims, crate::ir::Tile::new(&[12, 128, 128, 64]));
        // The chain's flops equal the two flat GEMMs it replaced.
        assert_eq!(ops[1].flops(), 4.0 * 12.0 * 128.0 * 128.0 * 64.0);
    }

    #[test]
    fn bert_large_is_bigger_than_bert() {
        let b: f64 = trace(Model::Bert, 128, DType::F32).iter().map(|p| p.flops()).sum();
        let bl: f64 =
            trace(Model::BertLarge, 128, DType::F32).iter().map(|p| p.flops()).sum();
        assert!(bl > 2.0 * b);
    }

    #[test]
    fn cnn_traces_are_conv_dominated() {
        for m in [Model::AlexNet, Model::ResNet50, Model::GoogleNet, Model::MobileNet] {
            let ops = trace(m, 8, DType::F32);
            let convs = ops
                .iter()
                .filter(|p| matches!(p, TensorProgram::Conv2d { .. }))
                .count();
            assert!(convs * 2 > ops.len(), "{:?}", m);
        }
    }

    #[test]
    fn traces_have_valid_geometry_and_honest_stems() {
        for m in Model::all() {
            for p in trace(m, 8, DType::F32) {
                assert!(p.validate().is_ok(), "{:?}: {}", m, p.id());
            }
        }
        // The ResNet stem must produce 112x112 from a 224x224 input.
        let stem = &trace(Model::ResNet50, 1, DType::F32)[0];
        assert_eq!(stem.conv_output(), Some((112, 112)));
        // AlexNet: 11x11 stride-4 pad-2 stem -> 55x55.
        let stem = &trace(Model::AlexNet, 1, DType::F32)[0];
        assert_eq!(stem.conv_output(), Some((55, 55)));
    }

    #[test]
    fn mobilenet_is_depthwise_separable() {
        let ops = trace(Model::MobileNet, 4, DType::F32);
        // 1 stem + 13 x (dw + pw) + classifier.
        assert_eq!(ops.len(), 1 + 13 * 2 + 1);
        let depthwise: Vec<&TensorProgram> = ops
            .iter()
            .filter(|p| {
                matches!(p, TensorProgram::Conv2d { cin, groups, .. } if groups == cin)
            })
            .collect();
        assert_eq!(depthwise.len(), 13);
        for p in depthwise {
            assert_eq!(p.space().op, crate::ir::OpKind::GroupedConv2d);
        }
        // Spatial chaining is consistent: dw output extent feeds the pw.
        let pw_h = match &ops[2] {
            TensorProgram::Conv2d { h, .. } => *h,
            other => panic!("expected conv, got {}", other.id()),
        };
        assert_eq!(ops[1].conv_output().unwrap().0, pw_h);
    }

    #[test]
    fn request_ops_are_the_serving_templates() {
        // Language model: QKV projection + attention chain at the
        // dynamic sequence length.
        let bert = request_ops(Model::Bert, 77, DType::F32);
        assert_eq!(bert.len(), 2);
        assert_eq!(bert[0], TensorProgram::Gemm { m: 77, n: 2304, k: 768, dtype: DType::F32 });
        assert!(matches!(&bert[1], TensorProgram::Attention { seq: 77, .. }));
        // CNN: the stem conv at the dynamic batch.
        let resnet = request_ops(Model::ResNet50, 3, DType::F32);
        assert_eq!(resnet.len(), 1);
        assert!(matches!(&resnet[0], TensorProgram::Conv2d { n: 3, h: 224, .. }));
        // Depthwise-separable model: stem + first depthwise block.
        let mobile = request_ops(Model::MobileNet, 2, DType::F16);
        assert_eq!(mobile.len(), 2);
        assert!(
            matches!(&mobile[1], TensorProgram::Conv2d { cin, groups, .. } if groups == cin)
        );
        for p in bert.iter().chain(&resnet).chain(&mobile) {
            assert!(p.validate().is_ok(), "{}", p.id());
        }
    }

    #[test]
    fn dynamic_ranges_match_paper() {
        let seqs = dynamic_range(Model::Bert);
        assert_eq!(seqs.first(), Some(&1));
        assert_eq!(seqs.last(), Some(&476));
        assert_eq!(seqs.len(), 17);
        let batches = dynamic_range(Model::ResNet50);
        assert_eq!(batches, vec![1, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40, 44, 48, 52, 56, 60, 64]);
    }
}
