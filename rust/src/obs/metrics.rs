//! Counters + exact-percentile histograms over event-clock samples,
//! with Prometheus-style text exposition and a JSON snapshot.
//!
//! A [`MetricsSnapshot`] is a pure projection of serving statistics
//! ([`crate::serve::MixedStats`] / [`crate::serve::FleetStats`]) —
//! it is computed AFTER the discrete-event run from data the run
//! already produced, so like the span tracer it cannot perturb
//! serving. Latency histograms are **exact**: every admitted
//! request's event-clock latency is kept and percentiles are computed
//! by quickselect over the full sample set (same index formula as the
//! per-lane `Metrics` percentiles), not bucket interpolation — the
//! unit tests pin this against a naive sort-based oracle.

use std::collections::BTreeMap;

use crate::serve::{
    CacheStats, DispatchStats, DropRecord, FleetStats, LaneClass, MixedStats,
    RequestOutcome, WorkerStats,
};
use crate::util::json::Json;

/// Exact-percentile histogram: keeps every sample, answers percentile
/// queries by quickselect (O(n) expected, deterministic
/// median-of-three pivoting — no RNG, so snapshots are reproducible).
/// Empty histograms answer 0.0 everywhere, never NaN.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Exact p-th percentile (`0.0 <= p <= 1.0`) using the shared
    /// nearest-rank index formula `round((n - 1) * p)` — identical to
    /// `Metrics::pct` over a sorted trace. 0.0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let k = ((self.samples.len() - 1) as f64 * p).round() as usize;
        let mut scratch = self.samples.clone();
        quickselect(&mut scratch, k)
    }

    /// Largest sample; 0.0 on an empty histogram.
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Arithmetic mean; 0.0 on an empty histogram.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

/// In-place quickselect for the k-th smallest element (k < len).
/// Median-of-three pivoting keeps the common sorted/reversed inputs
/// O(n) and makes the recursion depth deterministic.
fn quickselect(v: &mut [f64], k: usize) -> f64 {
    debug_assert!(k < v.len());
    let (mut lo, mut hi) = (0usize, v.len());
    loop {
        if hi - lo <= 1 {
            return v[lo];
        }
        // Median-of-three pivot moved to the front.
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (v[lo], v[mid], v[hi - 1]);
        let pivot_at = if (a <= b) == (b <= c) {
            mid
        } else if (b <= a) == (a <= c) {
            lo
        } else {
            hi - 1
        };
        v.swap(lo, pivot_at);
        let pivot = v[lo];
        // Hoare-style partition of v[lo+1..hi] around the pivot.
        let (mut i, mut j) = (lo + 1, hi - 1);
        loop {
            while i <= j && v[i] < pivot {
                i += 1;
            }
            while i <= j && v[j] > pivot {
                j -= 1;
            }
            if i >= j {
                break;
            }
            v.swap(i, j);
            i += 1;
            j -= 1;
        }
        let p = i - 1;
        v.swap(lo, p);
        match k.cmp(&p) {
            std::cmp::Ordering::Equal => return v[p],
            std::cmp::Ordering::Less => hi = p,
            std::cmp::Ordering::Greater => lo = p + 1,
        }
    }
}

/// One latency track: the event-clock latency distribution of a
/// (replica, lane/op-class) pair.
#[derive(Debug, Clone)]
pub struct LatencyTrack {
    pub replica: usize,
    pub lane: LaneClass,
    pub hist: Histogram,
}

/// Counter + histogram snapshot of one serving run.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Requests served (admitted + degraded).
    pub served: u64,
    pub dropped: u64,
    pub degraded: u64,
    /// Tri-state plan resolution counters (table / cache / fresh).
    pub plan: DispatchStats,
    /// Plan-cache hits / misses / evictions.
    pub cache: CacheStats,
    /// Per-worker executed-unit / steal counters from the
    /// work-stealing executor (empty outside the fleet pool).
    pub workers: Vec<WorkerStats>,
    /// Exact latency distributions per (replica, lane), sorted by
    /// (replica, lane index).
    pub latency: Vec<LatencyTrack>,
}

impl MetricsSnapshot {
    fn from_parts(
        outcomes: &[RequestOutcome],
        drops: &[DropRecord],
        plan: DispatchStats,
        cache: CacheStats,
        workers: Vec<WorkerStats>,
    ) -> MetricsSnapshot {
        let mut tracks: BTreeMap<(usize, usize), Histogram> = BTreeMap::new();
        let mut degraded = 0u64;
        for o in outcomes {
            tracks.entry((o.replica, o.lane.index())).or_default().record(o.latency);
            degraded += u64::from(o.degraded);
        }
        MetricsSnapshot {
            served: outcomes.len() as u64,
            dropped: drops.len() as u64,
            degraded,
            plan,
            cache,
            workers,
            latency: tracks
                .into_iter()
                .map(|((replica, lane), hist)| LatencyTrack {
                    replica,
                    lane: LaneClass::ALL[lane],
                    hist,
                })
                .collect(),
        }
    }

    /// Prometheus text exposition (version 0.0.4): counters as
    /// `vortex_*_total`, latency quantiles as a summary-style family
    /// labeled by replica × lane. Deterministic output order.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, rows: &[(String, u64)]| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for (labels, v) in rows {
                let _ = writeln!(out, "{name}{labels} {v}");
            }
        };
        counter(
            "vortex_requests_served_total",
            "Requests served (admitted + degraded).",
            &[(String::new(), self.served)],
        );
        counter(
            "vortex_requests_dropped_total",
            "Requests shed by the admission controller.",
            &[(String::new(), self.dropped)],
        );
        counter(
            "vortex_requests_degraded_total",
            "Requests served under a downgraded backend mode.",
            &[(String::new(), self.degraded)],
        );
        counter(
            "vortex_plan_resolutions_total",
            "Plan resolutions by source (table / cache / fresh).",
            &[
                ("{source=\"table\"}".to_string(), self.plan.table),
                ("{source=\"cache\"}".to_string(), self.plan.cache),
                ("{source=\"fresh\"}".to_string(), self.plan.fresh),
            ],
        );
        counter(
            "vortex_plan_cache_events_total",
            "Plan-cache lookups by result.",
            &[
                ("{event=\"hit\"}".to_string(), self.cache.hits),
                ("{event=\"miss\"}".to_string(), self.cache.misses),
                ("{event=\"eviction\"}".to_string(), self.cache.evictions),
            ],
        );
        if !self.workers.is_empty() {
            let exec: Vec<(String, u64)> = self
                .workers
                .iter()
                .enumerate()
                .map(|(w, s)| (format!("{{worker=\"{w}\"}}"), s.executed as u64))
                .collect();
            let steal: Vec<(String, u64)> = self
                .workers
                .iter()
                .enumerate()
                .map(|(w, s)| (format!("{{worker=\"{w}\"}}"), s.stolen as u64))
                .collect();
            counter(
                "vortex_worker_units_total",
                "(replica, lane) units executed per pool worker.",
                &exec,
            );
            counter(
                "vortex_worker_steals_total",
                "Units stolen from another worker's queue.",
                &steal,
            );
        }
        let _ = writeln!(
            out,
            "# HELP vortex_request_latency_seconds Event-clock request latency per replica x lane."
        );
        let _ = writeln!(out, "# TYPE vortex_request_latency_seconds summary");
        for t in &self.latency {
            let base = format!("replica=\"{}\",lane=\"{}\"", t.replica, t.lane.name());
            for (q, v) in [
                ("0.5", t.hist.percentile(0.5)),
                ("0.9", t.hist.percentile(0.9)),
                ("0.99", t.hist.percentile(0.99)),
            ] {
                let _ = writeln!(
                    out,
                    "vortex_request_latency_seconds{{{base},quantile=\"{q}\"}} {v}"
                );
            }
            let _ = writeln!(
                out,
                "vortex_request_latency_seconds_max{{{base}}} {}",
                t.hist.max()
            );
            let _ = writeln!(
                out,
                "vortex_request_latency_seconds_count{{{base}}} {}",
                t.hist.len()
            );
        }
        out
    }

    /// JSON snapshot mirroring [`MetricsSnapshot::to_prometheus`]
    /// (same counters and quantiles, machine-friendly shape).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("served", Json::num(self.served as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("degraded", Json::num(self.degraded as f64)),
            (
                "plan",
                Json::obj(vec![
                    ("table", Json::num(self.plan.table as f64)),
                    ("cache", Json::num(self.plan.cache as f64)),
                    ("fresh", Json::num(self.plan.fresh as f64)),
                    ("warm_start_rate", Json::num(self.plan.warm_start_rate())),
                ]),
            ),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(self.cache.hits as f64)),
                    ("misses", Json::num(self.cache.misses as f64)),
                    ("evictions", Json::num(self.cache.evictions as f64)),
                    ("hit_rate", Json::num(self.cache.hit_rate())),
                ]),
            ),
            (
                "workers",
                Json::arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("executed", Json::num(w.executed as f64)),
                                ("stolen", Json::num(w.stolen as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "latency",
                Json::arr(
                    self.latency
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("replica", Json::num(t.replica as f64)),
                                ("lane", Json::str(t.lane.name())),
                                ("count", Json::num(t.hist.len() as f64)),
                                ("p50", Json::num(t.hist.percentile(0.5))),
                                ("p90", Json::num(t.hist.percentile(0.9))),
                                ("p99", Json::num(t.hist.percentile(0.99))),
                                ("max", Json::num(t.hist.max())),
                                ("mean", Json::num(t.hist.mean())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Snapshot a single-host mixed run.
pub fn snapshot_mixed(stats: &MixedStats) -> MetricsSnapshot {
    MetricsSnapshot::from_parts(
        &stats.outcomes,
        &stats.drops,
        stats.dispatch,
        stats.cache.clone(),
        Vec::new(),
    )
}

/// Snapshot a fleet run (includes per-worker executor counters).
pub fn snapshot_fleet(stats: &FleetStats) -> MetricsSnapshot {
    MetricsSnapshot::from_parts(
        &stats.outcomes,
        &stats.drops,
        stats.dispatch,
        stats.cache.clone(),
        stats.worker_stats.clone(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive oracle: full sort + the shared nearest-rank index.
    fn sort_pct(samples: &[f64], p: f64) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[((s.len() - 1) as f64 * p).round() as usize]
    }

    #[test]
    fn quickselect_matches_the_sort_oracle_on_random_samples() {
        let mut rng = Rng::new(0x0b5e);
        for trial in 0..50 {
            let n = 1 + (trial * 37) % 400;
            // Event-clock-like latencies: exponential with ties mixed in.
            let samples: Vec<f64> = (0..n)
                .map(|i| {
                    if i % 7 == 0 {
                        1e-3
                    } else {
                        rng.exp(2e-3)
                    }
                })
                .collect();
            let mut h = Histogram::default();
            samples.iter().for_each(|&s| h.record(s));
            for p in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let got = h.percentile(p);
                let want = sort_pct(&samples, p);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "n={n} p={p}: quickselect {got} != sort oracle {want}"
                );
            }
            assert_eq!(h.max(), sort_pct(&samples, 1.0).max(0.0));
        }
    }

    #[test]
    fn quickselect_handles_sorted_reversed_and_constant_inputs() {
        for samples in [
            (0..100).map(f64::from).collect::<Vec<_>>(),
            (0..100).rev().map(f64::from).collect(),
            vec![4.2; 64],
            vec![1.0],
        ] {
            let mut h = Histogram::default();
            samples.iter().for_each(|&s| h.record(s));
            for p in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(h.percentile(p), sort_pct(&samples, p));
            }
        }
    }

    #[test]
    fn empty_histogram_answers_zero_everywhere() {
        let h = Histogram::default();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn empty_snapshot_exports_are_well_defined() {
        // The empty-trace path: zero admitted requests must yield
        // finite zeros in every exported number, not NaN.
        let snap = snapshot_mixed(&MixedStats::default());
        assert_eq!(snap.served, 0);
        let json = snap.to_json().dump();
        assert!(!json.contains("NaN") && !json.contains("null"), "{json}");
        assert_eq!(
            snap.to_json().get("plan").unwrap().get("warm_start_rate").unwrap().as_f64(),
            Some(0.0)
        );
        let prom = snap.to_prometheus();
        assert!(prom.contains("vortex_requests_served_total 0"));
        assert!(!prom.contains("NaN"));
    }
}
