//! Chrome trace-event JSON export / import for [`Trace`].
//!
//! Emits the object-form trace-event format — `{"displayTimeUnit",
//! "otherData", "traceEvents"}` — loadable directly in
//! `chrome://tracing` and Perfetto:
//!
//! * `"M"` metadata events label processes (replicas) and threads
//!   (lanes): `process_name` / `thread_name`;
//! * `"X"` complete events carry `ts` + `dur` in microseconds;
//! * `"i"` instant events (`"s":"t"`, thread-scoped) mark admissions,
//!   plan resolutions, drops and degrades.
//!
//! Spans store microseconds natively, and the crate's JSON layer is
//! deterministic (sorted object keys, shortest-round-trip `f64`
//! printing, correctly-rounded parsing), so
//! `emit -> parse -> re-emit` is **byte-identical** — the round-trip
//! property `tests` below and the schema gate in CI rely on.
//!
//! Extension field: events stamped from a wall clock (offline compile
//! / profiler spans) carry `"clock":"wall"`; viewers ignore the
//! unknown key, while [`crate::analysis::audit_trace`] uses it to
//! reject wall-clock timestamps inside serving categories.

use std::collections::BTreeMap;

use super::{Span, SpanClock, Trace};
use crate::util::json::Json;

impl Trace {
    /// Serialize to Chrome trace-event JSON (compact, deterministic).
    pub fn to_chrome_json(&self) -> String {
        let mut events: Vec<Json> = Vec::new();
        for (pid, label) in &self.processes {
            events.push(Json::obj(vec![
                ("args", Json::obj(vec![("name", Json::str(label.clone()))])),
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(*pid as f64)),
            ]));
        }
        for (pid, tid, label) in &self.threads {
            events.push(Json::obj(vec![
                ("args", Json::obj(vec![("name", Json::str(label.clone()))])),
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(*pid as f64)),
                ("tid", Json::num(*tid as f64)),
            ]));
        }
        for s in &self.spans {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("cat", Json::str(s.cat.clone())),
                ("name", Json::str(s.name.clone())),
                ("pid", Json::num(s.pid as f64)),
                ("tid", Json::num(s.tid as f64)),
                ("ts", Json::num(s.ts_us)),
            ];
            match s.dur_us {
                Some(d) => {
                    pairs.push(("ph", Json::str("X")));
                    pairs.push(("dur", Json::num(d)));
                }
                None => {
                    pairs.push(("ph", Json::str("i")));
                    pairs.push(("s", Json::str("t")));
                }
            }
            if s.clock == SpanClock::Wall {
                pairs.push(("clock", Json::str("wall")));
            }
            if !s.args.is_empty() {
                let map: BTreeMap<String, Json> = s.args.iter().cloned().collect();
                pairs.push(("args", Json::Obj(map)));
            }
            events.push(Json::obj(pairs));
        }
        let other: BTreeMap<String, Json> = self.meta.iter().cloned().collect();
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("otherData", Json::Obj(other)),
            ("traceEvents", Json::arr(events)),
        ])
        .dump()
    }

    /// Parse Chrome trace-event JSON produced by [`Trace::to_chrome_json`]
    /// (or hand-written in the same dialect). Validates the event schema:
    /// unknown phase types, missing fields, or non-numeric stamps are
    /// errors, not skips.
    pub fn from_chrome_json(text: &str) -> Result<Trace, String> {
        let root = Json::parse(text).map_err(|e| e.to_string())?;
        let mut trace = Trace::default();
        if let Some(other) = root.get("otherData").and_then(Json::as_obj) {
            trace.meta = other.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        }
        let events = root
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or("missing traceEvents array")?;
        for (i, ev) in events.iter().enumerate() {
            let at = |what: &str| format!("traceEvents[{i}]: {what}");
            let ph = ev.get("ph").and_then(Json::as_str).ok_or_else(|| at("missing ph"))?;
            let pid = ev
                .get("pid")
                .and_then(Json::as_usize)
                .ok_or_else(|| at("missing pid"))? as u64;
            match ph {
                "M" => {
                    let name = ev
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| at("metadata event without name"))?;
                    let label = ev
                        .get("args")
                        .and_then(|a| a.get("name"))
                        .and_then(Json::as_str)
                        .ok_or_else(|| at("metadata event without args.name"))?
                        .to_string();
                    match name {
                        "process_name" => trace.processes.push((pid, label)),
                        "thread_name" => {
                            let tid = ev
                                .get("tid")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| at("thread_name without tid"))?;
                            trace.threads.push((pid, tid as u64, label));
                        }
                        other => return Err(at(&format!("unknown metadata '{other}'"))),
                    }
                }
                "X" | "i" => {
                    let name = ev
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| at("missing name"))?
                        .to_string();
                    let cat = ev
                        .get("cat")
                        .and_then(Json::as_str)
                        .ok_or_else(|| at("missing cat"))?
                        .to_string();
                    let tid = ev
                        .get("tid")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| at("missing tid"))? as u64;
                    let ts_us = ev
                        .get("ts")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| at("missing ts"))?;
                    let dur_us = if ph == "X" {
                        Some(
                            ev.get("dur")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| at("complete event without dur"))?,
                        )
                    } else {
                        if ev.get("s").and_then(Json::as_str) != Some("t") {
                            return Err(at("instant event without thread scope"));
                        }
                        None
                    };
                    let clock = match ev.get("clock").and_then(Json::as_str) {
                        Some("wall") => SpanClock::Wall,
                        Some(other) => {
                            return Err(at(&format!("unknown clock '{other}'")))
                        }
                        None => SpanClock::Event,
                    };
                    let args = ev
                        .get("args")
                        .and_then(Json::as_obj)
                        .map(|o| {
                            o.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
                        })
                        .unwrap_or_default();
                    trace.spans.push(Span {
                        name,
                        cat,
                        pid,
                        tid,
                        ts_us,
                        dur_us,
                        clock,
                        args,
                    });
                }
                other => return Err(at(&format!("unsupported phase '{other}'"))),
            }
        }
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            spans: vec![
                Span::complete("form", "serve", 0, 1, 1.25e-3, 7.5e-4)
                    .arg("batch", Json::num(3.0)),
                Span::instant("plan", "serve", 0, 1, 2e-3)
                    .arg("source", Json::str("table")),
                Span::complete("candgen", "compile", 0, 0, 0.0, 0.125).wall(),
            ],
            processes: vec![(0, "replica 0".into())],
            threads: vec![(0, 1, "gemm".into())],
            meta: vec![("seed".into(), Json::num(7.0))],
        }
    }

    #[test]
    fn emit_parse_reemit_is_byte_identical() {
        let first = sample().to_chrome_json();
        let parsed = Trace::from_chrome_json(&first).unwrap();
        assert_eq!(parsed, sample());
        assert_eq!(parsed.to_chrome_json(), first);
    }

    #[test]
    fn awkward_float_timestamps_survive_the_round_trip() {
        // Values with no finite decimal representation: the emitter's
        // shortest-round-trip printing + the parser's correctly-rounded
        // reading must reproduce the exact bits.
        let mut t = Trace::default();
        for (i, ts) in [0.1, 1.0 / 3.0, 2.5e-7, 123456.789012345].iter().enumerate() {
            t.spans.push(Span::complete("exec", "serve", 0, 0, *ts, *ts / 7.0));
            t.spans[i].ts_us = *ts; // raw µs, bypass the secs conversion
        }
        let one = t.to_chrome_json();
        let back = Trace::from_chrome_json(&one).unwrap();
        for (a, b) in t.spans.iter().zip(&back.spans) {
            assert_eq!(a.ts_us.to_bits(), b.ts_us.to_bits());
        }
        assert_eq!(back.to_chrome_json(), one);
    }

    #[test]
    fn schema_violations_are_errors() {
        assert!(Trace::from_chrome_json("{}").is_err());
        let no_dur = r#"{"traceEvents":[{"cat":"serve","name":"x","ph":"X","pid":0,"tid":0,"ts":1}]}"#;
        assert!(Trace::from_chrome_json(no_dur).unwrap_err().contains("dur"));
        let bad_ph = r#"{"traceEvents":[{"cat":"serve","name":"x","ph":"Q","pid":0,"tid":0,"ts":1}]}"#;
        assert!(Trace::from_chrome_json(bad_ph).unwrap_err().contains("phase"));
        let bad_clock = r#"{"traceEvents":[{"cat":"c","clock":"lunar","dur":1,"name":"x","ph":"X","pid":0,"tid":0,"ts":1}]}"#;
        assert!(Trace::from_chrome_json(bad_clock).unwrap_err().contains("clock"));
    }

    #[test]
    fn metadata_events_label_tracks() {
        let json = sample().to_chrome_json();
        let t = Trace::from_chrome_json(&json).unwrap();
        assert_eq!(t.track_label(0, 1), "replica 0/gemm");
        assert_eq!(t.track_label(3, 9), "pid 3/tid 9");
    }
}
