//! Layer 9 — observability: zero-perturbation span tracing + metrics.
//!
//! Vortex's headline claims are rates and latencies — compile-time
//! speedups, O(axes · log intervals) dispatch, SLO-bounded p99 under
//! fleet load — and this module is the layer that turns every one of
//! them into an inspectable artifact instead of a per-run aggregate.
//! It threads through the whole stack:
//!
//! * **Serving spans** ([`crate::serve`]): admission, per-(replica,
//!   lane) batch formation, plan resolution tagged table/cache/fresh,
//!   the modeled scheduling charge, execution, and drop/degrade
//!   decisions. Every serving span is stamped from the
//!   **deterministic discrete-event clock** ([`SpanClock::Event`]) —
//!   the same `f64` seconds the serving loop already computes — so
//!   recording a span never reads a wall clock, never branches on
//!   shared state, and never feeds a value back into the loop.
//!   Tracing is therefore *zero-perturbation by construction*: a
//!   traced run is bit-identical to an untraced one, a property the
//!   fleet determinism oracle (`tests/fleet_oracle.rs`) proves at
//!   every CI worker count.
//! * **Compile spans** ([`crate::compiler::CompileReport::phases`]):
//!   candgen, the sequential L0 micro-measurement phase, the parallel
//!   per-L1 ranking, winner profiling and pruning — plus the
//!   per-(op, mode) dispatch-table build
//!   ([`crate::dispatch::BuildStats::per_table`]) with cell/merge
//!   counts. Offline phases are genuinely wall-clock; their spans are
//!   explicitly marked [`SpanClock::Wall`] so the trace schema itself
//!   distinguishes measured time from modeled time — and the trace
//!   auditor ([`crate::analysis::audit_trace`]) REJECTS a wall-marked
//!   span in a serving category.
//! * **Exports**: Chrome trace-event JSON ([`Trace::to_chrome_json`],
//!   loadable in `chrome://tracing` / Perfetto; parsed back by
//!   [`Trace::from_chrome_json`] with a byte-identical re-emit), a
//!   Prometheus-style text exposition + JSON snapshot of counters and
//!   exact-percentile latency histograms
//!   ([`MetricsSnapshot`]), and the `vortex trace summarize` CLI that
//!   prints a per-phase / per-track breakdown from a trace file.
//!
//! Timestamps are stored in **microseconds** (`ts_us` / `dur_us`) —
//! the Chrome trace-event unit — converted from event-clock seconds
//! exactly once at span construction, so emit → parse → re-emit never
//! re-converts (the round-trip stays byte-identical).
//!
//! **Add-an-op note:** span names are lane-agnostic (`admit`, `form`,
//! `plan`, `sched`, `exec`, `drop`, `degrade`); a new op only adds a
//! thread-label via [`crate::serve::LaneClass::name`], so the span
//! taxonomy — and every tool that consumes it — is untouched.
//!
//! See the "Layer 9 — observability" section of
//! `docs/ARCHITECTURE.md` for the full span taxonomy and the
//! determinism argument.

pub mod chrome;
pub mod metrics;

pub use metrics::{snapshot_fleet, snapshot_mixed, Histogram, MetricsSnapshot};

use crate::util::json::Json;
use crate::util::table::Table;

/// Which clock stamped a span. Serving spans are `Event` — simulated
/// seconds from the deterministic discrete-event loop. `Wall` marks
/// the explicitly-allowed exceptions: offline compile phases and
/// profiler measurement, where the duration IS the measurement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpanClock {
    #[default]
    Event,
    Wall,
}

/// One trace event: a complete span (`dur_us: Some`) or an instant
/// (`dur_us: None`). `pid` is the replica (serving) or 0 (compile);
/// `tid` is the lane index (serving) or 0 (compile pipeline track).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    /// Category: `"serve"`, `"compile"`, `"profiler"`, `"dispatch"`.
    pub cat: String,
    pub pid: u64,
    pub tid: u64,
    /// Start timestamp, microseconds on this span's clock.
    pub ts_us: f64,
    /// Duration in microseconds; `None` renders as an instant event.
    pub dur_us: Option<f64>,
    pub clock: SpanClock,
    /// Structured payload; rendered as the Chrome `args` object
    /// (sorted keys, so emission is deterministic).
    pub args: Vec<(String, Json)>,
}

impl Span {
    /// A complete span from `[start, start + dur]` seconds.
    pub fn complete(
        name: &str,
        cat: &str,
        pid: u64,
        tid: u64,
        start_secs: f64,
        dur_secs: f64,
    ) -> Span {
        Span {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us: start_secs * 1e6,
            dur_us: Some(dur_secs * 1e6),
            clock: SpanClock::Event,
            args: Vec::new(),
        }
    }

    /// An instant event at `at_secs`.
    pub fn instant(name: &str, cat: &str, pid: u64, tid: u64, at_secs: f64) -> Span {
        Span { dur_us: None, ..Span::complete(name, cat, pid, tid, at_secs, 0.0) }
    }

    /// Mark this span as wall-clock (offline compile / profiler time).
    pub fn wall(mut self) -> Span {
        self.clock = SpanClock::Wall;
        self
    }

    pub fn arg(mut self, key: &str, value: Json) -> Span {
        self.args.push((key.to_string(), value));
        self
    }
}

/// A full structured trace: the span list plus track labels and
/// run-level metadata. Assembled by the serving layer
/// ([`crate::serve::MixedStats::trace`],
/// [`crate::serve::FleetStats::trace`]) and by [`compile_trace`];
/// exported via [`Trace::to_chrome_json`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub spans: Vec<Span>,
    /// `(pid, label)` process labels, e.g. `(0, "replica 0")`.
    pub processes: Vec<(u64, String)>,
    /// `(pid, tid, label)` thread labels, e.g. `(0, 1, "gemm")`.
    pub threads: Vec<(u64, u64, String)>,
    /// Run-level metadata (routing policy, seed, ...), exported under
    /// the Chrome `otherData` object.
    pub meta: Vec<(String, Json)>,
}

impl Trace {
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Fold another trace's spans and track labels into this one
    /// (deduplicating labels; metadata keeps the first value).
    pub fn merge(&mut self, other: Trace) {
        self.spans.extend(other.spans);
        for p in other.processes {
            if !self.processes.contains(&p) {
                self.processes.push(p);
            }
        }
        for t in other.threads {
            if !self.threads.contains(&t) {
                self.threads.push(t);
            }
        }
        for (k, v) in other.meta {
            if !self.meta.iter().any(|(mk, _)| *mk == k) {
                self.meta.push((k, v));
            }
        }
    }

    /// The label of a `(pid, tid)` track: `"<process>/<thread>"` with
    /// numeric fallbacks for unlabeled tracks.
    pub fn track_label(&self, pid: u64, tid: u64) -> String {
        let p = self
            .processes
            .iter()
            .find(|(i, _)| *i == pid)
            .map_or_else(|| format!("pid {pid}"), |(_, n)| n.clone());
        let t = self
            .threads
            .iter()
            .find(|(i, j, _)| *i == pid && *j == tid)
            .map_or_else(|| format!("tid {tid}"), |(_, _, n)| n.clone());
        format!("{p}/{t}")
    }

    /// Per-(track, span-name) breakdown table — the `vortex trace
    /// summarize` report: counts, total/mean/max duration and the
    /// share of the track's total span time.
    pub fn summary_table(&self) -> Table {
        use std::collections::BTreeMap;
        // (pid, tid, name) -> (count, total_us, max_us)
        let mut rows: BTreeMap<(u64, u64, String), (usize, f64, f64)> = BTreeMap::new();
        let mut track_total: BTreeMap<(u64, u64), f64> = BTreeMap::new();
        for s in &self.spans {
            let e = rows.entry((s.pid, s.tid, s.name.clone())).or_insert((0, 0.0, 0.0));
            let d = s.dur_us.unwrap_or(0.0);
            e.0 += 1;
            e.1 += d;
            e.2 = e.2.max(d);
            if s.dur_us.is_some() {
                *track_total.entry((s.pid, s.tid)).or_insert(0.0) += d;
            }
        }
        let mut t = Table::new(
            "trace summary (per track x span)",
            &["track", "span", "count", "total", "mean", "max", "share %"],
        );
        for ((pid, tid, name), (count, total, max)) in rows {
            let denom = track_total.get(&(pid, tid)).copied().unwrap_or(0.0);
            let share = if denom > 0.0 { 100.0 * total / denom } else { 0.0 };
            t.row(vec![
                self.track_label(pid, tid),
                name,
                count.to_string(),
                crate::util::table::fmt_secs(total * 1e-6),
                crate::util::table::fmt_secs(total * 1e-6 / count.max(1) as f64),
                crate::util::table::fmt_secs(max * 1e-6),
                format!("{share:.1}"),
            ]);
        }
        t
    }
}

/// Assemble the offline-stage trace: the compile phases recorded in a
/// [`crate::compiler::CompileReport`] plus, when a dispatch table was
/// built, one `dispatch` span per (op, mode) table with its
/// cell/merge counts. All spans are wall-marked — this is the offline
/// half, where wall time is the measurement.
pub fn compile_trace(
    report: &crate::compiler::CompileReport,
    build: Option<&crate::dispatch::BuildStats>,
) -> Trace {
    let mut trace = Trace {
        processes: vec![(0, "compile".to_string())],
        threads: vec![(0, 0, "pipeline".to_string()), (0, 1, "dispatch".to_string())],
        meta: vec![
            ("op".to_string(), Json::str(report.library.op.to_string())),
            ("dtype".to_string(), Json::str(report.library.dtype.name())),
            ("hw".to_string(), Json::str(report.library.hw_name.clone())),
        ],
        ..Trace::default()
    };
    trace.spans.extend(report.phases.iter().cloned());
    if let Some(b) = build {
        // Per-table build spans laid end to end on the dispatch track
        // (the build itself is sequential over (op, mode) pairs).
        let mut at = 0.0f64;
        for t in &b.per_table {
            trace.spans.push(
                Span::complete("dispatch_table", "dispatch", 0, 1, at, t.build_secs)
                    .wall()
                    .arg("op", Json::str(t.op.to_string()))
                    .arg("mode", Json::str(t.mode.clone()))
                    .arg("cells_enumerated", Json::num(t.cells_enumerated as f64))
                    .arg("cells_merged", Json::num(t.cells_merged as f64)),
            );
            at += t.build_secs;
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_builders_stamp_microseconds_once() {
        let s = Span::complete("exec", "serve", 2, 1, 1.5e-3, 2e-6)
            .arg("batch", Json::num(4.0));
        assert_eq!(s.ts_us, 1.5e-3 * 1e6);
        assert_eq!(s.dur_us, Some(2e-6 * 1e6));
        assert_eq!(s.clock, SpanClock::Event);
        let i = Span::instant("drop", "serve", 0, 0, 0.25).wall();
        assert_eq!(i.dur_us, None);
        assert_eq!(i.clock, SpanClock::Wall);
    }

    #[test]
    fn merge_dedups_track_labels_and_keeps_first_meta() {
        let mut a = Trace {
            processes: vec![(0, "replica 0".into())],
            meta: vec![("routing".into(), Json::str("hash-key"))],
            ..Trace::default()
        };
        let b = Trace {
            spans: vec![Span::instant("admit", "serve", 0, 0, 0.0)],
            processes: vec![(0, "replica 0".into()), (1, "replica 1".into())],
            meta: vec![("routing".into(), Json::str("least-loaded"))],
            ..Trace::default()
        };
        a.merge(b);
        assert_eq!(a.spans.len(), 1);
        assert_eq!(a.processes.len(), 2);
        assert_eq!(a.meta.len(), 1);
        assert_eq!(a.meta[0].1.as_str(), Some("hash-key"));
    }

    #[test]
    fn summary_table_groups_by_track_and_name() {
        let trace = Trace {
            spans: vec![
                Span::complete("exec", "serve", 0, 0, 0.0, 1e-3),
                Span::complete("exec", "serve", 0, 0, 2e-3, 3e-3),
                Span::instant("admit", "serve", 0, 0, 0.0),
            ],
            processes: vec![(0, "replica 0".into())],
            threads: vec![(0, 0, "gemm".into())],
            ..Trace::default()
        };
        let t = trace.summary_table();
        // Two grouped rows: admit (instants) and exec (2 spans).
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[1][2], "2");
    }
}
