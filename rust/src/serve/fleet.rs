//! Fleet serving: shard admission across N replicas under
//! deterministic routing and execute the independent (replica, lane)
//! units on an optional worker pool — with the single-threaded
//! discrete-event replay as the correctness oracle.
//!
//! The paper's online half is O(1) per request (dispatch-table lookup),
//! so serving "millions of users" (ROADMAP) is an embarrassingly
//! shardable problem: every replica reads the SAME audited compile-time
//! [`DispatchTable`] through an [`Arc`] (the table is immutable after
//! its build, so sharing it is free — no per-replica clones of the
//! cell lattice) while owning its own [`PlanCache`] shards, so replicas
//! share no MUTABLE state at all. That makes determinism a
//! construction property rather than a locking discipline:
//!
//! 1. **Routing is a sequential pre-pass.** Before anything executes,
//!    every request is assigned a replica by a pure function of the
//!    trace prefix ([`RoutePolicy`]) — hash-affinity on the merge key
//!    (cache-friendly: compatible requests land together) or
//!    least-loaded on accumulated dynamic units (balance-friendly).
//!    Worker scheduling can never perturb placement.
//! 2. **The unit of work is one (replica, lane) pair.** Each unit gets
//!    a FRESH engine from the caller's factory (engines derive their
//!    noise streams from hardware + seed, so a fresh engine per unit is
//!    bit-reproducible wherever it is constructed), a fresh per-lane
//!    plan-cache shard, and runs the same [`serve_lane`] loop the
//!    single-threaded path runs.
//! 3. **The executor only chooses WHEN units run**
//!    ([`super::execute_units`]): results are scattered into
//!    unit-indexed slots and aggregated in a fixed (replica, lane)
//!    order, so worker count and steal order are unobservable in the
//!    output. `workers <= 1` IS the discrete-event simulation; the
//!    oracle test (`tests/fleet_oracle.rs`) checks the pool against it
//!    bitwise — selections, plan sources, latencies, drop decisions.
//!
//! Per-lane SLO priorities ([`LaneSlo::priority`]) seed the work
//! queues highest-first — a latency hint for the pool, provably not an
//! outcome change.

use std::cmp::Reverse;
use std::sync::Arc;

use crate::analysis::Diagnostic;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::select::Selector;
use crate::dispatch::DispatchTable;
use crate::util::rng::fnv1a;

use crate::obs::Trace;
use crate::util::json::Json;

use super::{
    dynamic_units, execute_units, merge_key, resolve_dispatch, serve_decode_lane,
    serve_lane, CacheStats, DispatchStats, DropRecord, LaneClass, LaneEngine, MixedStats,
    PlanCache, PlanSource, RequestOutcome, ServeConfig, ServeRequest, WorkerStats,
};

/// How the admission pre-pass assigns requests to replicas. Both
/// policies are pure functions of the trace prefix — routing is
/// deterministic and independent of execution order by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Merge-key affinity: requests hash on their [`merge_key`], so
    /// batch-compatible requests always land on the same replica —
    /// maximizes merge opportunities and keeps each replica's plan
    /// cache hot on its own shape families.
    #[default]
    HashKey,
    /// Send each request to the replica with the least accumulated
    /// dynamic-unit load so far (lowest index on ties) — trades cache
    /// affinity for balance under skewed traffic.
    LeastLoaded,
}

impl RoutePolicy {
    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::HashKey => "hash-key",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Fleet deployment shape: replica count, worker-pool size and the
/// per-replica serving configuration (every replica runs the same
/// [`ServeConfig`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of replicas admission shards across (>= 1).
    pub replicas: usize,
    /// Worker threads executing (replica, lane) units. `0` or `1` runs
    /// the sequential discrete-event loop on the calling thread — the
    /// determinism oracle the pool is tested against.
    pub workers: usize,
    pub routing: RoutePolicy,
    pub serve: ServeConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: 1,
            workers: 0,
            routing: RoutePolicy::default(),
            serve: ServeConfig::default(),
        }
    }
}

/// Fleet-wide serving result: per-replica [`MixedStats`] plus the
/// fleet aggregates. `outcomes`/`drops` are fleet-wide and sorted by
/// request id — the exact vectors the determinism oracle compares.
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// Per-replica results, indexed by replica (every replica present
    /// even when routed zero requests).
    pub replicas: Vec<MixedStats>,
    /// All outcomes fleet-wide, sorted by request id.
    pub outcomes: Vec<RequestOutcome>,
    /// All shed requests fleet-wide, sorted by request id.
    pub drops: Vec<DropRecord>,
    /// Fleet-wide tri-state plan-source accounting.
    pub dispatch: DispatchStats,
    /// Summed plan-cache counters across every per-unit shard.
    pub cache: CacheStats,
    /// Offline build statistics of the shared dispatch table build
    /// (built ONCE, shared read-only across replicas), when dispatch
    /// is enabled.
    pub dispatch_build: Option<crate::dispatch::BuildStats>,
    /// Adopted-table audit findings (see [`ServeConfig::table_policy`]).
    pub table_diags: Vec<Diagnostic>,
    /// Static SLO feasibility findings ([`crate::analysis::audit_slo`]):
    /// deadlines below the modeled service floor, unservable downgrade
    /// modes, windows exceeding deadlines. Advisory — serving proceeds.
    pub slo_diags: Vec<Diagnostic>,
    /// Max replica span (replicas are concurrent by definition).
    pub span_secs: f64,
    /// Per-worker executor telemetry (units executed / stolen).
    /// Timing-dependent with a real pool — excluded from the
    /// determinism oracle's fingerprint by design.
    pub worker_stats: Vec<WorkerStats>,
    /// Fleet-wide span trace when [`ServeConfig::trace`] was set:
    /// every replica a process, every (replica, lane) a thread track,
    /// spans aggregated in fixed unit order (see [`crate::obs`]).
    pub trace: Option<Trace>,
}

impl FleetStats {
    pub fn count(&self) -> usize {
        self.outcomes.len()
    }

    /// Requests offered to the fleet: served + shed.
    pub fn offered(&self) -> usize {
        self.outcomes.len() + self.drops.len()
    }

    /// Served requests that ran under a downgraded mode.
    pub fn degraded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.degraded).count()
    }

    /// Served at full fidelity. The accounting identity the overload
    /// tests pin: `admitted() + degraded() + drops.len() == offered()`.
    pub fn admitted(&self) -> usize {
        self.outcomes.len() - self.degraded()
    }

    /// Aggregate (p50, p95, p99) request latency across the fleet —
    /// same index formula as the per-lane [`Metrics`] percentiles.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut lat: Vec<f64> = self.outcomes.iter().map(|o| o.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            Metrics::pct(&lat, 0.5),
            Metrics::pct(&lat, 0.95),
            Metrics::pct(&lat, 0.99),
        )
    }
}

/// The routing pre-pass: replica index per request, as a pure function
/// of the trace prefix. Exposed to the oracle tests so they can assert
/// placement invariance directly.
pub(crate) fn route(
    policy: RoutePolicy,
    replicas: usize,
    requests: &[ServeRequest],
) -> Vec<usize> {
    match policy {
        RoutePolicy::HashKey => requests
            .iter()
            .map(|r| (fnv1a(merge_key(&r.program).id().as_bytes()) % replicas as u64) as usize)
            .collect(),
        RoutePolicy::LeastLoaded => {
            let mut loads = vec![0usize; replicas];
            requests
                .iter()
                .map(|r| {
                    let tgt = (0..replicas).min_by_key(|&i| loads[i]).unwrap();
                    loads[tgt] += dynamic_units(&r.program);
                    tgt
                })
                .collect()
        }
    }
}

/// One (replica, lane) unit's routed request list.
struct Unit<'a> {
    replica: usize,
    class: LaneClass,
    requests: Vec<&'a ServeRequest>,
}

/// What one executed unit hands back for aggregation.
struct UnitResult {
    run: super::LaneRun,
    cache: CacheStats,
}

/// Serve a mixed trace on a replica fleet. `make_engine` is called
/// once per (replica, lane) unit — IN the executing thread — and must
/// produce engines that are bit-reproducible functions of their
/// construction arguments (true of [`super::SimLaneEngine`]: service
/// times derive from hardware + seed, not from wall clock or address).
///
/// The result is bit-identical for every `workers` value — the fleet
/// determinism contract (see the module docs and
/// `tests/fleet_oracle.rs`).
pub fn serve_fleet<E: LaneEngine, F: Fn() -> E + Sync>(
    make_engine: F,
    selector: &Selector,
    cfg: &FleetConfig,
    requests: &[ServeRequest],
) -> FleetStats {
    assert!(cfg.replicas >= 1, "a fleet has at least one replica");
    debug_assert!(requests.windows(2).all(|w| w[0].arrive <= w[1].arrive));

    // Compile-time half, fleet edition: ONE table resolution (adopted
    // payloads audited once), then shared read-only across every
    // replica through an `Arc` — the table is immutable after its
    // build, so replicas alias one cell lattice instead of cloning it.
    let (dispatch, table_diags) = resolve_dispatch(selector, &cfg.serve);
    let dispatch_build = dispatch.as_ref().map(|t| t.stats.clone());
    let dispatch: Option<Arc<DispatchTable>> = dispatch.map(Arc::new);
    // Static SLO feasibility check: deadlines below the modeled
    // service floor or unservable downgrade modes are reported before
    // a single request is served.
    let slo_diags = crate::analysis::audit_slo(selector, &cfg.serve).diagnostics;

    // Sequential routing pre-pass: placement is fixed before any unit
    // executes. Per-replica lists stay arrival-sorted because the
    // input is.
    let assignment = route(cfg.routing, cfg.replicas, requests);
    let mut units: Vec<Unit> = Vec::new();
    for replica in 0..cfg.replicas {
        for class in LaneClass::ALL {
            let routed: Vec<&ServeRequest> = requests
                .iter()
                .zip(&assignment)
                .filter(|&(r, &a)| a == replica && LaneClass::of(&r.program) == class)
                .map(|(r, _)| r)
                .collect();
            if !routed.is_empty() {
                units.push(Unit { replica, class, requests: routed });
            }
        }
    }

    // Priority seeding: higher-priority lanes enter the work queues
    // first. A latency hint only — unit results are scattered by unit
    // index, so outcomes are invariant to this order (and the oracle
    // test would catch it if they were not).
    let mut seed_order: Vec<usize> = (0..units.len()).collect();
    seed_order
        .sort_by_key(|&u| (Reverse(cfg.serve.lane(units[u].class).slo.priority), u));

    let (results, worker_stats): (Vec<UnitResult>, Vec<WorkerStats>) =
        execute_units(cfg.workers, &seed_order, |u| {
            let unit = &units[u];
            let mut engine = make_engine();
            let mut cache =
                cfg.serve.plan_cache.map(|cap| PlanCache::for_selector(selector, cap));
            // The decode lane runs its continuous-batching loop; every
            // other lane runs the arrival-batched loop. Both see the
            // same shared table through the `Arc`.
            let run = if unit.class == LaneClass::Decode {
                serve_decode_lane(
                    &mut engine,
                    selector,
                    cfg.serve.lane(unit.class),
                    unit.replica,
                    &unit.requests,
                    dispatch.as_deref(),
                    cache.as_mut(),
                    cfg.serve.trace,
                )
            } else {
                serve_lane(
                    &mut engine,
                    selector,
                    cfg.serve.lane(unit.class),
                    unit.class,
                    unit.replica,
                    &unit.requests,
                    dispatch.as_deref(),
                    cache.as_mut(),
                    cfg.serve.trace,
                )
            };
            UnitResult { run, cache: cache.map(|c| c.stats).unwrap_or_default() }
        });

    // Aggregation in fixed (replica, lane) order — `units` was built
    // replica-major, lane-minor, and `results` is unit-indexed.
    let mut stats = FleetStats {
        replicas: (0..cfg.replicas)
            .map(|_| MixedStats {
                dispatch_build: dispatch_build.clone(),
                ..MixedStats::default()
            })
            .collect(),
        dispatch_build,
        table_diags,
        slo_diags,
        worker_stats,
        ..FleetStats::default()
    };
    // Trace assembly follows the same fixed unit order as every other
    // aggregate, so the trace is identical across worker counts too
    // (modulo the measured `select_wall_us` args it carries as data).
    let mut trace = cfg.serve.trace.then(|| Trace {
        processes: (0..cfg.replicas)
            .map(|r| (r as u64, format!("replica {r}")))
            .collect(),
        meta: vec![
            ("routing".to_string(), Json::str(cfg.routing.name())),
            ("replicas".to_string(), Json::num(cfg.replicas as f64)),
        ],
        ..Trace::default()
    });
    for (unit, result) in units.iter().zip(results) {
        let rep = &mut stats.replicas[unit.replica];
        rep.span_secs = rep.span_secs.max(result.run.stats.metrics.span_secs);
        rep.outcomes.extend(result.run.outcomes);
        rep.drops.extend(result.run.drops);
        rep.lanes.push(result.run.stats);
        rep.cache.absorb(&result.cache);
        if let Some(t) = trace.as_mut() {
            t.threads.push((
                unit.replica as u64,
                unit.class.index() as u64,
                unit.class.name().to_string(),
            ));
            t.spans.extend(result.run.trace);
        }
    }
    for rep in &mut stats.replicas {
        rep.outcomes.sort_by_key(|o| o.id);
        rep.drops.sort_by_key(|d| d.id);
        for o in &rep.outcomes {
            match o.source {
                PlanSource::Table => rep.dispatch.table += 1,
                PlanSource::Cache => rep.dispatch.cache += 1,
                PlanSource::Fresh => rep.dispatch.fresh += 1,
            }
        }
        stats.span_secs = stats.span_secs.max(rep.span_secs);
        stats.outcomes.extend(rep.outcomes.iter().cloned());
        stats.drops.extend(rep.drops.iter().cloned());
        stats.dispatch.table += rep.dispatch.table;
        stats.dispatch.cache += rep.dispatch.cache;
        stats.dispatch.fresh += rep.dispatch.fresh;
        stats.cache.absorb(&rep.cache);
    }
    stats.trace = trace;
    stats.outcomes.sort_by_key(|o| o.id);
    stats.drops.sort_by_key(|d| d.id);
    stats
}

#[cfg(test)]
mod tests {
    use super::super::scenario::{demo_selector, mixed_trace, serving_config};
    use super::super::{serve_mixed_trace, SimLaneEngine};
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::sim::Simulator;

    fn engine() -> SimLaneEngine {
        SimLaneEngine { sim: Simulator::new(presets::a100(), 11) }
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let trace = mixed_trace(120, 4e-4, 3, DType::F32);
        for policy in [RoutePolicy::HashKey, RoutePolicy::LeastLoaded] {
            let a = route(policy, 4, &trace);
            let b = route(policy, 4, &trace);
            assert_eq!(a, b);
            assert_eq!(a.len(), trace.len());
            assert!(a.iter().all(|&r| r < 4));
        }
        // One replica: everything lands on it under either policy.
        assert!(route(RoutePolicy::HashKey, 1, &trace).iter().all(|&r| r == 0));
    }

    #[test]
    fn hash_routing_keeps_merge_families_together() {
        let trace = mixed_trace(120, 4e-4, 3, DType::F32);
        let assignment = route(RoutePolicy::HashKey, 4, &trace);
        let mut family: std::collections::HashMap<String, usize> =
            std::collections::HashMap::new();
        for (r, &a) in trace.iter().zip(&assignment) {
            let key = merge_key(&r.program).id();
            let prev = family.entry(key).or_insert(a);
            assert_eq!(*prev, a, "merge family split across replicas");
        }
    }

    #[test]
    fn least_loaded_touches_every_replica() {
        let trace = mixed_trace(160, 4e-4, 5, DType::F32);
        let assignment = route(RoutePolicy::LeastLoaded, 4, &trace);
        let mut seen = vec![false; 4];
        for &a in &assignment {
            seen[a] = true;
        }
        assert!(seen.iter().all(|&s| s), "idle replica under least-loaded");
    }

    #[test]
    fn one_replica_fleet_matches_the_single_threaded_path() {
        // A 1-replica, 0-worker fleet is the serve_mixed_trace loop
        // with per-lane cache shards instead of one shared cache; at
        // the default capacity nothing evicts and lane buckets are
        // disjoint (the key includes the op), so every per-request
        // number is bit-identical.
        let selector = demo_selector(5);
        let cfg = FleetConfig { serve: serving_config(), ..FleetConfig::default() };
        let trace = mixed_trace(160, 4e-4, 7, DType::F32);
        let fleet = serve_fleet(engine, &selector, &cfg, &trace);
        let single = serve_mixed_trace(&mut engine(), &selector, &cfg.serve, &trace);
        assert_eq!(fleet.count(), single.count());
        for (f, s) in fleet.outcomes.iter().zip(&single.outcomes) {
            assert_eq!(f.id, s.id);
            assert_eq!(f.latency.to_bits(), s.latency.to_bits());
            assert_eq!(f.batch_size, s.batch_size);
            assert_eq!(f.source, s.source);
            assert!(f.selection.same_plan(&s.selection));
        }
        assert_eq!(fleet.cache.hits, single.cache.hits);
        assert_eq!(fleet.cache.misses, single.cache.misses);
    }

    #[test]
    fn arc_shared_table_fleet_matches_the_sequential_oracle() {
        // One audited dispatch table, aliased by every replica through
        // the `Arc` — sharing must be outcome-invisible: a fleet with
        // dispatch enabled (decode traffic included, so the
        // continuous-batching lane reads the shared table too) replays
        // bit-identically between the sequential oracle (workers 0)
        // and a real worker pool.
        use super::super::scenario::{decode_trace, dispatch_config};
        let selector = demo_selector(5);
        let mut trace = mixed_trace(120, 4e-4, 7, DType::F32);
        for mut r in decode_trace(40, 6e-4, 24, 9, DType::F32) {
            r.id += 1000;
            trace.push(r);
        }
        trace.sort_by(|a, b| a.arrive.partial_cmp(&b.arrive).unwrap());
        let serve = serving_config().with_dispatch(dispatch_config());
        for replicas in [1usize, 3] {
            let base = FleetConfig { replicas, serve: serve.clone(), ..FleetConfig::default() };
            let oracle = serve_fleet(engine, &selector, &base, &trace);
            let pooled = serve_fleet(
                engine,
                &selector,
                &FleetConfig { workers: 3, ..base.clone() },
                &trace,
            );
            assert_eq!(oracle.count(), pooled.count());
            for (a, b) in oracle.outcomes.iter().zip(&pooled.outcomes) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.latency.to_bits(), b.latency.to_bits());
                assert_eq!(a.source, b.source);
                assert!(a.selection.same_plan(&b.selection));
            }
            // The shared table actually answered: decode traffic is
            // in-horizon by construction, so every decode outcome
            // dispatched from the table on every replica.
            let decodes: Vec<_> = oracle
                .outcomes
                .iter()
                .filter(|o| o.lane == LaneClass::Decode)
                .collect();
            assert!(!decodes.is_empty());
            assert!(decodes.iter().all(|o| o.source == PlanSource::Table));
            assert!(oracle.dispatch.table > 0);
        }
    }

    #[test]
    fn sharding_preserves_every_request_exactly_once() {
        let selector = demo_selector(5);
        let trace = mixed_trace(160, 4e-4, 9, DType::F32);
        for replicas in [2usize, 4] {
            let cfg = FleetConfig {
                replicas,
                routing: RoutePolicy::LeastLoaded,
                serve: serving_config(),
                ..FleetConfig::default()
            };
            let fleet = serve_fleet(engine, &selector, &cfg, &trace);
            assert_eq!(fleet.offered(), trace.len());
            let ids: Vec<u64> = fleet.outcomes.iter().map(|o| o.id).collect();
            assert_eq!(ids, (0..trace.len() as u64).collect::<Vec<_>>());
            assert_eq!(fleet.replicas.len(), replicas);
            // Per-replica stats partition the fleet totals.
            let sum: usize = fleet.replicas.iter().map(|r| r.count()).sum();
            assert_eq!(sum, fleet.count());
            // Outcomes carry the replica the routing pre-pass chose.
            let assignment = route(cfg.routing, replicas, &trace);
            for o in &fleet.outcomes {
                assert_eq!(o.replica, assignment[o.id as usize]);
            }
        }
    }
}
