//! Bucketed plan cache: O(1) amortized shape→kernel dispatch.
//!
//! The paper's L1-tile padding math is what makes memoization sound:
//! the selector's fast path evaluates every candidate kernel on the
//! PADDED problem — `grid = ceil(dim / l1)` per axis, `padded = grid ·
//! l1` — so two runtime spaces that produce the same launch grid under
//! EVERY candidate L1 tile are indistinguishable to selection: same
//! padded problem, same traffic terms, same launch count, same argmin.
//! Padding therefore quantizes the unbounded dynamic-shape stream into
//! a small set of buckets, and per-request selection collapses into a
//! hash lookup after the first request of each bucket.
//!
//! The bucket key is derived from the selector itself: per serving op
//! (the measurement-alias FIXPOINT the selector would scan), per axis,
//! the distinct L1 extents of the loaded kernels; a space's bucket
//! coordinate is the tuple of `ceil(dim / extent)` over those extents.
//! Equal coordinates ⟹ equal per-kernel grids ⟹ the cached
//! [`Selection`] is IDENTICAL to fresh selection (library index,
//! kernel index, padded shape, grid and estimate — everything except
//! the wall-clock `select_secs`, which a hit replaces with the lookup
//! time). That guarantee is enforced by a property test below.
//!
//! Coherence: a `PlanCache` is constructed FOR one selector
//! ([`PlanCache::for_selector`]) — the bucket tables and memoized
//! plans are derived from that selector's libraries. Reloading or
//! swapping libraries requires building a fresh cache; there is no
//! partial-invalidation path by design (the rebuild is microseconds).
//!
//! Since the offline shape-space partitioner landed
//! ([`crate::dispatch`]), this cache is the BEYOND-HORIZON fallback:
//! in-horizon shapes are answered by the compile-time
//! [`crate::dispatch::DispatchTable`] with no warm-up at all, and only
//! the tail past the configured horizon still flows through the
//! reactive memoization here (tri-state accounting in
//! [`crate::serve::DispatchStats`]). The bucket-key insight is the
//! same in both: selection is a function of the per-axis
//! `ceil(dim/extent)` grid coordinates only — the table enumerates
//! that function offline, the cache memoizes it online.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::ir::{ceil_div, DType, IterSpace, OpKind};

/// Hit / miss / eviction counters of one [`PlanCache`].
#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Fold another shard's counters into this one (fleet aggregation
    /// over per-unit cache shards).
    pub fn absorb(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
    }
}

/// One padded-tile bucket: everything selection can observe about a
/// runtime space. `grids` is the per-axis launch-grid tuple under
/// every distinct L1 extent of the serving op's kernels — equal
/// `grids` means every candidate sees the same padded problem.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct BucketKey {
    op: OpKind,
    dtype: DType,
    mode: HwMode,
    grids: Vec<usize>,
}

#[derive(Debug, Clone)]
struct Entry {
    sel: Selection,
    tick: u64,
}

/// Memoized `Selection`s keyed by (op, dtype, mode, padded-tile
/// bucket), with LRU eviction and hit/miss/eviction stats.
#[derive(Debug)]
pub struct PlanCache {
    capacity: usize,
    map: HashMap<BucketKey, Entry>,
    /// Recency index: tick → bucket, exactly one entry per live bucket
    /// (ticks are unique and monotonic). Keeps eviction O(log n)
    /// instead of a full map scan when the live bucket set thrashes
    /// past `capacity`.
    lru: BTreeMap<u64, BucketKey>,
    /// serving op → per-axis sorted distinct L1 extents of its kernels.
    extents: HashMap<OpKind, Vec<Vec<usize>>>,
    tick: u64,
    pub stats: CacheStats,
}

impl PlanCache {
    /// Build a cache for one selector: precompute the per-axis distinct
    /// L1 extents of every loaded op's kernel set (the quantization
    /// grid the bucket key is computed against).
    pub fn for_selector(selector: &Selector, capacity: usize) -> PlanCache {
        let mut extents: HashMap<OpKind, Vec<Vec<usize>>> = HashMap::new();
        for lib in &selector.libraries {
            let per_axis = extents
                .entry(lib.op)
                .or_insert_with(|| vec![Vec::new(); lib.op.spec().rank()]);
            for k in &lib.kernels {
                for (a, ex) in per_axis.iter_mut().enumerate() {
                    if !ex.contains(&k.l1[a]) {
                        ex.push(k.l1[a]);
                    }
                }
            }
        }
        for per_axis in extents.values_mut() {
            for ex in per_axis.iter_mut() {
                ex.sort_unstable();
            }
        }
        PlanCache {
            capacity: capacity.max(1),
            map: HashMap::new(),
            lru: BTreeMap::new(),
            extents,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// The bucket a space falls into, or `None` when the serving op has
    /// no loaded kernels (fresh selection returns `None` there too).
    fn key(&self, selector: &Selector, space: IterSpace, mode: HwMode) -> Option<BucketKey> {
        let serving = selector.serving_op(space.op);
        let per_axis = self.extents.get(&serving)?;
        // Alias-chain invariant: the serving op preserves rank, so the
        // extent table lines up with the space's axes.
        debug_assert_eq!(per_axis.len(), space.dims.rank());
        let mut grids = Vec::with_capacity(per_axis.iter().map(Vec::len).sum());
        for (&d, ex) in space.dims.dims().iter().zip(per_axis) {
            for &t in ex {
                grids.push(ceil_div(d, t));
            }
        }
        Some(BucketKey { op: space.op, dtype: space.dtype, mode, grids })
    }

    /// Cached dispatch: identical to `selector.select(space, mode)` in
    /// every field except `select_secs` (a hit reports the lookup
    /// wall-clock instead of the full scan).
    pub fn select(
        &mut self,
        selector: &Selector,
        space: IterSpace,
        mode: HwMode,
    ) -> Option<Selection> {
        let t0 = Instant::now();
        let key = match self.key(selector, space, mode) {
            Some(k) => k,
            // No kernels for the serving op: pass through (None).
            None => return selector.select(space, mode),
        };
        self.tick += 1;
        if let Some(e) = self.map.get_mut(&key) {
            let stale = e.tick;
            e.tick = self.tick;
            self.stats.hits += 1;
            let mut sel = e.sel.clone();
            sel.select_secs = t0.elapsed().as_secs_f64();
            let bucket = self.lru.remove(&stale).expect("lru index out of sync");
            self.lru.insert(self.tick, bucket);
            return Some(sel);
        }
        let sel = selector.select(space, mode)?;
        self.stats.misses += 1;
        if self.map.len() >= self.capacity {
            if let Some((_, oldest)) = self.lru.pop_first() {
                self.map.remove(&oldest);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(key.clone(), Entry { sel: sel.clone(), tick: self.tick });
        self.lru.insert(self.tick, key);
        debug_assert_eq!(self.lru.len(), self.map.len());
        Some(sel)
    }

    /// Number of live buckets.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::Tile;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;
    use crate::util::prop::{forall, prop_assert};

    fn selector() -> Selector {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 5));
        let libs = vec![
            compile(&hw, OpKind::Gemm, DType::F32, &cfg, &mut prof, &CompileOpts::default())
                .library,
            compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut prof, &CompileOpts::default())
                .library,
            compile(
                &hw,
                OpKind::BatchedGemm,
                DType::F16,
                &cfg,
                &mut prof,
                &CompileOpts::default(),
            )
            .library,
        ];
        Selector::new(hw, libs)
    }

    // Plan identity is `Selection::same_plan` — the single definition
    // of "identical in every field except select_secs".
    fn same_plan(a: &Selection, b: &Selection) -> bool {
        a.same_plan(b)
    }

    #[test]
    fn repeat_lookup_hits_and_matches_fresh() {
        let s = selector();
        let mut cache = PlanCache::for_selector(&s, 64);
        let space = IterSpace::gemm(77, 2304, 768, DType::F16);
        let fresh = s.select(space, HwMode::Adaptive).unwrap();
        let miss = cache.select(&s, space, HwMode::Adaptive).unwrap();
        let hit = cache.select(&s, space, HwMode::Adaptive).unwrap();
        assert!(same_plan(&fresh, &miss));
        assert!(same_plan(&fresh, &hit));
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn nearby_shapes_share_a_padding_bucket() {
        // Two shapes with equal launch grids under every L1 extent are
        // ONE bucket: the second lookup is a hit even though the dims
        // differ. The smallest M extent defines the finest granularity,
        // so m and m+… within the same ceil-div cell coalesce.
        let s = selector();
        let mut cache = PlanCache::for_selector(&s, 64);
        let m_extents: Vec<usize> = {
            let mut v = Vec::new();
            for lib in s.libraries.iter().filter(|l| l.op == OpKind::Gemm) {
                for k in &lib.kernels {
                    if !v.contains(&k.l1[0]) {
                        v.push(k.l1[0]);
                    }
                }
            }
            v.sort_unstable();
            v
        };
        let g = m_extents[0]; // finest quantum on the M axis
        let lcm: usize = m_extents.iter().fold(1, |l, &e| l * e / gcd(l, e));
        // m = lcm and m = lcm - g + 1 round up identically under every
        // extent (both land in the top cell of each extent's grid).
        let a = IterSpace::gemm(lcm, 768, 768, DType::F16);
        let b = IterSpace::gemm(lcm - g + 1, 768, 768, DType::F16);
        let _ = cache.select(&s, a, HwMode::Adaptive).unwrap();
        let hit = cache.select(&s, b, HwMode::Adaptive).unwrap();
        assert_eq!(cache.stats.hits, 1, "padding bucket did not coalesce");
        let fresh = s.select(b, HwMode::Adaptive).unwrap();
        assert!(same_plan(&fresh, &hit));
    }

    fn gcd(a: usize, b: usize) -> usize {
        if b == 0 {
            a
        } else {
            gcd(b, a % b)
        }
    }

    #[test]
    fn eviction_respects_capacity() {
        let s = selector();
        let mut cache = PlanCache::for_selector(&s, 4);
        for m in 1..=64usize {
            let space = IterSpace::gemm(m * 128, 768, 768, DType::F16);
            let _ = cache.select(&s, space, HwMode::Adaptive);
        }
        assert!(cache.len() <= 4);
        assert!(cache.stats.evictions > 0);
        // An evicted bucket re-misses but still matches fresh selection.
        let space = IterSpace::gemm(128, 768, 768, DType::F16);
        let again = cache.select(&s, space, HwMode::Adaptive).unwrap();
        let fresh = s.select(space, HwMode::Adaptive).unwrap();
        assert!(same_plan(&fresh, &again));
    }

    #[test]
    fn unservable_space_passes_through_as_none() {
        let s = selector();
        let mut cache = PlanCache::for_selector(&s, 16);
        // Conv2d aliases to Gemm (served); a conv space works...
        let conv = IterSpace {
            op: OpKind::Conv2d,
            dims: Tile::from3([1352, 128, 576]),
            dtype: DType::F16,
        };
        assert!(cache.select(&s, conv, HwMode::Adaptive).is_some());
        // ...while a mode with no matching backend kernels yields None
        // from both the cache and fresh selection.
        let none = cache.select(&s, conv, HwMode::Only("no_such_backend"));
        assert!(none.is_none());
        assert!(s.select(conv, HwMode::Only("no_such_backend")).is_none());
    }

    #[test]
    fn prop_cached_dispatch_equals_fresh_selection() {
        // Satellite: across random shapes, ops and modes, the cached
        // plan is bit-identical to fresh selection (everything except
        // the wall-clock select_secs) — on misses AND on hits.
        let s = selector();
        let mut cache = PlanCache::for_selector(&s, 256);
        let ops = [
            OpKind::Gemm,
            OpKind::Conv2d,
            OpKind::BatchedGemm,
            OpKind::GroupedConv2d,
            OpKind::FusedAttention,
        ];
        let modes = [
            HwMode::Adaptive,
            HwMode::Only("cuda_core_f32"),
            HwMode::Only("tensor_core_f16"),
        ];
        // Some (op, mode) combos are legitimately unservable (e.g. a
        // batched space under a mode whose only backend the batched
        // library lacks) — both paths must agree on None there too.
        let mut servable = 0usize;
        forall(
            "plan-cache-equals-fresh",
            120,
            0xCAC4E,
            |r, size| {
                let op = ops[r.usize(0, ops.len() - 1)];
                let rank = op.spec().rank();
                let mut dims = vec![0usize; rank];
                // leading batch axes stay small, contraction axes wide
                for (i, d) in dims.iter_mut().enumerate() {
                    *d = if rank == 4 && i == 0 {
                        r.usize(1, 48)
                    } else {
                        r.usize(1, 1 + 48 * size)
                    };
                }
                let dtype = if r.usize(0, 1) == 0 { DType::F16 } else { DType::F32 };
                let mode = modes[r.usize(0, modes.len() - 1)];
                (op, dims, dtype, mode)
            },
            |(op, dims, dtype, mode)| {
                let space = IterSpace { op: *op, dims: Tile::new(dims), dtype: *dtype };
                let fresh = s.select(space, *mode);
                // First pass (miss or hit, depending on earlier cases).
                let c1 = cache.select(&s, space, *mode);
                // Second pass is a guaranteed hit when servable.
                let c2 = cache.select(&s, space, *mode);
                match (&fresh, &c1, &c2) {
                    (None, None, None) => Ok(()),
                    (Some(f), Some(a), Some(b)) => {
                        servable += 1;
                        prop_assert(
                            same_plan(f, a) && same_plan(f, b),
                            format!("cached plan diverged for {:?}: {:?} vs {:?}", space, f, a),
                        )
                    }
                    _ => Err(format!("cache servability diverged for {:?}", space)),
                }
            },
        );
        assert!(servable > 0, "property exercised no servable case");
        assert!(
            cache.stats.hits >= servable as u64,
            "every servable case's second pass must hit: {} hits / {} servable",
            cache.stats.hits,
            servable
        );
    }
}
