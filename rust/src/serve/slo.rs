//! Per-lane latency SLOs: deadline-derived batching windows, launch
//! cutoffs and the overload policy (shed vs. mode-downgrade).
//!
//! A serving deployment does not batch for throughput alone — every
//! lane carries a latency objective, and the batcher must spend the
//! deadline budget deliberately. The split here is fixed fractions of
//! the deadline (capacity planning, not feedback control — the same
//! sample-free posture as the dispatch tables):
//!
//! * at most [`BATCH_BUDGET_FRACTION`] of the deadline is spent
//!   *waiting* for peers to merge (the effective batching window is
//!   `batch_window.min(deadline × BATCH_BUDGET_FRACTION)` — the fix
//!   for the old hardcoded 2 ms window that ignored SLOs entirely);
//! * the batch *launches* no later than
//!   `arrive + deadline × LAUNCH_BUDGET_FRACTION`, reserving the rest
//!   of the budget for the modeled scheduling overhead + service time;
//! * a head request whose deadline is already unmeetable when the
//!   server frees up (`open > arrive + deadline`) triggers the
//!   [`OverloadPolicy`]: keep serving (the default — the legacy
//!   behavior, bit-for-bit), shed it (a [`DropRecord`], no clock
//!   charge — shedding is control-plane), or serve it immediately in a
//!   degraded backend mode (mode-downgrade: the batch closes at once
//!   and selection runs under the downgrade [`HwMode`]).
//!
//! Every decision is a function of the event clock and the
//! configuration only, so SLO-aware serving replays bit-identically —
//! the fleet executor's determinism oracle ([`crate::serve::fleet`])
//! covers drop and degrade decisions too. Feasibility of a deadline
//! against the modeled service floor is checked statically by
//! [`crate::analysis::audit_slo`].

use crate::coordinator::select::HwMode;

use super::LaneClass;

/// Fraction of the deadline the batcher may spend WAITING for
/// merge-compatible peers after the head request arrives.
pub const BATCH_BUDGET_FRACTION: f64 = 0.25;

/// Fraction of the deadline by which the batch must have LAUNCHED,
/// reserving the remainder for scheduling overhead + service.
pub const LAUNCH_BUDGET_FRACTION: f64 = 0.5;

/// What a lane does with a head request whose deadline is already
/// unmeetable when the server frees up.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverloadPolicy {
    /// Serve regardless (the legacy behavior, and the default): the
    /// SLO is observational only.
    #[default]
    ServeAnyway,
    /// Shed the head request: it is recorded as a [`DropRecord`] and
    /// never executes. Shedding charges nothing to the event clock —
    /// the decision is control-plane, and the freed capacity goes to
    /// the next pending request.
    Drop,
    /// Serve immediately under a downgraded backend mode: the batch
    /// closes at once (no further waiting) and selection runs with
    /// this [`HwMode`] instead of the lane's configured one. Outcomes
    /// are flagged `degraded`.
    Degrade(HwMode),
}

impl OverloadPolicy {
    /// Stable label used in trace span args and telemetry output.
    pub fn name(self) -> &'static str {
        match self {
            OverloadPolicy::ServeAnyway => "serve-anyway",
            OverloadPolicy::Drop => "drop",
            OverloadPolicy::Degrade(_) => "degrade",
        }
    }
}

/// Per-lane latency objective: an optional completion deadline
/// (seconds from request arrival), a scheduling priority (higher
/// priorities seed the fleet executor's work queues first — a
/// scheduling hint only, never an outcome change), and the overload
/// policy. The default is a no-op SLO: no deadline, priority 0,
/// serve-anyway — byte-identical serving to the pre-SLO loop.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LaneSlo {
    /// Completion deadline in seconds from arrival (`None` = no SLO).
    pub deadline: Option<f64>,
    /// Work-queue seeding priority (higher first). Scheduling only:
    /// per-request outcomes are invariant to it by construction.
    pub priority: u8,
    pub policy: OverloadPolicy,
}

impl LaneSlo {
    /// An SLO with the given deadline and default policy/priority.
    pub fn with_deadline(deadline: f64) -> LaneSlo {
        LaneSlo { deadline: Some(deadline), ..LaneSlo::default() }
    }

    pub fn with_policy(mut self, policy: OverloadPolicy) -> LaneSlo {
        self.policy = policy;
        self
    }

    pub fn with_priority(mut self, priority: u8) -> LaneSlo {
        self.priority = priority;
        self
    }

    /// The effective batching window under this SLO: the configured
    /// static window, capped at [`BATCH_BUDGET_FRACTION`] of the
    /// deadline — a tight-SLO lane never waits its deadline away.
    pub fn window(&self, batch_window: f64) -> f64 {
        match self.deadline {
            None => batch_window,
            Some(d) => batch_window.min(d * BATCH_BUDGET_FRACTION),
        }
    }

    /// Latest event-clock instant a batch headed by a request arriving
    /// at `arrive` may still launch (`None` when no deadline is set).
    pub fn launch_cutoff(&self, arrive: f64) -> Option<f64> {
        self.deadline.map(|d| arrive + d * LAUNCH_BUDGET_FRACTION)
    }
}

/// One shed request: the admission controller's drop decision, fully
/// determined by the event clock (replayed bit-identically by the
/// fleet determinism oracle).
#[derive(Debug, Clone)]
pub struct DropRecord {
    pub id: u64,
    pub lane: LaneClass,
    /// Replica whose admission controller shed the request.
    pub replica: usize,
    /// Event-clock instant the decision was taken (the head's
    /// batch-open time).
    pub decided_at: f64,
    /// How far past its deadline the head already was at decision
    /// time (> 0 by construction).
    pub miss_by: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_slo_is_a_no_op() {
        let slo = LaneSlo::default();
        assert_eq!(slo.deadline, None);
        assert_eq!(slo.policy, OverloadPolicy::ServeAnyway);
        assert_eq!(slo.window(2e-3), 2e-3);
        assert_eq!(slo.launch_cutoff(1.0), None);
    }

    #[test]
    fn window_derives_from_the_deadline_budget() {
        // A tight deadline shrinks the effective window below the
        // static configuration; a loose one leaves it alone.
        let tight = LaneSlo::with_deadline(400e-6);
        assert!((tight.window(2e-3) - 100e-6).abs() < 1e-18);
        let loose = LaneSlo::with_deadline(1.0);
        assert_eq!(loose.window(2e-3), 2e-3);
    }

    #[test]
    fn launch_cutoff_reserves_half_the_budget() {
        let slo = LaneSlo::with_deadline(1e-3);
        let cutoff = slo.launch_cutoff(2.0).unwrap();
        assert!((cutoff - (2.0 + 0.5e-3)).abs() < 1e-12);
    }

    #[test]
    fn builders_compose() {
        let slo = LaneSlo::with_deadline(1e-3)
            .with_policy(OverloadPolicy::Drop)
            .with_priority(3);
        assert_eq!(slo.deadline, Some(1e-3));
        assert_eq!(slo.policy, OverloadPolicy::Drop);
        assert_eq!(slo.priority, 3);
    }
}
