//! Production serving subsystem: multi-op request lanes over a shared
//! admission queue, with a bucketed plan cache for O(1) amortized
//! dispatch — the online half of the paper, productionized.
//!
//! The paper's motivation (§2.1) is a serving system whose batch sizes
//! and sequence lengths change per request; the end-to-end framing of
//! SoD² and Relax (PAPERS.md) is the same system serving *many
//! operators* at once. This module generalizes the single-op
//! discrete-event loop of [`crate::coordinator::server`] into:
//!
//! * **Request lanes** ([`LaneClass`]): requests carry full
//!   [`TensorProgram`]s; each op class gets its own lane with its own
//!   [`LaneConfig`] batching policy. A lane merges *compatible*
//!   requests (equal [`merge_key`]) along the op's natural batch axis
//!   — token rows along M for GEMM, the leading batch dim for batched
//!   GEMM and the conv family, and the head-group batch (padding to
//!   the longest sequence) for attention chains.
//! * **Dispatch table** ([`crate::dispatch::DispatchTable`], enabled
//!   via [`ServeConfig::dispatch`]): the offline shape-space partition
//!   answers in-horizon batches at request time with ZERO warm-up —
//!   the shape→kernel decision was enumerated at compile time. Plans
//!   are provably identical to fresh selection.
//! * **Decode lane** ([`LaneClass::Decode`]): autoregressive
//!   causal-attention steps ([`TensorProgram::CausalAttention`]) run a
//!   CONTINUOUS-batching loop (`serve_decode_lane`) instead of the
//!   one-shot batcher — sequences admit and retire mid-flight, the
//!   batch re-forms at every event-clock step, and per-sequence slots
//!   are reused so the steady-state path performs no allocation
//!   ([`Metrics::alloc_events`] counts the amortized pool builds).
//!   With the seq_k axis partitioned at L1-extent multiples over the
//!   decode horizon, every in-horizon step answers from the table:
//!   zero selector scans per token (see the "Decode serving" section
//!   of `docs/ARCHITECTURE.md`).
//! * **Plan cache** ([`PlanCache`]): the beyond-horizon fallback —
//!   per-batch shape→kernel selection is memoized into padded-tile
//!   buckets, so steady-state dispatch is a hash lookup; the cached
//!   plan is guaranteed identical to fresh selection (see
//!   `serve/cache.rs`). Accounting is tri-state per request:
//!   table hit / cache hit / fresh scan ([`DispatchStats`]).
//! * **Scenario + telemetry**: [`scenario`] generates mixed traffic
//!   (BERT-style token streams interleaved with vision bursts);
//!   [`MixedStats`] reports per-lane latency percentiles, scheduling
//!   fraction and cache hit rates. The `serve` bench
//!   (`bench::exp_serve`) emits `BENCH_serve.json`.
//!
//! The old GEMM-only API (`coordinator::server::serve_trace`)
//! delegates to a one-lane instance of [`serve_mixed_trace`].
//!
//! At fleet scale ([`fleet`]), admission shards across N replicas —
//! each owning its own dispatch-table copy and plan-cache shards —
//! under deterministic routing, per-lane latency SLOs ([`slo`]) drive
//! deadline-aware batching and overload shedding/degradation, and an
//! optional `std::thread` worker pool with work-stealing executes the
//! independent (replica, lane) units — proven bit-identical to the
//! single-threaded discrete-event replay (the determinism oracle; see
//! the "Fleet serving" section of `docs/ARCHITECTURE.md`).

pub mod cache;
pub mod fleet;
pub mod scenario;
pub mod slo;

pub use cache::{CacheStats, PlanCache};
pub use fleet::{serve_fleet, FleetConfig, FleetStats, RoutePolicy};
pub use slo::{
    DropRecord, LaneSlo, OverloadPolicy, BATCH_BUDGET_FRACTION, LAUNCH_BUDGET_FRACTION,
};

use crate::analysis::Diagnostic;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::dispatch::{DispatchConfig, DispatchTable, TableData};
use crate::ir::{DType, IterSpace, TensorProgram};
use crate::obs::{Span, Trace};
use crate::sim::Simulator;
use crate::util::json::Json;

/// Where one request's plan came from — the tri-state accounting of
/// the dispatch-table / plan-cache / fresh-selection stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// Answered by the compile-time dispatch table (zero warm-up).
    Table,
    /// Beyond the horizon, answered by a plan-cache hit.
    Cache,
    /// Beyond the horizon, first touch: a full selection scan ran
    /// (the only cold path left).
    Fresh,
}

impl PlanSource {
    /// Stable label used in trace span args and metric labels.
    pub fn name(self) -> &'static str {
        match self {
            PlanSource::Table => "table",
            PlanSource::Cache => "cache",
            PlanSource::Fresh => "fresh",
        }
    }
}

/// Per-request counts by [`PlanSource`]; sums to the request count.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchStats {
    pub table: u64,
    pub cache: u64,
    pub fresh: u64,
}

impl DispatchStats {
    pub fn total(&self) -> u64 {
        self.table + self.cache + self.fresh
    }

    /// Fraction of requests that never paid a fresh selection scan —
    /// 1.0 means no cold misses anywhere in the run.
    pub fn warm_start_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            (self.table + self.cache) as f64 / self.total() as f64
        }
    }

    /// Count one plan resolution by its source.
    pub(crate) fn bump(&mut self, source: PlanSource) {
        match source {
            PlanSource::Table => self.table += 1,
            PlanSource::Cache => self.cache += 1,
            PlanSource::Fresh => self.fresh += 1,
        }
    }
}

/// One serving request: a full tensor program plus its arrival time
/// (seconds from trace start).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub program: TensorProgram,
    pub arrive: f64,
    /// Decode tokens to generate (continuous-batching decode lane
    /// only; `program` describes the FIRST step, and seq_k grows by
    /// one per token). Every other lane serves exactly one batch per
    /// request and ignores this — use [`ServeRequest::once`].
    pub steps: usize,
}

impl ServeRequest {
    /// A one-shot request (`steps == 1`).
    pub fn once(id: u64, program: TensorProgram, arrive: f64) -> ServeRequest {
        ServeRequest { id, program, arrive, steps: 1 }
    }
}

/// Request lane classes: one discrete-event executor per class. The
/// conv family (`Conv2d`, grouped/depthwise included) shares one lane
/// — both merge along the image batch dim. The decode lane runs the
/// continuous-batching loop instead of the one-shot batcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LaneClass {
    Gemm,
    BatchedGemm,
    Conv,
    Attention,
    /// Autoregressive causal-attention decode steps
    /// ([`TensorProgram::CausalAttention`]): continuous batching with
    /// mid-flight admission/retirement, one token per sequence per
    /// event-clock step.
    Decode,
}

impl LaneClass {
    pub const ALL: [LaneClass; 5] = [
        LaneClass::Gemm,
        LaneClass::BatchedGemm,
        LaneClass::Conv,
        LaneClass::Attention,
        LaneClass::Decode,
    ];

    /// The lane a program is admitted to.
    pub fn of(p: &TensorProgram) -> LaneClass {
        match p {
            TensorProgram::Gemm { .. } => LaneClass::Gemm,
            TensorProgram::BatchedGemm { .. } => LaneClass::BatchedGemm,
            TensorProgram::Conv2d { .. } => LaneClass::Conv,
            TensorProgram::Attention { .. } => LaneClass::Attention,
            TensorProgram::CausalAttention { .. } => LaneClass::Decode,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LaneClass::Gemm => "gemm",
            LaneClass::BatchedGemm => "batched_gemm",
            LaneClass::Conv => "conv",
            LaneClass::Attention => "attention",
            LaneClass::Decode => "decode",
        }
    }

    /// Index into [`ServeConfig::lanes`].
    pub fn index(self) -> usize {
        match self {
            LaneClass::Gemm => 0,
            LaneClass::BatchedGemm => 1,
            LaneClass::Conv => 2,
            LaneClass::Attention => 3,
            LaneClass::Decode => 4,
        }
    }

    /// The op kinds admitted to this lane — the inverse of
    /// [`LaneClass::of`], used by the SLO feasibility audit
    /// ([`crate::analysis::audit_slo`]) to bound every op a lane's
    /// deadline must cover.
    pub fn ops(self) -> &'static [crate::ir::OpKind] {
        use crate::ir::OpKind;
        match self {
            LaneClass::Gemm => &[OpKind::Gemm],
            LaneClass::BatchedGemm => &[OpKind::BatchedGemm],
            LaneClass::Conv => &[OpKind::Conv2d, OpKind::GroupedConv2d],
            LaneClass::Attention => &[OpKind::FusedAttention],
            LaneClass::Decode => &[OpKind::CausalAttention],
        }
    }
}

/// Batching policy of one lane (the per-lane half of the old
/// `ServerConfig`).
#[derive(Debug, Clone, Copy)]
pub struct LaneConfig {
    pub max_batch: usize,
    /// Max time the batcher waits after the first queued request —
    /// capped by the lane's deadline budget when an SLO is set
    /// ([`LaneSlo::window`]), so a tight-SLO lane never batches its
    /// deadline away.
    pub batch_window: f64,
    pub mode: HwMode,
    /// Latency objective + overload policy (default: no SLO — the
    /// batching behavior is bit-identical to the pre-SLO loop).
    pub slo: LaneSlo,
}

impl Default for LaneConfig {
    fn default() -> Self {
        LaneConfig {
            max_batch: 8,
            batch_window: 2e-3,
            mode: HwMode::Adaptive,
            slo: LaneSlo::default(),
        }
    }
}

/// What serving does with an ADOPTED schema-v3 table payload
/// ([`ServeConfig::adopt`]) before trusting it with every plan
/// decision. In-process builds ([`ServeConfig::dispatch`]) are exempt:
/// they are constructed by the same arithmetic the auditor re-proves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TablePolicy {
    /// Run the plan auditor over the payload and REFUSE it (falling
    /// back to an in-process build, or no table) unless the audit is
    /// clean — the production default: a shipped file is input, not
    /// truth.
    #[default]
    RefuseUnaudited,
    /// Audit, record the findings in [`MixedStats::table_diags`], but
    /// serve from the payload anyway (staging/debug).
    WarnUnaudited,
    /// Adopt without auditing (the pre-audit behavior; the strict
    /// loader's fingerprint/digest checks still apply).
    Trust,
}

/// Full serving configuration: one [`LaneConfig`] per lane class plus
/// the plan-cache capacity (`None` disables caching — every batch
/// runs fresh selection, the baseline the `serve` bench compares
/// against).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub lanes: [LaneConfig; 5],
    pub plan_cache: Option<usize>,
    /// Offline shape-space partitioning: when set, a
    /// [`DispatchTable`] is built for the selector BEFORE the trace
    /// starts (the compile-time half) and consulted first for every
    /// batch; the plan cache only sees the beyond-horizon tail.
    pub dispatch: Option<DispatchConfig>,
    /// A shipped schema-v3 table payload (the `"dispatch"` field of a
    /// library dump) to adopt INSTEAD of building in process —
    /// subject to [`ServeConfig::table_policy`].
    pub adopt: Option<Vec<TableData>>,
    /// Gate on adopted payloads (see [`TablePolicy`]).
    pub table_policy: TablePolicy,
    /// Record structured spans ([`crate::obs`]) into
    /// [`MixedStats::trace`] / [`FleetStats::trace`]. Spans are
    /// stamped from the event clock with values the loop already
    /// computed, so enabling this is ZERO-perturbation: every outcome
    /// is bit-identical to an untraced run (the fleet oracle proves
    /// it; see `tests/fleet_oracle.rs`).
    pub trace: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            lanes: [LaneConfig::default(); 5],
            plan_cache: Some(1024),
            dispatch: None,
            adopt: None,
            table_policy: TablePolicy::default(),
            trace: false,
        }
    }
}

impl ServeConfig {
    pub fn lane(&self, class: LaneClass) -> &LaneConfig {
        &self.lanes[class.index()]
    }

    pub fn lane_mut(&mut self, class: LaneClass) -> &mut LaneConfig {
        &mut self.lanes[class.index()]
    }

    /// The cache-disabled twin of this config (baseline runs).
    pub fn without_cache(&self) -> ServeConfig {
        ServeConfig { plan_cache: None, ..self.clone() }
    }

    /// This config with compile-time dispatch tables enabled.
    pub fn with_dispatch(&self, cfg: DispatchConfig) -> ServeConfig {
        ServeConfig { dispatch: Some(cfg), ..self.clone() }
    }

    /// This config adopting a shipped table payload under `policy`.
    pub fn adopting(&self, payload: Vec<TableData>, policy: TablePolicy) -> ServeConfig {
        ServeConfig { adopt: Some(payload), table_policy: policy, ..self.clone() }
    }

    /// This config with span tracing enabled (zero-perturbation; see
    /// [`ServeConfig::trace`]).
    pub fn traced(&self) -> ServeConfig {
        ServeConfig { trace: true, ..self.clone() }
    }
}

/// Resolve the serving-time dispatch table: adopted payload (gated by
/// [`TablePolicy`]) first, then an in-process build. Every refusal or
/// warning is returned as auditor diagnostics so telemetry shows WHY a
/// payload was not (or reluctantly was) trusted.
pub(crate) fn resolve_dispatch(
    selector: &Selector,
    cfg: &ServeConfig,
) -> (Option<DispatchTable>, Vec<Diagnostic>) {
    let mut diags = Vec::new();
    if let Some(payload) = &cfg.adopt {
        match DispatchTable::from_data_checked(selector, payload) {
            Err(d) => diags.push(d),
            Ok(table) => {
                if cfg.table_policy == TablePolicy::Trust {
                    return (Some(table), diags);
                }
                let report = crate::analysis::audit_dispatch_table(selector, &table);
                if report.diagnostics.is_empty() {
                    return (Some(table), diags);
                }
                diags.extend(report.diagnostics);
                if cfg.table_policy == TablePolicy::WarnUnaudited {
                    return (Some(table), diags);
                }
                // RefuseUnaudited: fall through to the in-process
                // build (or no table at all).
            }
        }
    }
    let built = cfg.dispatch.as_ref().map(|d| DispatchTable::for_selector(selector, d));
    (built, diags)
}

/// Two requests batch together iff their merge keys are equal: the key
/// is the program with its merge axis zeroed (token rows M for GEMM,
/// the batch dim for batched GEMM and conv, batch AND seq for
/// attention — attention batches pad shorter sequences to the longest,
/// so any two chains with equal (d, heads, dtype) are compatible).
pub fn merge_key(p: &TensorProgram) -> TensorProgram {
    let mut key = p.clone();
    match &mut key {
        TensorProgram::Gemm { m, .. } => *m = 0,
        TensorProgram::BatchedGemm { b, .. } => *b = 0,
        TensorProgram::Conv2d { n, .. } => *n = 0,
        TensorProgram::Attention { batch, seq, .. } => {
            *batch = 0;
            *seq = 0;
        }
        // Decode steps merge across sequences at different KV-cache
        // depths (padding to the deepest) but NOT across seq_q: a
        // one-token decode step never merges with a prefill chunk.
        TensorProgram::CausalAttention { batch, seq_k, .. } => {
            *batch = 0;
            *seq_k = 0;
        }
    }
    key
}

/// Merge a batch of key-compatible programs into the one program the
/// lane executes: sum the merge axis; attention pads to the longest
/// sequence in the batch.
fn merge_programs(programs: &[&TensorProgram]) -> TensorProgram {
    let mut merged = programs[0].clone();
    for &p in &programs[1..] {
        match (&mut merged, p) {
            (TensorProgram::Gemm { m, .. }, TensorProgram::Gemm { m: m2, .. }) => *m += m2,
            (
                TensorProgram::BatchedGemm { b, .. },
                TensorProgram::BatchedGemm { b: b2, .. },
            ) => *b += b2,
            (TensorProgram::Conv2d { n, .. }, TensorProgram::Conv2d { n: n2, .. }) => {
                *n += n2
            }
            (
                TensorProgram::Attention { batch, seq, .. },
                TensorProgram::Attention { batch: b2, seq: s2, .. },
            ) => {
                *batch += b2;
                *seq = (*seq).max(*s2);
            }
            (
                TensorProgram::CausalAttention { batch, seq_k, .. },
                TensorProgram::CausalAttention { batch: b2, seq_k: k2, .. },
            ) => {
                *batch += b2;
                *seq_k = (*seq_k).max(*k2);
            }
            _ => unreachable!("merge across incompatible programs"),
        }
    }
    merged
}

/// The merged dynamic-axis extent (token rows / batch elements) a
/// program contributes — the lane-throughput unit, and the load
/// measure the fleet's least-loaded routing pre-pass accumulates.
pub(crate) fn dynamic_units(p: &TensorProgram) -> usize {
    match *p {
        TensorProgram::Gemm { m, .. } => m,
        TensorProgram::BatchedGemm { b, .. } => b,
        TensorProgram::Conv2d { n, .. } => n,
        TensorProgram::Attention { batch, .. } => batch,
        TensorProgram::CausalAttention { batch, .. } => batch,
    }
}

/// Execution backend of the serving loop, operator-generic.
pub trait LaneEngine {
    /// Run the selected kernel on the merged space; return the service
    /// time in seconds.
    fn execute(&mut self, space: IterSpace, sel: &Selection, selector: &Selector) -> f64;
    fn name(&self) -> &'static str;
}

/// Simulator-backed engine. A space served through a measurement-alias
/// library dispatches one alias block strategy per constituent kernel
/// (mirrors `bench::harness::Engine::time_space`).
pub struct SimLaneEngine {
    pub sim: Simulator,
}

impl LaneEngine for SimLaneEngine {
    fn execute(&mut self, space: IterSpace, sel: &Selection, selector: &Selector) -> f64 {
        let lib = &selector.libraries[sel.lib];
        let mult = if lib.op == space.op {
            1.0
        } else {
            space.op.spec().chain_kernels() as f64
        };
        self.sim.execute(lib.dtype, &selector.chain(sel)) * mult
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

/// Per-request serving record (one per admitted request).
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u64,
    pub lane: LaneClass,
    /// Replica that served the request (0 outside the fleet).
    pub replica: usize,
    /// Event-clock latency (queueing + modeled scheduling + service) —
    /// deterministic under replay; see [`SCHED_OVERHEAD_SECS`].
    pub latency: f64,
    /// Event-clock instant the request's batch launched — the number
    /// the SLO regression tests pin (a tight-SLO lane never launches
    /// past its deadline budget).
    pub launch: f64,
    pub batch_size: usize,
    /// Where the batch's plan came from (table / cache / fresh).
    pub source: PlanSource,
    /// True when the batch was served under the overload policy's
    /// downgraded backend mode ([`OverloadPolicy::Degrade`]).
    pub degraded: bool,
    /// The constructed plan the request's batch executed.
    pub selection: Selection,
}

impl RequestOutcome {
    /// True when the request never paid a fresh selection scan.
    pub fn warm(&self) -> bool {
        self.source != PlanSource::Fresh
    }
}

/// Per-lane telemetry.
#[derive(Debug, Clone)]
pub struct LaneStats {
    pub class: LaneClass,
    pub metrics: Metrics,
    pub batches: usize,
    /// Σ merged dynamic-axis extents over the lane's batches.
    pub total_units: usize,
    /// Per-BATCH tri-state accounting: one count per executed batch —
    /// for the continuous-batching decode lane that is one per
    /// event-clock STEP, the granularity the in-horizon invariant
    /// pins (`warm_start_rate() == 1.0` means not one step paid a
    /// fresh scan). Contrast [`MixedStats::dispatch`], which counts
    /// per request.
    pub batch_dispatch: DispatchStats,
}

/// Full mixed-trace serving result.
#[derive(Debug, Clone, Default)]
pub struct MixedStats {
    pub lanes: Vec<LaneStats>,
    /// All outcomes, sorted by request id.
    pub outcomes: Vec<RequestOutcome>,
    pub cache: CacheStats,
    /// Per-request tri-state accounting (table / cache / fresh);
    /// `dispatch.total()` always equals `count()`.
    pub dispatch: DispatchStats,
    /// Offline build statistics of the dispatch table, when one was
    /// enabled (cells, merge compression, whether horizons clamped).
    pub dispatch_build: Option<crate::dispatch::BuildStats>,
    /// Auditor findings against an adopted table payload
    /// ([`ServeConfig::adopt`]): why it was refused
    /// ([`TablePolicy::RefuseUnaudited`]) or what it was adopted in
    /// spite of ([`TablePolicy::WarnUnaudited`]). Empty when no payload
    /// was adopted or the audit was clean.
    pub table_diags: Vec<Diagnostic>,
    /// Requests shed by the admission controller
    /// ([`OverloadPolicy::Drop`]), sorted by request id. Empty without
    /// SLOs — and `count() + drops.len()` is always the offered load.
    pub drops: Vec<DropRecord>,
    /// Max lane span (lanes run as concurrent executors).
    pub span_secs: f64,
    /// Structured span trace of the run, when [`ServeConfig::trace`]
    /// was set (event-clock stamped; see [`crate::obs`]).
    pub trace: Option<Trace>,
}

impl MixedStats {
    pub fn count(&self) -> usize {
        self.outcomes.len()
    }

    /// Requests offered to the server: served + shed.
    pub fn offered(&self) -> usize {
        self.outcomes.len() + self.drops.len()
    }

    /// Served requests that ran under the overload policy's
    /// downgraded mode.
    pub fn degraded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.degraded).count()
    }

    /// Served requests at full fidelity (neither shed nor degraded) —
    /// `admitted() + degraded() + drops.len() == offered()` exactly.
    pub fn admitted(&self) -> usize {
        self.outcomes.len() - self.degraded()
    }

    pub fn total_sched_secs(&self) -> f64 {
        self.lanes.iter().map(|l| l.metrics.total_sched_secs()).sum()
    }

    pub fn total_exec_secs(&self) -> f64 {
        self.lanes.iter().map(|l| l.metrics.total_exec_secs()).sum()
    }

    /// Aggregate scheduling share across lanes (Fig. 14 style).
    pub fn sched_fraction(&self) -> f64 {
        let (s, e) = (self.total_sched_secs(), self.total_exec_secs());
        if s + e == 0.0 {
            0.0
        } else {
            s / (s + e)
        }
    }

    /// Aggregate per-batch tri-state accounting across lanes (one
    /// count per decode STEP in the continuous-batching lane) — the
    /// number the decode bench's in-horizon invariant asserts on.
    pub fn batch_dispatch(&self) -> DispatchStats {
        let mut d = DispatchStats::default();
        for l in &self.lanes {
            d.table += l.batch_dispatch.table;
            d.cache += l.batch_dispatch.cache;
            d.fresh += l.batch_dispatch.fresh;
        }
        d
    }

    /// Aggregate (p50, p95, p99) request latency across lanes —
    /// same index formula as the per-lane [`Metrics`] percentiles.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut lat: Vec<f64> = self.outcomes.iter().map(|o| o.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            Metrics::pct(&lat, 0.5),
            Metrics::pct(&lat, 0.95),
            Metrics::pct(&lat, 0.99),
        )
    }
}

/// Modeled per-batch scheduling overhead charged on the event clock
/// (the paper's Fig. 14 scale on the A100 host; `bench::harness`
/// imports this constant). The clock deliberately does NOT advance by
/// this machine's wall-clock selection time: mixing wall time into
/// simulated seconds would double-count hardware differences AND make
/// replay non-deterministic (batch membership would depend on
/// selection jitter). The MEASURED selection/lookup wall-clock is
/// recorded in [`Metrics`] as the scheduling component instead —
/// that is the number the plan cache shrinks.
pub const SCHED_OVERHEAD_SECS: f64 = 2e-6;

/// Deterministic discrete-event serving loop over a mixed multi-op
/// trace. Requests must be sorted by arrival time; each lane runs the
/// same size/window batching policy as the old single-op loop, over
/// merge-key-compatible requests, and all lanes share one plan cache.
/// Replay is deterministic: the event clock advances by launch +
/// [`SCHED_OVERHEAD_SECS`] + service only.
pub fn serve_mixed_trace(
    engine: &mut dyn LaneEngine,
    selector: &Selector,
    cfg: &ServeConfig,
    requests: &[ServeRequest],
) -> MixedStats {
    debug_assert!(requests.windows(2).all(|w| w[0].arrive <= w[1].arrive));
    // The compile-time half: the dispatch table is built (or shipped
    // with the library — gated through the plan auditor per
    // [`ServeConfig::table_policy`]) BEFORE any request arrives — its
    // cost is offline, not serving wall-clock.
    let (dispatch, table_diags) = resolve_dispatch(selector, cfg);
    let mut plan_cache = cfg.plan_cache.map(|cap| PlanCache::for_selector(selector, cap));
    let mut stats = MixedStats {
        dispatch_build: dispatch.as_ref().map(|t| t.stats.clone()),
        table_diags,
        ..MixedStats::default()
    };
    let mut trace = cfg.trace.then(|| Trace {
        processes: vec![(0, "replica 0".to_string())],
        ..Trace::default()
    });
    for class in LaneClass::ALL {
        let lane_reqs: Vec<&ServeRequest> = requests
            .iter()
            .filter(|r| LaneClass::of(&r.program) == class)
            .collect();
        if lane_reqs.is_empty() {
            continue;
        }
        let run = if class == LaneClass::Decode {
            serve_decode_lane(
                engine,
                selector,
                cfg.lane(class),
                0,
                &lane_reqs,
                dispatch.as_ref(),
                plan_cache.as_mut(),
                cfg.trace,
            )
        } else {
            serve_lane(
                engine,
                selector,
                cfg.lane(class),
                class,
                0,
                &lane_reqs,
                dispatch.as_ref(),
                plan_cache.as_mut(),
                cfg.trace,
            )
        };
        stats.span_secs = stats.span_secs.max(run.stats.metrics.span_secs);
        stats.outcomes.extend(run.outcomes);
        stats.drops.extend(run.drops);
        stats.lanes.push(run.stats);
        if let Some(t) = trace.as_mut() {
            t.threads.push((0, class.index() as u64, class.name().to_string()));
            t.spans.extend(run.trace);
        }
    }
    stats.trace = trace;
    stats.outcomes.sort_by_key(|o| o.id);
    stats.drops.sort_by_key(|d| d.id);
    stats.cache = plan_cache.map(|c| c.stats).unwrap_or_default();
    for o in &stats.outcomes {
        match o.source {
            PlanSource::Table => stats.dispatch.table += 1,
            PlanSource::Cache => stats.dispatch.cache += 1,
            PlanSource::Fresh => stats.dispatch.fresh += 1,
        }
    }
    stats
}

/// One lane's full discrete-event result: the unit of parallel work in
/// the fleet executor — a pure function of (engine seed, selector,
/// lane config, request list, table), so any execution order produces
/// bit-identical runs.
#[derive(Debug)]
pub(crate) struct LaneRun {
    pub(crate) stats: LaneStats,
    pub(crate) outcomes: Vec<RequestOutcome>,
    pub(crate) drops: Vec<DropRecord>,
    /// Event-clock spans of this lane's run (empty unless tracing was
    /// requested). Purely additive output — recording reads only
    /// values the loop already computed.
    pub(crate) trace: Vec<Span>,
}

/// One lane's discrete-event loop: the old `serve_trace` core,
/// generalized to merge-key batching and (when the lane carries an
/// SLO) deadline-aware batching + admission control. Incompatible
/// requests never merge — they stay queued and the next batch forms
/// from the earliest pending request.
///
/// SLO semantics (all functions of the event clock — replay stays
/// bit-identical): the batching window is capped at the deadline
/// budget ([`LaneSlo::window`]), the window close is capped at the
/// head's launch cutoff ([`LaneSlo::launch_cutoff`]), and a head whose
/// deadline already passed when the server freed up is shed
/// ([`OverloadPolicy::Drop`] — control-plane, no clock charge) or
/// served immediately under the downgrade mode
/// ([`OverloadPolicy::Degrade`]). With the default no-op SLO every
/// branch reduces to the legacy rule exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_lane(
    engine: &mut dyn LaneEngine,
    selector: &Selector,
    cfg: &LaneConfig,
    class: LaneClass,
    replica: usize,
    requests: &[&ServeRequest],
    dispatch: Option<&DispatchTable>,
    mut plan_cache: Option<&mut PlanCache>,
    traced: bool,
) -> LaneRun {
    let mut metrics = Metrics::default();
    let mut outcomes = Vec::new();
    let mut drops = Vec::new();
    // Span recording is write-only bookkeeping over values the loop
    // computes anyway: no wall-clock reads, no extra branches on
    // serving state — the zero-perturbation invariant the fleet
    // oracle's traced-vs-untraced leg pins bitwise.
    let mut trace: Vec<Span> = Vec::new();
    let (pid, tid) = (replica as u64, class.index() as u64);
    let mut batches = 0usize;
    let mut total_units = 0usize;
    let mut batch_dispatch = DispatchStats::default();
    let mut clock = 0.0f64;
    let mut served = vec![false; requests.len()];
    let mut pending = requests.len();
    let mut next = 0usize;
    loop {
        while next < requests.len() && served[next] {
            next += 1;
        }
        if next >= requests.len() {
            break;
        }
        // Server becomes free at `clock`; the next batch forms from the
        // earliest pending request and its merge-key-compatible peers.
        let first = requests[next];
        let key = merge_key(&first.program);
        let open = clock.max(first.arrive);

        // Admission control: a head whose deadline already passed when
        // the server freed up triggers the overload policy.
        let mut mode = cfg.mode;
        let mut degraded = false;
        if let Some(d) = cfg.slo.deadline {
            if open > first.arrive + d {
                match cfg.slo.policy {
                    OverloadPolicy::ServeAnyway => {}
                    OverloadPolicy::Drop => {
                        // Shed ONE head at a time: the decision charges
                        // nothing to the clock, and the freed capacity
                        // goes to the next pending request.
                        drops.push(DropRecord {
                            id: first.id,
                            lane: class,
                            replica,
                            decided_at: open,
                            miss_by: open - (first.arrive + d),
                        });
                        if traced {
                            trace.push(
                                Span::instant("drop", "serve", pid, tid, open)
                                    .arg("id", Json::num(first.id as f64))
                                    .arg(
                                        "miss_by_us",
                                        Json::num((open - (first.arrive + d)) * 1e6),
                                    )
                                    .arg("policy", Json::str(cfg.slo.policy.name())),
                            );
                        }
                        metrics.dropped += 1;
                        served[next] = true;
                        pending -= 1;
                        continue;
                    }
                    OverloadPolicy::Degrade(m) => {
                        mode = m;
                        degraded = true;
                    }
                }
            }
        }

        // The window close: the (deadline-capped) batching window,
        // never past the head's launch cutoff. A degraded batch closes
        // immediately — only already-arrived peers merge.
        let close = if degraded {
            open
        } else {
            let close = open + cfg.slo.window(cfg.batch_window);
            match cfg.slo.launch_cutoff(first.arrive) {
                Some(cutoff) => close.min(cutoff.max(open)),
                None => close,
            }
        };
        let mut batch = vec![next];
        for (j, r) in requests.iter().enumerate().skip(next + 1) {
            if batch.len() >= cfg.max_batch || r.arrive > close {
                break;
            }
            if !served[j] && merge_key(&r.program) == key {
                batch.push(j);
            }
        }
        // Batch launch time: when the window closes or the batch fills,
        // but never before the server is free — identical to the old
        // single-op rule.
        let last_arrive = requests[*batch.last().unwrap()].arrive;
        // Unserved requests outside this batch (every unserved index is
        // >= next, so the counter is exact) — O(1), not a trace rescan.
        let more_pending = pending > batch.len();
        let launch = if degraded {
            open
        } else if batch.len() == cfg.max_batch || !more_pending {
            last_arrive.max(open)
        } else {
            close
        };

        let programs: Vec<&TensorProgram> =
            batch.iter().map(|&j| &requests[j].program).collect();
        let merged = merge_programs(&programs);
        let space = merged.space();
        // Tri-state resolution: compile-time table first, then the
        // plan cache (beyond-horizon fallback), then a fresh scan.
        // `mode` is the lane's configured mode, or the overload
        // downgrade — the cache key and any (op, mode) table both
        // include the mode, so the tri-state stack stays sound.
        let table_sel = dispatch.and_then(|t| t.select(selector, space, mode));
        let (sel, source) = match table_sel {
            Some(sel) => (sel, PlanSource::Table),
            None => match plan_cache.as_deref_mut() {
                Some(c) => {
                    let hits0 = c.stats.hits;
                    let sel = c
                        .select(selector, space, mode)
                        .expect("selector must handle any shape (sample-free)");
                    let source = if c.stats.hits > hits0 {
                        PlanSource::Cache
                    } else {
                        PlanSource::Fresh
                    };
                    (sel, source)
                }
                None => (
                    selector
                        .select(space, mode)
                        .expect("selector must handle any shape (sample-free)"),
                    PlanSource::Fresh,
                ),
            },
        };
        let service = engine.execute(space, &sel, selector);
        let done = launch + SCHED_OVERHEAD_SECS + service;
        let bsz = batch.len();
        let merged_flops = space.flops();
        let own: Vec<f64> = programs.iter().map(|p| p.flops()).collect();
        let own_sum: f64 = own.iter().sum();
        for (bi, &j) in batch.iter().enumerate() {
            let r = requests[j];
            let latency = done - r.arrive;
            metrics.record(
                latency,
                sel.select_secs / bsz as f64,
                service / bsz as f64,
                merged_flops * own[bi] / own_sum,
            );
            if degraded {
                metrics.degraded += 1;
            }
            outcomes.push(RequestOutcome {
                id: r.id,
                lane: class,
                replica,
                latency,
                launch,
                batch_size: bsz,
                source,
                degraded,
                selection: sel.clone(),
            });
            served[j] = true;
        }
        if traced {
            for &j in &batch {
                trace.push(
                    Span::instant("admit", "serve", pid, tid, requests[j].arrive)
                        .arg("id", Json::num(requests[j].id as f64)),
                );
            }
            if degraded {
                trace.push(
                    Span::instant("degrade", "serve", pid, tid, open)
                        .arg("policy", Json::str(cfg.slo.policy.name())),
                );
            }
            trace.push(
                Span::complete("form", "serve", pid, tid, open, launch - open)
                    .arg("batch", Json::num(bsz as f64)),
            );
            // The plan instant is EVENT-stamped at launch; the measured
            // selection wall-clock rides along as data (`select_wall_us`
            // — the Fig. 14 scheduling component), never as a timestamp.
            trace.push(
                Span::instant("plan", "serve", pid, tid, launch)
                    .arg("source", Json::str(source.name()))
                    .arg("lib", Json::num(sel.lib as f64))
                    .arg("kernel", Json::num(sel.kernel as f64))
                    .arg("select_wall_us", Json::num(sel.select_secs * 1e6)),
            );
            trace.push(Span::complete("sched", "serve", pid, tid, launch, SCHED_OVERHEAD_SECS));
            trace.push(
                Span::complete(
                    "exec",
                    "serve",
                    pid,
                    tid,
                    launch + SCHED_OVERHEAD_SECS,
                    service,
                )
                .arg("batch", Json::num(bsz as f64))
                .arg("degraded", Json::Bool(degraded)),
            );
        }
        batches += 1;
        total_units += dynamic_units(&merged);
        batch_dispatch.bump(source);
        pending -= bsz;
        clock = done;
    }
    metrics.span_secs = clock;
    LaneRun {
        stats: LaneStats { class, metrics, batches, total_units, batch_dispatch },
        outcomes,
        drops,
        trace,
    }
}

/// Per-sequence continuous-batching slot. The pool holds at most
/// `max_batch` slots, built once up front and REUSED as sequences
/// retire and new ones admit — the steady-state decode path touches
/// no allocator ([`Metrics::alloc_events`] counts the pool builds).
#[derive(Debug)]
struct DecodeSlot {
    /// Index into the lane's request list.
    req: usize,
    /// Per-request head-group batch (summed into the merged step).
    batch: usize,
    /// Step query length (1 for token decode) — part of the merge
    /// key: a one-token step never merges with a prefill chunk.
    seq_q: usize,
    /// KV-cache depth of the NEXT step; grows by one per token.
    seq_k: usize,
    d: usize,
    heads: usize,
    dtype: DType,
    /// Tokens to generate / generated so far.
    steps: usize,
    tokens: usize,
    /// Event-clock completion of the previous token (the arrival
    /// time before the first) — the per-token latency base.
    prev_done: f64,
    /// Event-clock launch of the sequence's first step.
    first_launch: f64,
    /// Whether any step of this sequence paid a fresh scan / was
    /// answered beyond-horizon by the plan cache.
    paid_fresh: bool,
    hit_cache: bool,
    active: bool,
}

/// The continuous-batching decode loop ([`LaneClass::Decode`]): one
/// merged causal-attention step per event-clock iteration, one token
/// per in-flight sequence per step. Sequences ADMIT at the first step
/// boundary at/after their arrival (capacity permitting, in arrival
/// order) and RETIRE after `steps` tokens, freeing their slot — the
/// batch re-forms every step from whoever is in flight, so it shrinks
/// and grows mid-flight without quantizing work to one-shot batches.
///
/// Steady-state dispatch is zero-scan and zero-allocation: every
/// in-horizon step resolves from the dispatch table (the seq_k axis
/// partitions at L1-extent multiples over the decode horizon, so the
/// growing depth walks table cells, never the selector), and all
/// per-step state (slot pool, step group, flops scratch, metric
/// reservoirs) is allocated once up front — counted in
/// [`Metrics::alloc_events`] — and reused. Span recording (`traced`)
/// is exempt: it is write-only output, and the zero-perturbation
/// oracle pins its outcomes bitwise, not its allocations.
///
/// SLO semantics: a sequence whose time-to-first-token deadline has
/// already passed at its admission boundary is shed under
/// [`OverloadPolicy::Drop`]; `Degrade` is treated as `ServeAnyway`
/// (a merged step serves many sequences — per-sequence mode
/// downgrades would fork the batch). Everything is a function of the
/// event clock, so replay stays bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_decode_lane(
    engine: &mut dyn LaneEngine,
    selector: &Selector,
    cfg: &LaneConfig,
    replica: usize,
    requests: &[&ServeRequest],
    dispatch: Option<&DispatchTable>,
    mut plan_cache: Option<&mut PlanCache>,
    traced: bool,
) -> LaneRun {
    let class = LaneClass::Decode;
    let mut metrics = Metrics::default();
    // The amortized up-front builds: outcome list, per-token metric
    // reservoirs, slot pool, step group, flops scratch. Nothing else
    // on the loop's untraced path allocates.
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(requests.len());
    let total_tokens: usize = requests.iter().map(|r| r.steps.max(1)).sum();
    metrics.reserve(total_tokens);
    let cap = cfg.max_batch.max(1);
    let mut slots: Vec<DecodeSlot> = Vec::with_capacity(cap);
    let mut group: Vec<usize> = Vec::with_capacity(cap);
    let mut own: Vec<f64> = Vec::with_capacity(cap);
    metrics.alloc_events += 5;
    let mut drops = Vec::new();
    let mut trace: Vec<Span> = Vec::new();
    let (pid, tid) = (replica as u64, class.index() as u64);
    let mut batches = 0usize;
    let mut total_units = 0usize;
    let mut batch_dispatch = DispatchStats::default();
    let mut clock = 0.0f64;
    let mut next = 0usize;
    loop {
        let mut active = slots.iter().filter(|s| s.active).count();
        if active == 0 {
            if next >= requests.len() {
                break;
            }
            // Idle server: jump to the next arrival.
            clock = clock.max(requests[next].arrive);
        }
        // Admit arrivals at/before this step boundary, in arrival
        // order, up to the slot pool's capacity.
        while next < requests.len() && active < cap && requests[next].arrive <= clock {
            let r = requests[next];
            next += 1;
            if let Some(d) = cfg.slo.deadline {
                if clock > r.arrive + d && matches!(cfg.slo.policy, OverloadPolicy::Drop) {
                    drops.push(DropRecord {
                        id: r.id,
                        lane: class,
                        replica,
                        decided_at: clock,
                        miss_by: clock - (r.arrive + d),
                    });
                    if traced {
                        trace.push(
                            Span::instant("drop", "serve", pid, tid, clock)
                                .arg("id", Json::num(r.id as f64))
                                .arg("miss_by_us", Json::num((clock - (r.arrive + d)) * 1e6))
                                .arg("policy", Json::str(cfg.slo.policy.name())),
                        );
                    }
                    metrics.dropped += 1;
                    continue;
                }
            }
            let (batch, seq_q, seq_k, d, heads, dtype) = match r.program {
                TensorProgram::CausalAttention { batch, seq_q, seq_k, d, heads, dtype } => {
                    (batch, seq_q, seq_k, d, heads, dtype)
                }
                _ => unreachable!("decode lane admits only causal-attention programs"),
            };
            let slot = DecodeSlot {
                req: next - 1,
                batch,
                seq_q,
                seq_k,
                d,
                heads,
                dtype,
                steps: r.steps.max(1),
                tokens: 0,
                prev_done: r.arrive,
                first_launch: 0.0,
                paid_fresh: false,
                hit_cache: false,
                active: true,
            };
            match slots.iter().position(|s| !s.active) {
                Some(i) => slots[i] = slot,
                None => {
                    // Never fires while the pool is at capacity (the
                    // admission guard caps active at `cap`) — counted
                    // so the zero-alloc invariant stays honest.
                    if slots.len() == slots.capacity() {
                        metrics.alloc_events += 1;
                    }
                    slots.push(slot);
                }
            }
            if traced {
                trace.push(
                    Span::instant("admit", "serve", pid, tid, r.arrive)
                        .arg("id", Json::num(r.id as f64)),
                );
            }
            active += 1;
        }
        if active == 0 {
            // Everything admissible at this boundary was shed.
            continue;
        }
        // The step group: every active slot sharing the merge key of
        // the EARLIEST-admitted active sequence. Mixed-key traffic is
        // served key-group by key-group, deterministically.
        let mut lead = usize::MAX;
        for (i, s) in slots.iter().enumerate() {
            if s.active && (lead == usize::MAX || s.req < slots[lead].req) {
                lead = i;
            }
        }
        let (kq, kd, kh, kt) =
            (slots[lead].seq_q, slots[lead].d, slots[lead].heads, slots[lead].dtype);
        group.clear();
        own.clear();
        let mut batch_sum = 0usize;
        let mut seq_k_pad = 0usize;
        let mut own_sum = 0.0f64;
        for (i, s) in slots.iter().enumerate() {
            if s.active && s.seq_q == kq && s.d == kd && s.heads == kh && s.dtype == kt {
                group.push(i);
                batch_sum += s.batch;
                seq_k_pad = seq_k_pad.max(s.seq_k);
                let f = TensorProgram::CausalAttention {
                    batch: s.batch,
                    seq_q: s.seq_q,
                    seq_k: s.seq_k,
                    d: s.d,
                    heads: s.heads,
                    dtype: s.dtype,
                }
                .flops();
                own.push(f);
                own_sum += f;
            }
        }
        let merged = TensorProgram::CausalAttention {
            batch: batch_sum,
            seq_q: kq,
            seq_k: seq_k_pad,
            d: kd,
            heads: kh,
            dtype: kt,
        };
        let space = merged.space();
        // Same tri-state stack as the one-shot lanes: compile-time
        // table first, plan cache beyond the horizon, fresh scan last.
        let table_sel = dispatch.and_then(|t| t.select(selector, space, cfg.mode));
        let (sel, source) = match table_sel {
            Some(sel) => (sel, PlanSource::Table),
            None => match plan_cache.as_deref_mut() {
                Some(c) => {
                    let hits0 = c.stats.hits;
                    let sel = c
                        .select(selector, space, cfg.mode)
                        .expect("selector must handle any shape (sample-free)");
                    let source = if c.stats.hits > hits0 {
                        PlanSource::Cache
                    } else {
                        PlanSource::Fresh
                    };
                    (sel, source)
                }
                None => (
                    selector
                        .select(space, cfg.mode)
                        .expect("selector must handle any shape (sample-free)"),
                    PlanSource::Fresh,
                ),
            },
        };
        // Continuous batching launches at the step boundary: every
        // group member already arrived, so there is no window to hold
        // open — new arrivals join at the NEXT boundary.
        let launch = clock;
        let service = engine.execute(space, &sel, selector);
        let done = launch + SCHED_OVERHEAD_SECS + service;
        let g = group.len();
        let merged_flops = space.flops();
        for (bi, &i) in group.iter().enumerate() {
            let s = &mut slots[i];
            // Per-TOKEN latency: from the previous token's completion
            // (arrival, for the first token) to this one's.
            let latency = done - s.prev_done;
            metrics.record(
                latency,
                sel.select_secs / g as f64,
                service / g as f64,
                merged_flops * own[bi] / own_sum,
            );
            if s.tokens == 0 {
                s.first_launch = launch;
            }
            s.tokens += 1;
            s.seq_k += 1;
            s.prev_done = done;
            match source {
                PlanSource::Fresh => s.paid_fresh = true,
                PlanSource::Cache => s.hit_cache = true,
                PlanSource::Table => {}
            }
            if s.tokens >= s.steps {
                s.active = false;
                let r = requests[s.req];
                outcomes.push(RequestOutcome {
                    id: r.id,
                    lane: class,
                    replica,
                    // Full-sequence completion latency; the per-token
                    // distribution lives in the lane [`Metrics`].
                    latency: done - r.arrive,
                    launch: s.first_launch,
                    batch_size: g,
                    // Worst source any step paid: `warm()` means not
                    // one of this sequence's tokens cost a scan.
                    source: if s.paid_fresh {
                        PlanSource::Fresh
                    } else if s.hit_cache {
                        PlanSource::Cache
                    } else {
                        PlanSource::Table
                    },
                    degraded: false,
                    selection: sel.clone(),
                });
            }
        }
        if traced {
            trace.push(
                Span::complete("form", "serve", pid, tid, launch, 0.0)
                    .arg("batch", Json::num(g as f64)),
            );
            trace.push(
                Span::instant("plan", "serve", pid, tid, launch)
                    .arg("source", Json::str(source.name()))
                    .arg("lib", Json::num(sel.lib as f64))
                    .arg("kernel", Json::num(sel.kernel as f64))
                    .arg("select_wall_us", Json::num(sel.select_secs * 1e6)),
            );
            trace.push(Span::complete("sched", "serve", pid, tid, launch, SCHED_OVERHEAD_SECS));
            trace.push(
                Span::complete("exec", "serve", pid, tid, launch + SCHED_OVERHEAD_SECS, service)
                    .arg("batch", Json::num(g as f64))
                    .arg("degraded", Json::Bool(false)),
            );
        }
        batches += 1;
        total_units += dynamic_units(&merged);
        batch_dispatch.bump(source);
        clock = done;
    }
    metrics.span_secs = clock;
    LaneRun {
        stats: LaneStats { class, metrics, batches, total_units, batch_dispatch },
        outcomes,
        drops,
        trace,
    }
}

/// Per-worker executor telemetry: how many (replica, lane) units the
/// worker ran, and how many of those it STOLE from another worker's
/// queue. Telemetry only — steal counts depend on thread timing and
/// are deliberately excluded from the determinism oracle's
/// fingerprint (serving OUTCOMES stay bitwise invariant; which worker
/// ran a unit does not).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub executed: usize,
    pub stolen: usize,
}

/// Deterministic parallel executor over independent work units: run
/// `job(u)` for every `u` in `0..seed_order.len()` and return the
/// results in UNIT-INDEX order regardless of worker count, plus
/// per-worker [`WorkerStats`].
///
/// `workers <= 1` is the sequential discrete-event oracle (units run
/// in index order on the calling thread). With more workers, a
/// `std::thread` pool is seeded round-robin from `seed_order` (the
/// caller's priority order — a scheduling hint) and idle workers
/// STEAL from the back of other workers' queues. Determinism is by
/// construction, not by locking discipline: each unit is an
/// independent pure job writing only its own indexed result slot, so
/// scheduling affects wall-clock and nothing else — the property the
/// fleet oracle test (`tests/fleet_oracle.rs`) checks bitwise across
/// worker counts.
pub(crate) fn execute_units<R: Send>(
    workers: usize,
    seed_order: &[usize],
    job: impl Fn(usize) -> R + Sync,
) -> (Vec<R>, Vec<WorkerStats>) {
    use std::collections::VecDeque;
    use std::sync::Mutex;
    let n = seed_order.len();
    debug_assert!({
        let mut s: Vec<usize> = seed_order.to_vec();
        s.sort_unstable();
        s == (0..n).collect::<Vec<_>>()
    });
    if workers <= 1 {
        let results = (0..n).map(job).collect();
        return (results, vec![WorkerStats { executed: n, stolen: 0 }]);
    }
    let queues: Vec<Mutex<VecDeque<usize>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for (i, &u) in seed_order.iter().enumerate() {
        queues[i % workers].lock().unwrap().push_back(u);
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut worker_stats = vec![WorkerStats::default(); workers];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let queues = &queues;
                let job = &job;
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    let mut stats = WorkerStats::default();
                    loop {
                        // Own queue front first, then steal from the
                        // BACK of the others (classic stealing keeps
                        // contention off the owners' hot ends). No unit
                        // ever re-enqueues work, so all-empty means
                        // drained for good.
                        let u = queues[w].lock().unwrap().pop_front().map(|u| (u, false)).or_else(
                            || {
                                (0..queues.len()).filter(|&o| o != w).find_map(|o| {
                                    queues[o]
                                        .lock()
                                        .unwrap()
                                        .pop_back()
                                        .map(|u| (u, true))
                                })
                            },
                        );
                        match u {
                            Some((u, stolen)) => {
                                stats.executed += 1;
                                stats.stolen += usize::from(stolen);
                                done.push((u, job(u)));
                            }
                            None => break,
                        }
                    }
                    (done, stats)
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            let (done, stats) = h.join().expect("fleet worker panicked");
            worker_stats[w] = stats;
            for (u, r) in done {
                slots[u] = Some(r);
            }
        }
    });
    let results = slots
        .into_iter()
        .map(|r| r.expect("every unit executes exactly once"))
        .collect();
    (results, worker_stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;

    fn gemm(m: usize) -> TensorProgram {
        TensorProgram::Gemm { m, n: 768, k: 768, dtype: DType::F32 }
    }

    fn conv(n: usize) -> TensorProgram {
        TensorProgram::conv2d((n, 28, 28, 64), (3, 3, 128), (1, 1, 1), DType::F32).unwrap()
    }

    fn attn(batch: usize, seq: usize) -> TensorProgram {
        TensorProgram::attention((batch, seq), (768, 12), DType::F32).unwrap()
    }

    fn selector() -> Selector {
        scenario::demo_selector(5)
    }

    #[test]
    fn merge_keys_partition_by_shape_family() {
        assert_eq!(merge_key(&gemm(1)), merge_key(&gemm(400)));
        assert_ne!(
            merge_key(&gemm(1)),
            merge_key(&TensorProgram::Gemm { m: 1, n: 768, k: 1024, dtype: DType::F32 })
        );
        assert_eq!(merge_key(&conv(1)), merge_key(&conv(32)));
        // Attention merges across BOTH batch and sequence (padding).
        assert_eq!(merge_key(&attn(1, 77)), merge_key(&attn(4, 476)));
        assert_ne!(
            merge_key(&attn(1, 77)),
            merge_key(&TensorProgram::attention((1, 77), (1024, 16), DType::F32).unwrap())
        );
    }

    #[test]
    fn merged_programs_sum_the_merge_axis() {
        let g = merge_programs(&[&gemm(3), &gemm(5), &gemm(7)]);
        assert_eq!(g, gemm(15));
        let c = merge_programs(&[&conv(2), &conv(6)]);
        assert_eq!(c, conv(8));
        let a = merge_programs(&[&attn(1, 77), &attn(2, 128), &attn(1, 64)]);
        assert_eq!(a, attn(4, 128)); // batch summed, seq padded to max
        assert!(a.validate().is_ok());
    }

    #[test]
    fn mixed_trace_serves_every_lane_once() {
        let s = selector();
        let mut requests = Vec::new();
        for i in 0..30u64 {
            let program = match i % 3 {
                0 => gemm(16 + i as usize),
                1 => conv(1 + (i as usize % 4)),
                _ => attn(1, 64),
            };
            requests.push(ServeRequest { id: i, program, arrive: 1e-4 * i as f64, steps: 1 });
        }
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &ServeConfig::default(), &requests);
        assert_eq!(stats.count(), 30);
        let ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
        // Three lanes active (gemm, conv, attention), none lost.
        assert_eq!(stats.lanes.len(), 3);
        assert!(stats.span_secs > 0.0);
        let (p50, p95, p99) = stats.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn incompatible_requests_never_merge() {
        let s = selector();
        // Two interleaved gemm widths arriving simultaneously: batches
        // must be key-pure, so each batch's size stays within its own
        // key's population.
        let wide = |m: usize| TensorProgram::Gemm { m, n: 1024, k: 768, dtype: DType::F32 };
        let mut requests = Vec::new();
        for i in 0..16u64 {
            let program = if i % 2 == 0 { gemm(8) } else { wide(8) };
            requests.push(ServeRequest { id: i, program, arrive: 1e-6 * i as f64, steps: 1 });
        }
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &ServeConfig::default(), &requests);
        assert_eq!(stats.count(), 16);
        // All 16 share the gemm lane; a merged batch of mixed keys
        // would produce a single 16-deep batch, key-purity caps it at 8.
        assert!(stats.outcomes.iter().all(|o| o.batch_size <= 8));
        let lane = &stats.lanes[0];
        assert!(lane.batches >= 2);
    }

    #[test]
    fn dispatch_tri_state_counts_and_matches_fresh_plans() {
        use crate::dispatch::DispatchConfig;
        use crate::ir::OpKind;
        let s = selector();
        // Horizon covers the gemm template at small m only; arrivals
        // are spaced past the batch window so every batch is one
        // request and the counts are exact.
        let dcfg = DispatchConfig {
            ops: vec![OpKind::Gemm],
            ..DispatchConfig::default()
        }
        .with_op_horizons(OpKind::Gemm, &[64, 768, 768]);
        let mut cfg = ServeConfig::default().with_dispatch(dcfg);
        for class in LaneClass::ALL {
            cfg.lane_mut(class).max_batch = 1;
        }
        let requests: Vec<ServeRequest> = (0..12u64)
            .map(|i| ServeRequest {
                id: i,
                program: gemm(if i % 2 == 0 { 16 } else { 500 }),
                arrive: 5e-3 * i as f64,
                steps: 1,
            })
            .collect();
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &cfg, &requests);
        // Tri-state accounting sums to the request count, with every
        // outcome kind represented: m=16 is table-answered, the first
        // m=500 batch is the one fresh scan, its repeats hit the cache.
        assert_eq!(stats.dispatch.total(), 12);
        assert_eq!(stats.dispatch.table, 6);
        assert_eq!(stats.dispatch.fresh, 1);
        assert_eq!(stats.dispatch.cache, 5);
        assert!((stats.dispatch.warm_start_rate() - 11.0 / 12.0).abs() < 1e-12);
        for o in &stats.outcomes {
            assert_eq!(o.warm(), o.source != PlanSource::Fresh);
        }
        // Plans are identical to a run with no table and no cache.
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let plain = ServeConfig {
            plan_cache: None,
            dispatch: None,
            lanes: cfg.lanes,
            ..ServeConfig::default()
        };
        let fresh = serve_mixed_trace(&mut e2, &s, &plain, &requests);
        assert_eq!(fresh.dispatch.fresh, 12);
        for (a, b) in stats.outcomes.iter().zip(&fresh.outcomes) {
            assert_eq!(a.id, b.id);
            assert!(
                a.selection.same_plan(&b.selection),
                "plan diverged for request {} ({:?})",
                a.id,
                a.source
            );
        }
    }

    #[test]
    fn adopted_payloads_are_gated_by_the_plan_auditor() {
        use crate::dispatch::{table_digest, DispatchConfig};
        use crate::ir::OpKind;
        let s = selector();
        let dcfg = DispatchConfig { ops: vec![OpKind::Gemm], ..DispatchConfig::default() }
            .with_op_horizons(OpKind::Gemm, &[64, 768, 768]);
        let payload = DispatchTable::for_selector(&s, &dcfg).to_data(&s);

        let mut cfg = ServeConfig::default();
        cfg.plan_cache = None;
        for class in LaneClass::ALL {
            cfg.lane_mut(class).max_batch = 1;
        }
        let requests: Vec<ServeRequest> = (0..6u64)
            .map(|i| ServeRequest::once(i, gemm(16), 5e-3 * i as f64))
            .collect();
        let run = |cfg: &ServeConfig| {
            let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
            serve_mixed_trace(&mut engine, &s, cfg, &requests)
        };

        // A clean payload is audited and adopted under the default
        // (refuse-unaudited) policy: every in-horizon request is a
        // table hit and no findings are recorded.
        let clean = run(&cfg.adopting(payload.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(clean.dispatch.table, 6);
        assert!(clean.table_diags.is_empty());

        // Forge a digest-consistent payload the strict loader accepts
        // but whose edge the auditor proves off the fine lattice.
        let mut forged = payload.clone();
        let table = DispatchTable::from_data_checked(&s, &payload).unwrap();
        let mut tampered = false;
        'search: for (ti, t) in table.tables.iter().enumerate() {
            for a in 0..t.edges.len() {
                let mut extents: Vec<usize> = s
                    .eligible_fast(s.serving_op(t.op), t.mode)
                    .iter()
                    .map(|&fi| s.fast[fi].l1[a])
                    .collect();
                extents.sort_unstable();
                extents.dedup();
                let fine =
                    crate::dispatch::axis_edges(&extents, *t.edges[a].last().unwrap());
                for j in 0..t.edges[a].len().saturating_sub(1) {
                    let bumped = t.edges[a][j] + 1;
                    if bumped < t.edges[a][j + 1] && fine.binary_search(&bumped).is_err() {
                        forged[ti].edges[a][j] = bumped;
                        forged[ti].digest = table_digest(
                            forged[ti].op,
                            &forged[ti].mode,
                            &forged[ti].edges,
                            &forged[ti].runs,
                            forged[ti].clamped,
                        );
                        tampered = true;
                        break 'search;
                    }
                }
            }
        }
        assert!(tampered, "no tamperable off-lattice edge found");

        // RefuseUnaudited with no in-process build: the payload is
        // refused, every request pays fresh selection, and the refusal
        // reason is on record.
        let refused = run(&cfg.adopting(forged.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(refused.dispatch.table, 0);
        assert_eq!(refused.dispatch.fresh, 6);
        assert!(refused
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));

        // ... and WITH an in-process build configured, refusal falls
        // back to it: table hits return, findings stay on record.
        let fallback = run(&cfg
            .with_dispatch(dcfg.clone())
            .adopting(forged.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(fallback.dispatch.table, 6);
        assert!(fallback
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));

        // WarnUnaudited serves from the forged payload anyway but keeps
        // the findings; Trust skips the audit entirely.
        let warned = run(&cfg.adopting(forged.clone(), TablePolicy::WarnUnaudited));
        assert!(warned.dispatch.table > 0);
        assert!(warned
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));
        let trusted = run(&cfg.adopting(forged, TablePolicy::Trust));
        assert!(trusted.dispatch.table > 0);
        assert!(trusted.table_diags.is_empty());

        // A loader-level refusal (foreign fingerprint) surfaces its own
        // diagnostic code even under Trust — the strict loader is not
        // subject to policy.
        let mut foreign = payload;
        foreign[0].fingerprint ^= 1;
        let stats = run(&cfg.adopting(foreign, TablePolicy::Trust));
        assert_eq!(stats.dispatch.table, 0);
        assert!(stats
            .table_diags
            .iter()
            .any(|d| d.code == "load.fingerprint_mismatch"));
    }

    #[test]
    fn cache_disabled_and_enabled_pick_identical_plans() {
        let s = selector();
        let requests: Vec<ServeRequest> = (0..24u64)
            .map(|i| ServeRequest {
                id: i,
                program: attn(1, 64 + 64 * (i as usize % 3)),
                arrive: 2e-4 * i as f64,
                steps: 1,
            })
            .collect();
        let cfg = ServeConfig::default();
        let mut e1 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let cached = serve_mixed_trace(&mut e1, &s, &cfg, &requests);
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let fresh = serve_mixed_trace(&mut e2, &s, &cfg.without_cache(), &requests);
        assert!(cached.cache.hits > 0);
        assert_eq!(fresh.cache.lookups(), 0);
        for (a, b) in cached.outcomes.iter().zip(&fresh.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.batch_size, b.batch_size);
            assert!(
                a.selection.same_plan(&b.selection),
                "plan diverged for request {}: {:?} vs {:?}",
                a.id,
                a.selection,
                b.selection
            );
        }
    }

    #[test]
    fn tracing_is_zero_perturbation_and_spans_reconcile() {
        let s = selector();
        let requests: Vec<ServeRequest> = (0..40u64)
            .map(|i| {
                let program = match i % 3 {
                    0 => gemm(16 + i as usize),
                    1 => conv(1 + (i as usize % 4)),
                    _ => attn(1, 64),
                };
                ServeRequest { id: i, program, arrive: 1e-4 * i as f64, steps: 1 }
            })
            .collect();
        let cfg = ServeConfig::default();
        let mut e1 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let plain = serve_mixed_trace(&mut e1, &s, &cfg, &requests);
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let traced = serve_mixed_trace(&mut e2, &s, &cfg.traced(), &requests);
        // Zero perturbation: recording spans must not move a single bit
        // of any outcome.
        assert!(plain.trace.is_none());
        assert_eq!(plain.outcomes.len(), traced.outcomes.len());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.launch.to_bits(), b.launch.to_bits());
            assert_eq!(a.batch_size, b.batch_size);
            assert_eq!(a.source, b.source);
            assert!(a.selection.same_plan(&b.selection));
        }
        // The trace reconciles with the outcome log: one admit instant
        // per request; one form/plan/sched/exec span per batch; every
        // span stamped from the event clock.
        let t = traced.trace.as_ref().expect("trace requested");
        let count = |name: &str| t.spans.iter().filter(|sp| sp.name == name).count();
        assert_eq!(count("admit"), traced.outcomes.len());
        let batches: usize = traced.lanes.iter().map(|l| l.batches).sum();
        for name in ["form", "plan", "sched", "exec"] {
            assert_eq!(count(name), batches, "{name} spans vs {batches} batches");
        }
        assert!(t.spans.iter().all(|sp| sp.clock == crate::obs::SpanClock::Event));
        assert_eq!(t.threads.len(), traced.lanes.len());
    }

    fn decode(id: u64, prompt: usize, arrive: f64, steps: usize) -> ServeRequest {
        ServeRequest {
            id,
            program: TensorProgram::decode_step((1, prompt), (768, 12), DType::F32).unwrap(),
            arrive,
            steps,
        }
    }

    #[test]
    fn decode_lane_admits_and_retires_mid_flight() {
        let s = selector();
        // Three overlapping sequences with distinct output lengths: the
        // step batch must grow as sequences admit and shrink as they
        // retire, without losing a token anywhere.
        let requests =
            vec![decode(0, 32, 0.0, 6), decode(1, 48, 1e-5, 3), decode(2, 64, 2e-5, 9)];
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &ServeConfig::default(), &requests);
        assert_eq!(stats.count(), 3);
        assert!(stats.outcomes.iter().all(|o| o.lane == LaneClass::Decode));
        let lane = &stats.lanes[0];
        assert_eq!(lane.class, LaneClass::Decode);
        // One metric sample and one dynamic unit per TOKEN (6 + 3 + 9),
        // not per request.
        assert_eq!(lane.metrics.count(), 18);
        assert_eq!(lane.total_units, 18);
        // Continuous batching: at least as many steps as the longest
        // sequence, strictly fewer than one isolated batch per token.
        assert!(lane.batches >= 9, "{} steps", lane.batches);
        assert!(lane.batches < 18, "{} steps — nothing ever shared a step", lane.batches);
        assert!(stats.outcomes.iter().any(|o| o.batch_size > 1), "no step was shared");
        assert_eq!(lane.batch_dispatch.total() as usize, lane.batches);
        for o in &stats.outcomes {
            assert!(o.latency > 0.0);
            assert!(o.launch >= 0.0);
        }
    }

    #[test]
    fn decode_in_horizon_steps_all_hit_the_table() {
        // The tentpole invariant: with the scenario envelope configured,
        // EVERY in-horizon decode step resolves from the compile-time
        // table — zero selector scans, zero cache traffic, per token.
        let s = selector();
        let trace = scenario::decode_trace(80, 2e-4, 16, 3, DType::F32);
        let cfg = scenario::serving_config().with_dispatch(scenario::dispatch_config());
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &cfg, &trace);
        assert_eq!(stats.count(), 80);
        assert!(!stats.dispatch_build.as_ref().unwrap().clamped);
        let bd = stats.batch_dispatch();
        assert!(bd.total() > 0);
        assert_eq!(bd.fresh, 0, "a decode step paid a fresh selector scan");
        assert_eq!(bd.cache, 0, "a decode step fell beyond the horizon");
        assert_eq!(bd.warm_start_rate(), 1.0);
        // The per-request roll-up agrees: every sequence was
        // table-answered on every one of its tokens.
        assert!(stats.outcomes.iter().all(|o| o.source == PlanSource::Table));
        assert_eq!(stats.dispatch.table as usize, stats.count());
    }

    #[test]
    fn decode_steady_state_allocations_are_amortized() {
        // `alloc_events` counts the up-front pool builds and NOTHING
        // else: a 3x longer trace with 4x longer sequences must report
        // exactly the same count — the steady-state per-token path
        // never touches the allocator.
        let s = selector();
        let cfg = scenario::serving_config().with_dispatch(scenario::dispatch_config());
        let events = |n: usize, mean_tokens: usize| {
            let trace = scenario::decode_trace(n, 2e-4, mean_tokens, 3, DType::F32);
            let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
            let stats = serve_mixed_trace(&mut engine, &s, &cfg, &trace);
            assert_eq!(stats.lanes.len(), 1);
            stats.lanes[0].metrics.alloc_events
        };
        let short = events(20, 8);
        let long = events(60, 32);
        assert_eq!(short, 5, "expected exactly the five amortized pool builds");
        assert_eq!(short, long, "allocation count grew with the trace");
    }

    #[test]
    fn decode_table_answers_the_whole_horizon_with_fresh_identical_plans() {
        // Horizon sweep: for EVERY seq_k a decode step can present —
        // powers of two, primes, the horizon edge — and both the
        // single-sequence and the fully merged batch, the table answers
        // (no fallback) and its plan is `same_plan`-identical to a
        // fresh selector scan.
        let s = selector();
        let dcfg = scenario::dispatch_config();
        let table = DispatchTable::for_selector(&s, &dcfg);
        let horizon = dcfg.horizons_for(crate::ir::OpKind::CausalAttention)[2];
        assert_eq!(horizon, 256);
        for g in [1usize, 4] {
            for seq_k in 1..=horizon {
                let p = TensorProgram::CausalAttention {
                    batch: g,
                    seq_q: 1,
                    seq_k,
                    d: 768,
                    heads: 12,
                    dtype: DType::F32,
                };
                let space = p.space();
                let from_table = table
                    .select(&s, space, HwMode::Adaptive)
                    .unwrap_or_else(|| panic!("seq_k {seq_k} (batch {g}) missed the table"));
                let fresh = s.select(space, HwMode::Adaptive).unwrap();
                assert!(
                    from_table.same_plan(&fresh),
                    "table plan diverged at seq_k {seq_k} (batch {g})"
                );
            }
        }
    }

    #[test]
    fn decode_tracing_is_zero_perturbation_and_spans_reconcile() {
        let s = selector();
        let trace = scenario::decode_trace(30, 2e-4, 8, 5, DType::F32);
        let cfg = scenario::serving_config().with_dispatch(scenario::dispatch_config());
        let mut e1 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let plain = serve_mixed_trace(&mut e1, &s, &cfg, &trace);
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let traced = serve_mixed_trace(&mut e2, &s, &cfg.traced(), &trace);
        assert_eq!(plain.outcomes.len(), traced.outcomes.len());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.launch.to_bits(), b.launch.to_bits());
            assert_eq!(a.batch_size, b.batch_size);
            assert_eq!(a.source, b.source);
            assert!(a.selection.same_plan(&b.selection));
        }
        // One admit instant per sequence; one form/plan/sched/exec
        // span per STEP; everything event-clock stamped.
        let t = traced.trace.as_ref().expect("trace requested");
        let count = |name: &str| t.spans.iter().filter(|sp| sp.name == name).count();
        assert_eq!(count("admit"), traced.outcomes.len());
        let steps = traced.lanes[0].batches;
        for name in ["form", "plan", "sched", "exec"] {
            assert_eq!(count(name), steps, "{name} spans vs {steps} steps");
        }
        assert!(t.spans.iter().all(|sp| sp.clock == crate::obs::SpanClock::Event));
    }

    #[test]
    fn zero_request_stats_are_well_defined_zeros() {
        // The empty-trace path: every rate and percentile must answer
        // 0.0, never NaN, and a requested trace still materializes.
        let s = selector();
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats =
            serve_mixed_trace(&mut engine, &s, &ServeConfig::default().traced(), &[]);
        assert_eq!(stats.count(), 0);
        assert_eq!(stats.latency_percentiles(), (0.0, 0.0, 0.0));
        assert_eq!(stats.sched_fraction(), 0.0);
        assert_eq!(stats.dispatch.warm_start_rate(), 0.0);
        assert_eq!(stats.cache.hit_rate(), 0.0);
        let t = stats.trace.as_ref().expect("trace requested");
        assert!(t.spans.is_empty());
    }
}
