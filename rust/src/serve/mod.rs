//! Production serving subsystem: multi-op request lanes over a shared
//! admission queue, with a bucketed plan cache for O(1) amortized
//! dispatch — the online half of the paper, productionized.
//!
//! The paper's motivation (§2.1) is a serving system whose batch sizes
//! and sequence lengths change per request; the end-to-end framing of
//! SoD² and Relax (PAPERS.md) is the same system serving *many
//! operators* at once. This module generalizes the single-op
//! discrete-event loop of [`crate::coordinator::server`] into:
//!
//! * **Request lanes** ([`LaneClass`]): requests carry full
//!   [`TensorProgram`]s; each op class gets its own lane with its own
//!   [`LaneConfig`] batching policy. A lane merges *compatible*
//!   requests (equal [`merge_key`]) along the op's natural batch axis
//!   — token rows along M for GEMM, the leading batch dim for batched
//!   GEMM and the conv family, and the head-group batch (padding to
//!   the longest sequence) for attention chains.
//! * **Dispatch table** ([`crate::dispatch::DispatchTable`], enabled
//!   via [`ServeConfig::dispatch`]): the offline shape-space partition
//!   answers in-horizon batches at request time with ZERO warm-up —
//!   the shape→kernel decision was enumerated at compile time. Plans
//!   are provably identical to fresh selection.
//! * **Plan cache** ([`PlanCache`]): the beyond-horizon fallback —
//!   per-batch shape→kernel selection is memoized into padded-tile
//!   buckets, so steady-state dispatch is a hash lookup; the cached
//!   plan is guaranteed identical to fresh selection (see
//!   `serve/cache.rs`). Accounting is tri-state per request:
//!   table hit / cache hit / fresh scan ([`DispatchStats`]).
//! * **Scenario + telemetry**: [`scenario`] generates mixed traffic
//!   (BERT-style token streams interleaved with vision bursts);
//!   [`MixedStats`] reports per-lane latency percentiles, scheduling
//!   fraction and cache hit rates. The `serve` bench
//!   (`bench::exp_serve`) emits `BENCH_serve.json`.
//!
//! The old GEMM-only API (`coordinator::server::serve_trace`)
//! delegates to a one-lane instance of [`serve_mixed_trace`].
//!
//! At fleet scale ([`fleet`]), admission shards across N replicas —
//! each owning its own dispatch-table copy and plan-cache shards —
//! under deterministic routing, per-lane latency SLOs ([`slo`]) drive
//! deadline-aware batching and overload shedding/degradation, and an
//! optional `std::thread` worker pool with work-stealing executes the
//! independent (replica, lane) units — proven bit-identical to the
//! single-threaded discrete-event replay (the determinism oracle; see
//! the "Fleet serving" section of `docs/ARCHITECTURE.md`).

pub mod cache;
pub mod fleet;
pub mod scenario;
pub mod slo;

pub use cache::{CacheStats, PlanCache};
pub use fleet::{serve_fleet, FleetConfig, FleetStats, RoutePolicy};
pub use slo::{
    DropRecord, LaneSlo, OverloadPolicy, BATCH_BUDGET_FRACTION, LAUNCH_BUDGET_FRACTION,
};

use crate::analysis::Diagnostic;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::dispatch::{DispatchConfig, DispatchTable, TableData};
use crate::ir::{IterSpace, TensorProgram};
use crate::obs::{Span, Trace};
use crate::sim::Simulator;
use crate::util::json::Json;

/// Where one request's plan came from — the tri-state accounting of
/// the dispatch-table / plan-cache / fresh-selection stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// Answered by the compile-time dispatch table (zero warm-up).
    Table,
    /// Beyond the horizon, answered by a plan-cache hit.
    Cache,
    /// Beyond the horizon, first touch: a full selection scan ran
    /// (the only cold path left).
    Fresh,
}

impl PlanSource {
    /// Stable label used in trace span args and metric labels.
    pub fn name(self) -> &'static str {
        match self {
            PlanSource::Table => "table",
            PlanSource::Cache => "cache",
            PlanSource::Fresh => "fresh",
        }
    }
}

/// Per-request counts by [`PlanSource`]; sums to the request count.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchStats {
    pub table: u64,
    pub cache: u64,
    pub fresh: u64,
}

impl DispatchStats {
    pub fn total(&self) -> u64 {
        self.table + self.cache + self.fresh
    }

    /// Fraction of requests that never paid a fresh selection scan —
    /// 1.0 means no cold misses anywhere in the run.
    pub fn warm_start_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            (self.table + self.cache) as f64 / self.total() as f64
        }
    }
}

/// One serving request: a full tensor program plus its arrival time
/// (seconds from trace start).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    pub program: TensorProgram,
    pub arrive: f64,
}

/// Request lane classes: one discrete-event executor per class. The
/// conv family (`Conv2d`, grouped/depthwise included) shares one lane
/// — both merge along the image batch dim.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LaneClass {
    Gemm,
    BatchedGemm,
    Conv,
    Attention,
}

impl LaneClass {
    pub const ALL: [LaneClass; 4] = [
        LaneClass::Gemm,
        LaneClass::BatchedGemm,
        LaneClass::Conv,
        LaneClass::Attention,
    ];

    /// The lane a program is admitted to.
    pub fn of(p: &TensorProgram) -> LaneClass {
        match p {
            TensorProgram::Gemm { .. } => LaneClass::Gemm,
            TensorProgram::BatchedGemm { .. } => LaneClass::BatchedGemm,
            TensorProgram::Conv2d { .. } => LaneClass::Conv,
            TensorProgram::Attention { .. } => LaneClass::Attention,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LaneClass::Gemm => "gemm",
            LaneClass::BatchedGemm => "batched_gemm",
            LaneClass::Conv => "conv",
            LaneClass::Attention => "attention",
        }
    }

    /// Index into [`ServeConfig::lanes`].
    pub fn index(self) -> usize {
        match self {
            LaneClass::Gemm => 0,
            LaneClass::BatchedGemm => 1,
            LaneClass::Conv => 2,
            LaneClass::Attention => 3,
        }
    }

    /// The op kinds admitted to this lane — the inverse of
    /// [`LaneClass::of`], used by the SLO feasibility audit
    /// ([`crate::analysis::audit_slo`]) to bound every op a lane's
    /// deadline must cover.
    pub fn ops(self) -> &'static [crate::ir::OpKind] {
        use crate::ir::OpKind;
        match self {
            LaneClass::Gemm => &[OpKind::Gemm],
            LaneClass::BatchedGemm => &[OpKind::BatchedGemm],
            LaneClass::Conv => &[OpKind::Conv2d, OpKind::GroupedConv2d],
            LaneClass::Attention => &[OpKind::FusedAttention],
        }
    }
}

/// Batching policy of one lane (the per-lane half of the old
/// `ServerConfig`).
#[derive(Debug, Clone, Copy)]
pub struct LaneConfig {
    pub max_batch: usize,
    /// Max time the batcher waits after the first queued request —
    /// capped by the lane's deadline budget when an SLO is set
    /// ([`LaneSlo::window`]), so a tight-SLO lane never batches its
    /// deadline away.
    pub batch_window: f64,
    pub mode: HwMode,
    /// Latency objective + overload policy (default: no SLO — the
    /// batching behavior is bit-identical to the pre-SLO loop).
    pub slo: LaneSlo,
}

impl Default for LaneConfig {
    fn default() -> Self {
        LaneConfig {
            max_batch: 8,
            batch_window: 2e-3,
            mode: HwMode::Adaptive,
            slo: LaneSlo::default(),
        }
    }
}

/// What serving does with an ADOPTED schema-v3 table payload
/// ([`ServeConfig::adopt`]) before trusting it with every plan
/// decision. In-process builds ([`ServeConfig::dispatch`]) are exempt:
/// they are constructed by the same arithmetic the auditor re-proves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TablePolicy {
    /// Run the plan auditor over the payload and REFUSE it (falling
    /// back to an in-process build, or no table) unless the audit is
    /// clean — the production default: a shipped file is input, not
    /// truth.
    #[default]
    RefuseUnaudited,
    /// Audit, record the findings in [`MixedStats::table_diags`], but
    /// serve from the payload anyway (staging/debug).
    WarnUnaudited,
    /// Adopt without auditing (the pre-audit behavior; the strict
    /// loader's fingerprint/digest checks still apply).
    Trust,
}

/// Full serving configuration: one [`LaneConfig`] per lane class plus
/// the plan-cache capacity (`None` disables caching — every batch
/// runs fresh selection, the baseline the `serve` bench compares
/// against).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub lanes: [LaneConfig; 4],
    pub plan_cache: Option<usize>,
    /// Offline shape-space partitioning: when set, a
    /// [`DispatchTable`] is built for the selector BEFORE the trace
    /// starts (the compile-time half) and consulted first for every
    /// batch; the plan cache only sees the beyond-horizon tail.
    pub dispatch: Option<DispatchConfig>,
    /// A shipped schema-v3 table payload (the `"dispatch"` field of a
    /// library dump) to adopt INSTEAD of building in process —
    /// subject to [`ServeConfig::table_policy`].
    pub adopt: Option<Vec<TableData>>,
    /// Gate on adopted payloads (see [`TablePolicy`]).
    pub table_policy: TablePolicy,
    /// Record structured spans ([`crate::obs`]) into
    /// [`MixedStats::trace`] / [`FleetStats::trace`]. Spans are
    /// stamped from the event clock with values the loop already
    /// computed, so enabling this is ZERO-perturbation: every outcome
    /// is bit-identical to an untraced run (the fleet oracle proves
    /// it; see `tests/fleet_oracle.rs`).
    pub trace: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            lanes: [LaneConfig::default(); 4],
            plan_cache: Some(1024),
            dispatch: None,
            adopt: None,
            table_policy: TablePolicy::default(),
            trace: false,
        }
    }
}

impl ServeConfig {
    pub fn lane(&self, class: LaneClass) -> &LaneConfig {
        &self.lanes[class.index()]
    }

    pub fn lane_mut(&mut self, class: LaneClass) -> &mut LaneConfig {
        &mut self.lanes[class.index()]
    }

    /// The cache-disabled twin of this config (baseline runs).
    pub fn without_cache(&self) -> ServeConfig {
        ServeConfig { plan_cache: None, ..self.clone() }
    }

    /// This config with compile-time dispatch tables enabled.
    pub fn with_dispatch(&self, cfg: DispatchConfig) -> ServeConfig {
        ServeConfig { dispatch: Some(cfg), ..self.clone() }
    }

    /// This config adopting a shipped table payload under `policy`.
    pub fn adopting(&self, payload: Vec<TableData>, policy: TablePolicy) -> ServeConfig {
        ServeConfig { adopt: Some(payload), table_policy: policy, ..self.clone() }
    }

    /// This config with span tracing enabled (zero-perturbation; see
    /// [`ServeConfig::trace`]).
    pub fn traced(&self) -> ServeConfig {
        ServeConfig { trace: true, ..self.clone() }
    }
}

/// Resolve the serving-time dispatch table: adopted payload (gated by
/// [`TablePolicy`]) first, then an in-process build. Every refusal or
/// warning is returned as auditor diagnostics so telemetry shows WHY a
/// payload was not (or reluctantly was) trusted.
pub(crate) fn resolve_dispatch(
    selector: &Selector,
    cfg: &ServeConfig,
) -> (Option<DispatchTable>, Vec<Diagnostic>) {
    let mut diags = Vec::new();
    if let Some(payload) = &cfg.adopt {
        match DispatchTable::from_data_checked(selector, payload) {
            Err(d) => diags.push(d),
            Ok(table) => {
                if cfg.table_policy == TablePolicy::Trust {
                    return (Some(table), diags);
                }
                let report = crate::analysis::audit_dispatch_table(selector, &table);
                if report.diagnostics.is_empty() {
                    return (Some(table), diags);
                }
                diags.extend(report.diagnostics);
                if cfg.table_policy == TablePolicy::WarnUnaudited {
                    return (Some(table), diags);
                }
                // RefuseUnaudited: fall through to the in-process
                // build (or no table at all).
            }
        }
    }
    let built = cfg.dispatch.as_ref().map(|d| DispatchTable::for_selector(selector, d));
    (built, diags)
}

/// Two requests batch together iff their merge keys are equal: the key
/// is the program with its merge axis zeroed (token rows M for GEMM,
/// the batch dim for batched GEMM and conv, batch AND seq for
/// attention — attention batches pad shorter sequences to the longest,
/// so any two chains with equal (d, heads, dtype) are compatible).
pub fn merge_key(p: &TensorProgram) -> TensorProgram {
    let mut key = p.clone();
    match &mut key {
        TensorProgram::Gemm { m, .. } => *m = 0,
        TensorProgram::BatchedGemm { b, .. } => *b = 0,
        TensorProgram::Conv2d { n, .. } => *n = 0,
        TensorProgram::Attention { batch, seq, .. } => {
            *batch = 0;
            *seq = 0;
        }
    }
    key
}

/// Merge a batch of key-compatible programs into the one program the
/// lane executes: sum the merge axis; attention pads to the longest
/// sequence in the batch.
fn merge_programs(programs: &[&TensorProgram]) -> TensorProgram {
    let mut merged = programs[0].clone();
    for &p in &programs[1..] {
        match (&mut merged, p) {
            (TensorProgram::Gemm { m, .. }, TensorProgram::Gemm { m: m2, .. }) => *m += m2,
            (
                TensorProgram::BatchedGemm { b, .. },
                TensorProgram::BatchedGemm { b: b2, .. },
            ) => *b += b2,
            (TensorProgram::Conv2d { n, .. }, TensorProgram::Conv2d { n: n2, .. }) => {
                *n += n2
            }
            (
                TensorProgram::Attention { batch, seq, .. },
                TensorProgram::Attention { batch: b2, seq: s2, .. },
            ) => {
                *batch += b2;
                *seq = (*seq).max(*s2);
            }
            _ => unreachable!("merge across incompatible programs"),
        }
    }
    merged
}

/// The merged dynamic-axis extent (token rows / batch elements) a
/// program contributes — the lane-throughput unit, and the load
/// measure the fleet's least-loaded routing pre-pass accumulates.
pub(crate) fn dynamic_units(p: &TensorProgram) -> usize {
    match *p {
        TensorProgram::Gemm { m, .. } => m,
        TensorProgram::BatchedGemm { b, .. } => b,
        TensorProgram::Conv2d { n, .. } => n,
        TensorProgram::Attention { batch, .. } => batch,
    }
}

/// Execution backend of the serving loop, operator-generic.
pub trait LaneEngine {
    /// Run the selected kernel on the merged space; return the service
    /// time in seconds.
    fn execute(&mut self, space: IterSpace, sel: &Selection, selector: &Selector) -> f64;
    fn name(&self) -> &'static str;
}

/// Simulator-backed engine. A space served through a measurement-alias
/// library dispatches one alias block strategy per constituent kernel
/// (mirrors `bench::harness::Engine::time_space`).
pub struct SimLaneEngine {
    pub sim: Simulator,
}

impl LaneEngine for SimLaneEngine {
    fn execute(&mut self, space: IterSpace, sel: &Selection, selector: &Selector) -> f64 {
        let lib = &selector.libraries[sel.lib];
        let mult = if lib.op == space.op {
            1.0
        } else {
            space.op.spec().chain_kernels() as f64
        };
        self.sim.execute(lib.dtype, &selector.chain(sel)) * mult
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

/// Per-request serving record (one per admitted request).
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: u64,
    pub lane: LaneClass,
    /// Replica that served the request (0 outside the fleet).
    pub replica: usize,
    /// Event-clock latency (queueing + modeled scheduling + service) —
    /// deterministic under replay; see [`SCHED_OVERHEAD_SECS`].
    pub latency: f64,
    /// Event-clock instant the request's batch launched — the number
    /// the SLO regression tests pin (a tight-SLO lane never launches
    /// past its deadline budget).
    pub launch: f64,
    pub batch_size: usize,
    /// Where the batch's plan came from (table / cache / fresh).
    pub source: PlanSource,
    /// True when the batch was served under the overload policy's
    /// downgraded backend mode ([`OverloadPolicy::Degrade`]).
    pub degraded: bool,
    /// The constructed plan the request's batch executed.
    pub selection: Selection,
}

impl RequestOutcome {
    /// True when the request never paid a fresh selection scan.
    pub fn warm(&self) -> bool {
        self.source != PlanSource::Fresh
    }
}

/// Per-lane telemetry.
#[derive(Debug, Clone)]
pub struct LaneStats {
    pub class: LaneClass,
    pub metrics: Metrics,
    pub batches: usize,
    /// Σ merged dynamic-axis extents over the lane's batches.
    pub total_units: usize,
}

/// Full mixed-trace serving result.
#[derive(Debug, Clone, Default)]
pub struct MixedStats {
    pub lanes: Vec<LaneStats>,
    /// All outcomes, sorted by request id.
    pub outcomes: Vec<RequestOutcome>,
    pub cache: CacheStats,
    /// Per-request tri-state accounting (table / cache / fresh);
    /// `dispatch.total()` always equals `count()`.
    pub dispatch: DispatchStats,
    /// Offline build statistics of the dispatch table, when one was
    /// enabled (cells, merge compression, whether horizons clamped).
    pub dispatch_build: Option<crate::dispatch::BuildStats>,
    /// Auditor findings against an adopted table payload
    /// ([`ServeConfig::adopt`]): why it was refused
    /// ([`TablePolicy::RefuseUnaudited`]) or what it was adopted in
    /// spite of ([`TablePolicy::WarnUnaudited`]). Empty when no payload
    /// was adopted or the audit was clean.
    pub table_diags: Vec<Diagnostic>,
    /// Requests shed by the admission controller
    /// ([`OverloadPolicy::Drop`]), sorted by request id. Empty without
    /// SLOs — and `count() + drops.len()` is always the offered load.
    pub drops: Vec<DropRecord>,
    /// Max lane span (lanes run as concurrent executors).
    pub span_secs: f64,
    /// Structured span trace of the run, when [`ServeConfig::trace`]
    /// was set (event-clock stamped; see [`crate::obs`]).
    pub trace: Option<Trace>,
}

impl MixedStats {
    pub fn count(&self) -> usize {
        self.outcomes.len()
    }

    /// Requests offered to the server: served + shed.
    pub fn offered(&self) -> usize {
        self.outcomes.len() + self.drops.len()
    }

    /// Served requests that ran under the overload policy's
    /// downgraded mode.
    pub fn degraded(&self) -> usize {
        self.outcomes.iter().filter(|o| o.degraded).count()
    }

    /// Served requests at full fidelity (neither shed nor degraded) —
    /// `admitted() + degraded() + drops.len() == offered()` exactly.
    pub fn admitted(&self) -> usize {
        self.outcomes.len() - self.degraded()
    }

    pub fn total_sched_secs(&self) -> f64 {
        self.lanes.iter().map(|l| l.metrics.total_sched_secs()).sum()
    }

    pub fn total_exec_secs(&self) -> f64 {
        self.lanes.iter().map(|l| l.metrics.total_exec_secs()).sum()
    }

    /// Aggregate scheduling share across lanes (Fig. 14 style).
    pub fn sched_fraction(&self) -> f64 {
        let (s, e) = (self.total_sched_secs(), self.total_exec_secs());
        if s + e == 0.0 {
            0.0
        } else {
            s / (s + e)
        }
    }

    /// Aggregate (p50, p95, p99) request latency across lanes —
    /// same index formula as the per-lane [`Metrics`] percentiles.
    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut lat: Vec<f64> = self.outcomes.iter().map(|o| o.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            Metrics::pct(&lat, 0.5),
            Metrics::pct(&lat, 0.95),
            Metrics::pct(&lat, 0.99),
        )
    }
}

/// Modeled per-batch scheduling overhead charged on the event clock
/// (the paper's Fig. 14 scale on the A100 host; `bench::harness`
/// imports this constant). The clock deliberately does NOT advance by
/// this machine's wall-clock selection time: mixing wall time into
/// simulated seconds would double-count hardware differences AND make
/// replay non-deterministic (batch membership would depend on
/// selection jitter). The MEASURED selection/lookup wall-clock is
/// recorded in [`Metrics`] as the scheduling component instead —
/// that is the number the plan cache shrinks.
pub const SCHED_OVERHEAD_SECS: f64 = 2e-6;

/// Deterministic discrete-event serving loop over a mixed multi-op
/// trace. Requests must be sorted by arrival time; each lane runs the
/// same size/window batching policy as the old single-op loop, over
/// merge-key-compatible requests, and all lanes share one plan cache.
/// Replay is deterministic: the event clock advances by launch +
/// [`SCHED_OVERHEAD_SECS`] + service only.
pub fn serve_mixed_trace(
    engine: &mut dyn LaneEngine,
    selector: &Selector,
    cfg: &ServeConfig,
    requests: &[ServeRequest],
) -> MixedStats {
    debug_assert!(requests.windows(2).all(|w| w[0].arrive <= w[1].arrive));
    // The compile-time half: the dispatch table is built (or shipped
    // with the library — gated through the plan auditor per
    // [`ServeConfig::table_policy`]) BEFORE any request arrives — its
    // cost is offline, not serving wall-clock.
    let (dispatch, table_diags) = resolve_dispatch(selector, cfg);
    let mut plan_cache = cfg.plan_cache.map(|cap| PlanCache::for_selector(selector, cap));
    let mut stats = MixedStats {
        dispatch_build: dispatch.as_ref().map(|t| t.stats.clone()),
        table_diags,
        ..MixedStats::default()
    };
    let mut trace = cfg.trace.then(|| Trace {
        processes: vec![(0, "replica 0".to_string())],
        ..Trace::default()
    });
    for class in LaneClass::ALL {
        let lane_reqs: Vec<&ServeRequest> = requests
            .iter()
            .filter(|r| LaneClass::of(&r.program) == class)
            .collect();
        if lane_reqs.is_empty() {
            continue;
        }
        let run = serve_lane(
            engine,
            selector,
            cfg.lane(class),
            class,
            0,
            &lane_reqs,
            dispatch.as_ref(),
            plan_cache.as_mut(),
            cfg.trace,
        );
        stats.span_secs = stats.span_secs.max(run.stats.metrics.span_secs);
        stats.outcomes.extend(run.outcomes);
        stats.drops.extend(run.drops);
        stats.lanes.push(run.stats);
        if let Some(t) = trace.as_mut() {
            t.threads.push((0, class.index() as u64, class.name().to_string()));
            t.spans.extend(run.trace);
        }
    }
    stats.trace = trace;
    stats.outcomes.sort_by_key(|o| o.id);
    stats.drops.sort_by_key(|d| d.id);
    stats.cache = plan_cache.map(|c| c.stats).unwrap_or_default();
    for o in &stats.outcomes {
        match o.source {
            PlanSource::Table => stats.dispatch.table += 1,
            PlanSource::Cache => stats.dispatch.cache += 1,
            PlanSource::Fresh => stats.dispatch.fresh += 1,
        }
    }
    stats
}

/// One lane's full discrete-event result: the unit of parallel work in
/// the fleet executor — a pure function of (engine seed, selector,
/// lane config, request list, table), so any execution order produces
/// bit-identical runs.
#[derive(Debug)]
pub(crate) struct LaneRun {
    pub(crate) stats: LaneStats,
    pub(crate) outcomes: Vec<RequestOutcome>,
    pub(crate) drops: Vec<DropRecord>,
    /// Event-clock spans of this lane's run (empty unless tracing was
    /// requested). Purely additive output — recording reads only
    /// values the loop already computed.
    pub(crate) trace: Vec<Span>,
}

/// One lane's discrete-event loop: the old `serve_trace` core,
/// generalized to merge-key batching and (when the lane carries an
/// SLO) deadline-aware batching + admission control. Incompatible
/// requests never merge — they stay queued and the next batch forms
/// from the earliest pending request.
///
/// SLO semantics (all functions of the event clock — replay stays
/// bit-identical): the batching window is capped at the deadline
/// budget ([`LaneSlo::window`]), the window close is capped at the
/// head's launch cutoff ([`LaneSlo::launch_cutoff`]), and a head whose
/// deadline already passed when the server freed up is shed
/// ([`OverloadPolicy::Drop`] — control-plane, no clock charge) or
/// served immediately under the downgrade mode
/// ([`OverloadPolicy::Degrade`]). With the default no-op SLO every
/// branch reduces to the legacy rule exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_lane(
    engine: &mut dyn LaneEngine,
    selector: &Selector,
    cfg: &LaneConfig,
    class: LaneClass,
    replica: usize,
    requests: &[&ServeRequest],
    dispatch: Option<&DispatchTable>,
    mut plan_cache: Option<&mut PlanCache>,
    traced: bool,
) -> LaneRun {
    let mut metrics = Metrics::default();
    let mut outcomes = Vec::new();
    let mut drops = Vec::new();
    // Span recording is write-only bookkeeping over values the loop
    // computes anyway: no wall-clock reads, no extra branches on
    // serving state — the zero-perturbation invariant the fleet
    // oracle's traced-vs-untraced leg pins bitwise.
    let mut trace: Vec<Span> = Vec::new();
    let (pid, tid) = (replica as u64, class.index() as u64);
    let mut batches = 0usize;
    let mut total_units = 0usize;
    let mut clock = 0.0f64;
    let mut served = vec![false; requests.len()];
    let mut pending = requests.len();
    let mut next = 0usize;
    loop {
        while next < requests.len() && served[next] {
            next += 1;
        }
        if next >= requests.len() {
            break;
        }
        // Server becomes free at `clock`; the next batch forms from the
        // earliest pending request and its merge-key-compatible peers.
        let first = requests[next];
        let key = merge_key(&first.program);
        let open = clock.max(first.arrive);

        // Admission control: a head whose deadline already passed when
        // the server freed up triggers the overload policy.
        let mut mode = cfg.mode;
        let mut degraded = false;
        if let Some(d) = cfg.slo.deadline {
            if open > first.arrive + d {
                match cfg.slo.policy {
                    OverloadPolicy::ServeAnyway => {}
                    OverloadPolicy::Drop => {
                        // Shed ONE head at a time: the decision charges
                        // nothing to the clock, and the freed capacity
                        // goes to the next pending request.
                        drops.push(DropRecord {
                            id: first.id,
                            lane: class,
                            replica,
                            decided_at: open,
                            miss_by: open - (first.arrive + d),
                        });
                        if traced {
                            trace.push(
                                Span::instant("drop", "serve", pid, tid, open)
                                    .arg("id", Json::num(first.id as f64))
                                    .arg(
                                        "miss_by_us",
                                        Json::num((open - (first.arrive + d)) * 1e6),
                                    )
                                    .arg("policy", Json::str(cfg.slo.policy.name())),
                            );
                        }
                        metrics.dropped += 1;
                        served[next] = true;
                        pending -= 1;
                        continue;
                    }
                    OverloadPolicy::Degrade(m) => {
                        mode = m;
                        degraded = true;
                    }
                }
            }
        }

        // The window close: the (deadline-capped) batching window,
        // never past the head's launch cutoff. A degraded batch closes
        // immediately — only already-arrived peers merge.
        let close = if degraded {
            open
        } else {
            let close = open + cfg.slo.window(cfg.batch_window);
            match cfg.slo.launch_cutoff(first.arrive) {
                Some(cutoff) => close.min(cutoff.max(open)),
                None => close,
            }
        };
        let mut batch = vec![next];
        for (j, r) in requests.iter().enumerate().skip(next + 1) {
            if batch.len() >= cfg.max_batch || r.arrive > close {
                break;
            }
            if !served[j] && merge_key(&r.program) == key {
                batch.push(j);
            }
        }
        // Batch launch time: when the window closes or the batch fills,
        // but never before the server is free — identical to the old
        // single-op rule.
        let last_arrive = requests[*batch.last().unwrap()].arrive;
        // Unserved requests outside this batch (every unserved index is
        // >= next, so the counter is exact) — O(1), not a trace rescan.
        let more_pending = pending > batch.len();
        let launch = if degraded {
            open
        } else if batch.len() == cfg.max_batch || !more_pending {
            last_arrive.max(open)
        } else {
            close
        };

        let programs: Vec<&TensorProgram> =
            batch.iter().map(|&j| &requests[j].program).collect();
        let merged = merge_programs(&programs);
        let space = merged.space();
        // Tri-state resolution: compile-time table first, then the
        // plan cache (beyond-horizon fallback), then a fresh scan.
        // `mode` is the lane's configured mode, or the overload
        // downgrade — the cache key and any (op, mode) table both
        // include the mode, so the tri-state stack stays sound.
        let table_sel = dispatch.and_then(|t| t.select(selector, space, mode));
        let (sel, source) = match table_sel {
            Some(sel) => (sel, PlanSource::Table),
            None => match plan_cache.as_deref_mut() {
                Some(c) => {
                    let hits0 = c.stats.hits;
                    let sel = c
                        .select(selector, space, mode)
                        .expect("selector must handle any shape (sample-free)");
                    let source = if c.stats.hits > hits0 {
                        PlanSource::Cache
                    } else {
                        PlanSource::Fresh
                    };
                    (sel, source)
                }
                None => (
                    selector
                        .select(space, mode)
                        .expect("selector must handle any shape (sample-free)"),
                    PlanSource::Fresh,
                ),
            },
        };
        let service = engine.execute(space, &sel, selector);
        let done = launch + SCHED_OVERHEAD_SECS + service;
        let bsz = batch.len();
        let merged_flops = space.flops();
        let own: Vec<f64> = programs.iter().map(|p| p.flops()).collect();
        let own_sum: f64 = own.iter().sum();
        for (bi, &j) in batch.iter().enumerate() {
            let r = requests[j];
            let latency = done - r.arrive;
            metrics.record(
                latency,
                sel.select_secs / bsz as f64,
                service / bsz as f64,
                merged_flops * own[bi] / own_sum,
            );
            if degraded {
                metrics.degraded += 1;
            }
            outcomes.push(RequestOutcome {
                id: r.id,
                lane: class,
                replica,
                latency,
                launch,
                batch_size: bsz,
                source,
                degraded,
                selection: sel.clone(),
            });
            served[j] = true;
        }
        if traced {
            for &j in &batch {
                trace.push(
                    Span::instant("admit", "serve", pid, tid, requests[j].arrive)
                        .arg("id", Json::num(requests[j].id as f64)),
                );
            }
            if degraded {
                trace.push(
                    Span::instant("degrade", "serve", pid, tid, open)
                        .arg("policy", Json::str(cfg.slo.policy.name())),
                );
            }
            trace.push(
                Span::complete("form", "serve", pid, tid, open, launch - open)
                    .arg("batch", Json::num(bsz as f64)),
            );
            // The plan instant is EVENT-stamped at launch; the measured
            // selection wall-clock rides along as data (`select_wall_us`
            // — the Fig. 14 scheduling component), never as a timestamp.
            trace.push(
                Span::instant("plan", "serve", pid, tid, launch)
                    .arg("source", Json::str(source.name()))
                    .arg("lib", Json::num(sel.lib as f64))
                    .arg("kernel", Json::num(sel.kernel as f64))
                    .arg("select_wall_us", Json::num(sel.select_secs * 1e6)),
            );
            trace.push(Span::complete("sched", "serve", pid, tid, launch, SCHED_OVERHEAD_SECS));
            trace.push(
                Span::complete(
                    "exec",
                    "serve",
                    pid,
                    tid,
                    launch + SCHED_OVERHEAD_SECS,
                    service,
                )
                .arg("batch", Json::num(bsz as f64))
                .arg("degraded", Json::Bool(degraded)),
            );
        }
        batches += 1;
        total_units += dynamic_units(&merged);
        pending -= bsz;
        clock = done;
    }
    metrics.span_secs = clock;
    LaneRun {
        stats: LaneStats { class, metrics, batches, total_units },
        outcomes,
        drops,
        trace,
    }
}

/// Per-worker executor telemetry: how many (replica, lane) units the
/// worker ran, and how many of those it STOLE from another worker's
/// queue. Telemetry only — steal counts depend on thread timing and
/// are deliberately excluded from the determinism oracle's
/// fingerprint (serving OUTCOMES stay bitwise invariant; which worker
/// ran a unit does not).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerStats {
    pub executed: usize,
    pub stolen: usize,
}

/// Deterministic parallel executor over independent work units: run
/// `job(u)` for every `u` in `0..seed_order.len()` and return the
/// results in UNIT-INDEX order regardless of worker count, plus
/// per-worker [`WorkerStats`].
///
/// `workers <= 1` is the sequential discrete-event oracle (units run
/// in index order on the calling thread). With more workers, a
/// `std::thread` pool is seeded round-robin from `seed_order` (the
/// caller's priority order — a scheduling hint) and idle workers
/// STEAL from the back of other workers' queues. Determinism is by
/// construction, not by locking discipline: each unit is an
/// independent pure job writing only its own indexed result slot, so
/// scheduling affects wall-clock and nothing else — the property the
/// fleet oracle test (`tests/fleet_oracle.rs`) checks bitwise across
/// worker counts.
pub(crate) fn execute_units<R: Send>(
    workers: usize,
    seed_order: &[usize],
    job: impl Fn(usize) -> R + Sync,
) -> (Vec<R>, Vec<WorkerStats>) {
    use std::collections::VecDeque;
    use std::sync::Mutex;
    let n = seed_order.len();
    debug_assert!({
        let mut s: Vec<usize> = seed_order.to_vec();
        s.sort_unstable();
        s == (0..n).collect::<Vec<_>>()
    });
    if workers <= 1 {
        let results = (0..n).map(job).collect();
        return (results, vec![WorkerStats { executed: n, stolen: 0 }]);
    }
    let queues: Vec<Mutex<VecDeque<usize>>> =
        (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
    for (i, &u) in seed_order.iter().enumerate() {
        queues[i % workers].lock().unwrap().push_back(u);
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut worker_stats = vec![WorkerStats::default(); workers];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let queues = &queues;
                let job = &job;
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    let mut stats = WorkerStats::default();
                    loop {
                        // Own queue front first, then steal from the
                        // BACK of the others (classic stealing keeps
                        // contention off the owners' hot ends). No unit
                        // ever re-enqueues work, so all-empty means
                        // drained for good.
                        let u = queues[w].lock().unwrap().pop_front().map(|u| (u, false)).or_else(
                            || {
                                (0..queues.len()).filter(|&o| o != w).find_map(|o| {
                                    queues[o]
                                        .lock()
                                        .unwrap()
                                        .pop_back()
                                        .map(|u| (u, true))
                                })
                            },
                        );
                        match u {
                            Some((u, stolen)) => {
                                stats.executed += 1;
                                stats.stolen += usize::from(stolen);
                                done.push((u, job(u)));
                            }
                            None => break,
                        }
                    }
                    (done, stats)
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            let (done, stats) = h.join().expect("fleet worker panicked");
            worker_stats[w] = stats;
            for (u, r) in done {
                slots[u] = Some(r);
            }
        }
    });
    let results = slots
        .into_iter()
        .map(|r| r.expect("every unit executes exactly once"))
        .collect();
    (results, worker_stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::DType;

    fn gemm(m: usize) -> TensorProgram {
        TensorProgram::Gemm { m, n: 768, k: 768, dtype: DType::F32 }
    }

    fn conv(n: usize) -> TensorProgram {
        TensorProgram::conv2d((n, 28, 28, 64), (3, 3, 128), (1, 1, 1), DType::F32).unwrap()
    }

    fn attn(batch: usize, seq: usize) -> TensorProgram {
        TensorProgram::attention((batch, seq), (768, 12), DType::F32).unwrap()
    }

    fn selector() -> Selector {
        scenario::demo_selector(5)
    }

    #[test]
    fn merge_keys_partition_by_shape_family() {
        assert_eq!(merge_key(&gemm(1)), merge_key(&gemm(400)));
        assert_ne!(
            merge_key(&gemm(1)),
            merge_key(&TensorProgram::Gemm { m: 1, n: 768, k: 1024, dtype: DType::F32 })
        );
        assert_eq!(merge_key(&conv(1)), merge_key(&conv(32)));
        // Attention merges across BOTH batch and sequence (padding).
        assert_eq!(merge_key(&attn(1, 77)), merge_key(&attn(4, 476)));
        assert_ne!(
            merge_key(&attn(1, 77)),
            merge_key(&TensorProgram::attention((1, 77), (1024, 16), DType::F32).unwrap())
        );
    }

    #[test]
    fn merged_programs_sum_the_merge_axis() {
        let g = merge_programs(&[&gemm(3), &gemm(5), &gemm(7)]);
        assert_eq!(g, gemm(15));
        let c = merge_programs(&[&conv(2), &conv(6)]);
        assert_eq!(c, conv(8));
        let a = merge_programs(&[&attn(1, 77), &attn(2, 128), &attn(1, 64)]);
        assert_eq!(a, attn(4, 128)); // batch summed, seq padded to max
        assert!(a.validate().is_ok());
    }

    #[test]
    fn mixed_trace_serves_every_lane_once() {
        let s = selector();
        let mut requests = Vec::new();
        for i in 0..30u64 {
            let program = match i % 3 {
                0 => gemm(16 + i as usize),
                1 => conv(1 + (i as usize % 4)),
                _ => attn(1, 64),
            };
            requests.push(ServeRequest { id: i, program, arrive: 1e-4 * i as f64 });
        }
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &ServeConfig::default(), &requests);
        assert_eq!(stats.count(), 30);
        let ids: Vec<u64> = stats.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids, (0..30).collect::<Vec<_>>());
        // Three lanes active (gemm, conv, attention), none lost.
        assert_eq!(stats.lanes.len(), 3);
        assert!(stats.span_secs > 0.0);
        let (p50, p95, p99) = stats.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
    }

    #[test]
    fn incompatible_requests_never_merge() {
        let s = selector();
        // Two interleaved gemm widths arriving simultaneously: batches
        // must be key-pure, so each batch's size stays within its own
        // key's population.
        let wide = |m: usize| TensorProgram::Gemm { m, n: 1024, k: 768, dtype: DType::F32 };
        let mut requests = Vec::new();
        for i in 0..16u64 {
            let program = if i % 2 == 0 { gemm(8) } else { wide(8) };
            requests.push(ServeRequest { id: i, program, arrive: 1e-6 * i as f64 });
        }
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &ServeConfig::default(), &requests);
        assert_eq!(stats.count(), 16);
        // All 16 share the gemm lane; a merged batch of mixed keys
        // would produce a single 16-deep batch, key-purity caps it at 8.
        assert!(stats.outcomes.iter().all(|o| o.batch_size <= 8));
        let lane = &stats.lanes[0];
        assert!(lane.batches >= 2);
    }

    #[test]
    fn dispatch_tri_state_counts_and_matches_fresh_plans() {
        use crate::dispatch::DispatchConfig;
        use crate::ir::OpKind;
        let s = selector();
        // Horizon covers the gemm template at small m only; arrivals
        // are spaced past the batch window so every batch is one
        // request and the counts are exact.
        let dcfg = DispatchConfig {
            ops: vec![OpKind::Gemm],
            ..DispatchConfig::default()
        }
        .with_op_horizons(OpKind::Gemm, &[64, 768, 768]);
        let mut cfg = ServeConfig::default().with_dispatch(dcfg);
        for class in LaneClass::ALL {
            cfg.lane_mut(class).max_batch = 1;
        }
        let requests: Vec<ServeRequest> = (0..12u64)
            .map(|i| ServeRequest {
                id: i,
                program: gemm(if i % 2 == 0 { 16 } else { 500 }),
                arrive: 5e-3 * i as f64,
            })
            .collect();
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats = serve_mixed_trace(&mut engine, &s, &cfg, &requests);
        // Tri-state accounting sums to the request count, with every
        // outcome kind represented: m=16 is table-answered, the first
        // m=500 batch is the one fresh scan, its repeats hit the cache.
        assert_eq!(stats.dispatch.total(), 12);
        assert_eq!(stats.dispatch.table, 6);
        assert_eq!(stats.dispatch.fresh, 1);
        assert_eq!(stats.dispatch.cache, 5);
        assert!((stats.dispatch.warm_start_rate() - 11.0 / 12.0).abs() < 1e-12);
        for o in &stats.outcomes {
            assert_eq!(o.warm(), o.source != PlanSource::Fresh);
        }
        // Plans are identical to a run with no table and no cache.
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let plain = ServeConfig {
            plan_cache: None,
            dispatch: None,
            lanes: cfg.lanes,
            ..ServeConfig::default()
        };
        let fresh = serve_mixed_trace(&mut e2, &s, &plain, &requests);
        assert_eq!(fresh.dispatch.fresh, 12);
        for (a, b) in stats.outcomes.iter().zip(&fresh.outcomes) {
            assert_eq!(a.id, b.id);
            assert!(
                a.selection.same_plan(&b.selection),
                "plan diverged for request {} ({:?})",
                a.id,
                a.source
            );
        }
    }

    #[test]
    fn adopted_payloads_are_gated_by_the_plan_auditor() {
        use crate::dispatch::{table_digest, DispatchConfig};
        use crate::ir::OpKind;
        let s = selector();
        let dcfg = DispatchConfig { ops: vec![OpKind::Gemm], ..DispatchConfig::default() }
            .with_op_horizons(OpKind::Gemm, &[64, 768, 768]);
        let payload = DispatchTable::for_selector(&s, &dcfg).to_data(&s);

        let mut cfg = ServeConfig::default();
        cfg.plan_cache = None;
        for class in LaneClass::ALL {
            cfg.lane_mut(class).max_batch = 1;
        }
        let requests: Vec<ServeRequest> = (0..6u64)
            .map(|i| ServeRequest { id: i, program: gemm(16), arrive: 5e-3 * i as f64 })
            .collect();
        let run = |cfg: &ServeConfig| {
            let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
            serve_mixed_trace(&mut engine, &s, cfg, &requests)
        };

        // A clean payload is audited and adopted under the default
        // (refuse-unaudited) policy: every in-horizon request is a
        // table hit and no findings are recorded.
        let clean = run(&cfg.adopting(payload.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(clean.dispatch.table, 6);
        assert!(clean.table_diags.is_empty());

        // Forge a digest-consistent payload the strict loader accepts
        // but whose edge the auditor proves off the fine lattice.
        let mut forged = payload.clone();
        let table = DispatchTable::from_data_checked(&s, &payload).unwrap();
        let mut tampered = false;
        'search: for (ti, t) in table.tables.iter().enumerate() {
            for a in 0..t.edges.len() {
                let mut extents: Vec<usize> = s
                    .eligible_fast(s.serving_op(t.op), t.mode)
                    .iter()
                    .map(|&fi| s.fast[fi].l1[a])
                    .collect();
                extents.sort_unstable();
                extents.dedup();
                let fine =
                    crate::dispatch::axis_edges(&extents, *t.edges[a].last().unwrap());
                for j in 0..t.edges[a].len().saturating_sub(1) {
                    let bumped = t.edges[a][j] + 1;
                    if bumped < t.edges[a][j + 1] && fine.binary_search(&bumped).is_err() {
                        forged[ti].edges[a][j] = bumped;
                        forged[ti].digest = table_digest(
                            forged[ti].op,
                            &forged[ti].mode,
                            &forged[ti].edges,
                            &forged[ti].runs,
                            forged[ti].clamped,
                        );
                        tampered = true;
                        break 'search;
                    }
                }
            }
        }
        assert!(tampered, "no tamperable off-lattice edge found");

        // RefuseUnaudited with no in-process build: the payload is
        // refused, every request pays fresh selection, and the refusal
        // reason is on record.
        let refused = run(&cfg.adopting(forged.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(refused.dispatch.table, 0);
        assert_eq!(refused.dispatch.fresh, 6);
        assert!(refused
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));

        // ... and WITH an in-process build configured, refusal falls
        // back to it: table hits return, findings stay on record.
        let fallback = run(&cfg
            .with_dispatch(dcfg.clone())
            .adopting(forged.clone(), TablePolicy::RefuseUnaudited));
        assert_eq!(fallback.dispatch.table, 6);
        assert!(fallback
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));

        // WarnUnaudited serves from the forged payload anyway but keeps
        // the findings; Trust skips the audit entirely.
        let warned = run(&cfg.adopting(forged.clone(), TablePolicy::WarnUnaudited));
        assert!(warned.dispatch.table > 0);
        assert!(warned
            .table_diags
            .iter()
            .any(|d| d.code == "dispatch.edge_off_lattice"));
        let trusted = run(&cfg.adopting(forged, TablePolicy::Trust));
        assert!(trusted.dispatch.table > 0);
        assert!(trusted.table_diags.is_empty());

        // A loader-level refusal (foreign fingerprint) surfaces its own
        // diagnostic code even under Trust — the strict loader is not
        // subject to policy.
        let mut foreign = payload;
        foreign[0].fingerprint ^= 1;
        let stats = run(&cfg.adopting(foreign, TablePolicy::Trust));
        assert_eq!(stats.dispatch.table, 0);
        assert!(stats
            .table_diags
            .iter()
            .any(|d| d.code == "load.fingerprint_mismatch"));
    }

    #[test]
    fn cache_disabled_and_enabled_pick_identical_plans() {
        let s = selector();
        let requests: Vec<ServeRequest> = (0..24u64)
            .map(|i| ServeRequest {
                id: i,
                program: attn(1, 64 + 64 * (i as usize % 3)),
                arrive: 2e-4 * i as f64,
            })
            .collect();
        let cfg = ServeConfig::default();
        let mut e1 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let cached = serve_mixed_trace(&mut e1, &s, &cfg, &requests);
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let fresh = serve_mixed_trace(&mut e2, &s, &cfg.without_cache(), &requests);
        assert!(cached.cache.hits > 0);
        assert_eq!(fresh.cache.lookups(), 0);
        for (a, b) in cached.outcomes.iter().zip(&fresh.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.batch_size, b.batch_size);
            assert!(
                a.selection.same_plan(&b.selection),
                "plan diverged for request {}: {:?} vs {:?}",
                a.id,
                a.selection,
                b.selection
            );
        }
    }

    #[test]
    fn tracing_is_zero_perturbation_and_spans_reconcile() {
        let s = selector();
        let requests: Vec<ServeRequest> = (0..40u64)
            .map(|i| {
                let program = match i % 3 {
                    0 => gemm(16 + i as usize),
                    1 => conv(1 + (i as usize % 4)),
                    _ => attn(1, 64),
                };
                ServeRequest { id: i, program, arrive: 1e-4 * i as f64 }
            })
            .collect();
        let cfg = ServeConfig::default();
        let mut e1 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let plain = serve_mixed_trace(&mut e1, &s, &cfg, &requests);
        let mut e2 = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let traced = serve_mixed_trace(&mut e2, &s, &cfg.traced(), &requests);
        // Zero perturbation: recording spans must not move a single bit
        // of any outcome.
        assert!(plain.trace.is_none());
        assert_eq!(plain.outcomes.len(), traced.outcomes.len());
        for (a, b) in plain.outcomes.iter().zip(&traced.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.launch.to_bits(), b.launch.to_bits());
            assert_eq!(a.batch_size, b.batch_size);
            assert_eq!(a.source, b.source);
            assert!(a.selection.same_plan(&b.selection));
        }
        // The trace reconciles with the outcome log: one admit instant
        // per request; one form/plan/sched/exec span per batch; every
        // span stamped from the event clock.
        let t = traced.trace.as_ref().expect("trace requested");
        let count = |name: &str| t.spans.iter().filter(|sp| sp.name == name).count();
        assert_eq!(count("admit"), traced.outcomes.len());
        let batches: usize = traced.lanes.iter().map(|l| l.batches).sum();
        for name in ["form", "plan", "sched", "exec"] {
            assert_eq!(count(name), batches, "{name} spans vs {batches} batches");
        }
        assert!(t.spans.iter().all(|sp| sp.clock == crate::obs::SpanClock::Event));
        assert_eq!(t.threads.len(), traced.lanes.len());
    }

    #[test]
    fn zero_request_stats_are_well_defined_zeros() {
        // The empty-trace path: every rate and percentile must answer
        // 0.0, never NaN, and a requested trace still materializes.
        let s = selector();
        let mut engine = SimLaneEngine { sim: Simulator::new(presets::a100(), 5) };
        let stats =
            serve_mixed_trace(&mut engine, &s, &ServeConfig::default().traced(), &[]);
        assert_eq!(stats.count(), 0);
        assert_eq!(stats.latency_percentiles(), (0.0, 0.0, 0.0));
        assert_eq!(stats.sched_fraction(), 0.0);
        assert_eq!(stats.dispatch.warm_start_rate(), 0.0);
        assert_eq!(stats.cache.hit_rate(), 0.0);
        let t = stats.trace.as_ref().expect("trace requested");
        assert!(t.spans.is_empty());
    }
}
