//! Mixed-traffic scenario generator: BERT-style token traffic
//! interleaved with ResNet/MobileNet vision bursts, with every request
//! template drawn from the model zoo ([`crate::models::request_ops`]).
//!
//! Production streams are not uniform random: token traffic clusters
//! at a few context buckets (the paper's BERT evaluation sweeps a
//! fixed seq-length grid), and vision requests arrive in camera-batch
//! bursts of near-simultaneous frames with fixed geometry. That
//! clustering is exactly what the bucketed plan cache exploits —
//! merged batch shapes recur, so steady-state dispatch is a hash
//! lookup. The generator is deterministic from its seed.

use crate::compiler::{compile, CompileOpts};
use crate::coordinator::Selector;
use crate::cost::hybrid::AnalyzerConfig;
use crate::dispatch::DispatchConfig;
use crate::hw::presets;
use crate::ir::{DType, OpKind, TensorProgram};
use crate::models::{self, Model};
use crate::profiler::SimProfiler;
use crate::serve::{LaneClass, LaneConfig, ServeConfig, ServeRequest};
use crate::sim::Simulator;
use crate::util::rng::Rng;

/// Token context buckets the language streams draw from.
const SEQ_BUCKETS: [usize; 3] = [64, 128, 256];

/// Generate a mixed multi-op request trace: ~40% BERT QKV token GEMMs,
/// ~30% BERT attention chains (both at context-bucket sequence
/// lengths), ~30% vision bursts — ResNet stem convolutions and
/// MobileNet depthwise blocks, 2–4 near-simultaneous frames per burst
/// at camera batch 1–2. Arrivals are Poisson-ish with the given mean
/// gap; the trace is sorted by arrival and ids are assigned in arrival
/// order.
pub fn mixed_trace(
    n_requests: usize,
    mean_interarrival: f64,
    seed: u64,
    dtype: DType,
) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    // Request templates from the model zoo: per context bucket the
    // BERT [QKV, attention] pair; per camera batch the ResNet stem and
    // the MobileNet depthwise block.
    let lm: Vec<Vec<TensorProgram>> = SEQ_BUCKETS
        .iter()
        .map(|&seq| models::request_ops(Model::Bert, seq, dtype))
        .collect();
    let vision: Vec<[TensorProgram; 2]> = (1..=2usize)
        .map(|b| {
            let resnet = models::request_ops(Model::ResNet50, b, dtype);
            let mobile = models::request_ops(Model::MobileNet, b, dtype);
            [resnet[0].clone(), mobile[1].clone()]
        })
        .collect();

    let mut t = 0.0f64;
    let mut out: Vec<ServeRequest> = Vec::with_capacity(n_requests);
    while out.len() < n_requests {
        t += rng.exp(mean_interarrival);
        let roll = rng.f64();
        if roll < 0.7 {
            // Token traffic: QKV projection or attention chain at a
            // context-bucket sequence length.
            let bucket = rng.usize(0, SEQ_BUCKETS.len() - 1);
            let which = usize::from(roll >= 0.4);
            out.push(ServeRequest {
                id: out.len() as u64,
                program: lm[bucket][which].clone(),
                arrive: t,
                steps: 1,
            });
        } else {
            // Vision burst: a few camera frames land almost together.
            let kind = usize::from(roll >= 0.9); // 0 = ResNet, 1 = depthwise
            let frames = rng.usize(2, 4);
            for _ in 0..frames {
                if out.len() >= n_requests {
                    break;
                }
                t += rng.exp(mean_interarrival / 8.0);
                let batch = rng.usize(1, 2);
                out.push(ServeRequest {
                    id: out.len() as u64,
                    program: vision[batch - 1][kind].clone(),
                    arrive: t,
                    steps: 1,
                });
            }
        }
    }
    out
}

/// The selector the mixed scenario is served with — ONE definition
/// shared by the `serve` bench, the `vortex serve --mixed` CLI, the
/// `mixed_serving` example and the acceptance tests, so their library
/// sets can never drift apart: a GEMM F32 library (serves conv via
/// implicit GEMM) plus a batched-GEMM F32 library (serves grouped conv
/// and attention chains via the measurement-alias fixpoint), compiled
/// offline on the simulated A100.
pub fn demo_selector(seed: u64) -> Selector {
    let hw = presets::a100();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let libs = vec![
        compile(&hw, OpKind::Gemm, DType::F32, &cfg, &mut prof, &CompileOpts::default())
            .library,
        compile(
            &hw,
            OpKind::BatchedGemm,
            DType::F32,
            &cfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library,
    ];
    Selector::new(hw, libs)
}

/// The lane configuration the mixed scenario is served with: modest
/// per-lane batch caps (merged shapes stay within the recurring bucket
/// set) under the default 2 ms batching window.
pub fn serving_config() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    for class in LaneClass::ALL {
        *cfg.lane_mut(class) = LaneConfig { max_batch: 4, ..LaneConfig::default() };
    }
    cfg
}

/// The offline dispatch-table configuration matching this scenario's
/// advertised shape envelope — per-op horizons covering every merged
/// batch the generator + lane caps can produce, so in the nominal case
/// the whole trace is answered at compile time (zero cold misses):
///
/// * GEMM: QKV token rows merge up to `max_batch (4) × top context
///   bucket (256)`; (n, k) are the BERT projection (2304, 768).
/// * Attention: 12 head groups × up to 4 merged chains, sequences
///   padded to the 256 bucket, head dim 64.
/// * Conv: the ResNet stem's implicit GEMM at up to 8 merged frames
///   (4 requests × camera batch 2) of 112×112 output — M = 100352 —
///   with (cout, kh·kw·cin) = (64, 147).
/// * Grouped conv: the MobileNet depthwise block (32 groups, same
///   merged-frame envelope, 1 output channel per group, 3·3·1 taps).
/// * Causal decode: up to 4 merged sequences × 12 head groups, one
///   query per step, KV depth up to the 256 context bucket, head dim
///   64 — every in-horizon decode step is table-answered, which is
///   what makes per-token dispatch zero-scan ([`decode_trace`]
///   generates in-horizon sequences by construction).
///
/// This is capacity planning (a service-level envelope), not shape
/// sampling: no profile of the traffic is taken, and shapes beyond the
/// envelope still serve exactly via the plan-cache fallback. The cell
/// budget bounds the offline build; if a library's extent set is so
/// fine that the envelope exceeds it, horizons clamp (recorded in
/// [`crate::dispatch::BuildStats::clamped`]) and the tail degrades to
/// the cache — correctness is never traded.
pub fn dispatch_config() -> DispatchConfig {
    DispatchConfig { max_cells: 1 << 22, ..DispatchConfig::default() }
        .with_op_horizons(OpKind::Gemm, &[1024, 2304, 768])
        .with_op_horizons(OpKind::FusedAttention, &[48, 256, 256, 64])
        .with_op_horizons(OpKind::Conv2d, &[100_352, 64, 147])
        .with_op_horizons(OpKind::GroupedConv2d, &[32, 100_352, 1, 9])
        .with_op_horizons(OpKind::CausalAttention, &[48, 8, 256, 64])
}

/// Overload scenario: `n_requests` land in one burst across EVERY lane
/// class (token GEMMs, raw batched GEMMs, attention chains, strided +
/// depthwise convs), with microsecond-scale interarrivals — far faster
/// than any lane can drain, so every lane's queue grows without bound
/// for the duration of the burst. This is the trace the overload tests
/// drive: under tight deadlines an admission controller MUST shed or
/// degrade, and adding replicas must monotonically relieve the tail.
/// Deterministic from the seed; sorted by arrival, ids in arrival
/// order; every template is servable by [`demo_selector`].
pub fn burst_trace(n_requests: usize, seed: u64, dtype: DType) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    let lm = models::request_ops(Model::Bert, 128, dtype);
    let resnet = models::request_ops(Model::ResNet50, 2, dtype);
    let mobile = models::request_ops(Model::MobileNet, 2, dtype);
    let templates: Vec<TensorProgram> = vec![
        lm[0].clone(),                                                   // token GEMM
        lm[1].clone(),                                                   // attention chain
        TensorProgram::BatchedGemm { b: 12, m: 64, n: 64, k: 64, dtype }, // raw batched GEMM
        resnet[0].clone(),                                               // strided conv
        mobile[1].clone(),                                               // depthwise conv
        TensorProgram::decode_step((1, 128), (768, 12), dtype).unwrap(), // decode token
    ];
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        // ~1 µs mean gap: the whole burst lands within ~n µs while
        // per-batch service is tens of µs — saturation by construction.
        t += rng.exp(1e-6);
        out.push(ServeRequest {
            id: i as u64,
            program: templates[i % templates.len()].clone(),
            arrive: t,
            steps: 1,
        });
    }
    out
}

/// Autoregressive decode trace: Poisson arrivals of single-sequence
/// causal-attention decode requests against the BERT-geometry model
/// (d = 768, 12 heads), with geometrically distributed output lengths
/// (mean `mean_tokens`, the memoryless per-token stop rule) and
/// context lengths drawn from the scenario buckets. Every sequence is
/// generated IN-HORIZON by construction: `prompt + tokens <= 256`
/// (the top context bucket = the dispatch seq_k horizon), so a table
/// built from [`dispatch_config`] answers 100% of the steps —
/// the invariant `vortex bench decode` asserts. Deterministic from
/// the seed; sorted by arrival, ids in arrival order.
pub fn decode_trace(
    n_requests: usize,
    mean_interarrival: f64,
    mean_tokens: usize,
    seed: u64,
    dtype: DType,
) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    let horizon = SEQ_BUCKETS[SEQ_BUCKETS.len() - 1];
    let p = 1.0 / mean_tokens.max(1) as f64;
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        t += rng.exp(mean_interarrival);
        // Prompt (pre-filled KV depth) from a bucket-ish spread; the
        // first decode step attends prompt + 1 keys.
        let prompt = rng.usize(16, 160);
        // Geometric output length via inverse transform, clamped to
        // the horizon so the LAST step's seq_k stays table-answered.
        let u = rng.f64().max(1e-12);
        let tokens = (1.0 + u.ln() / (1.0 - p).ln()) as usize;
        let tokens = tokens.clamp(1, horizon - prompt - 1);
        out.push(ServeRequest {
            id: i as u64,
            program: TensorProgram::decode_step((1, prompt + 1), (768, 12), dtype)
                .expect("decode template is valid"),
            arrive: t,
            steps: tokens,
        });
    }
    out
}

/// [`serving_config`] with the given SLO applied to every lane, and
/// staggered priorities (attention highest — the interactive lane) so
/// the fleet executor's priority seeding has something to order.
pub fn slo_serving_config(slo: crate::serve::LaneSlo) -> ServeConfig {
    let mut cfg = serving_config();
    for (i, class) in LaneClass::ALL.iter().enumerate() {
        cfg.lane_mut(*class).slo = slo.with_priority(i as u8 + 1);
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::OpKind;
    use std::collections::HashSet;

    #[test]
    fn trace_is_sorted_valid_and_mixed() {
        let trace = mixed_trace(300, 4e-4, 9, DType::F32);
        assert_eq!(trace.len(), 300);
        assert!(trace.windows(2).all(|w| w[0].arrive <= w[1].arrive));
        let ids: Vec<u64> = trace.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..300).collect::<Vec<_>>());
        let mut kinds: HashSet<OpKind> = HashSet::new();
        for r in &trace {
            assert!(r.program.validate().is_ok(), "{}", r.program.id());
            kinds.insert(r.program.space().op);
        }
        // Token GEMMs, attention chains, strided convs and depthwise
        // (grouped) convs — at least 3 distinct op kinds guaranteed.
        assert!(kinds.len() >= 3, "only {:?}", kinds);
    }

    #[test]
    fn trace_is_deterministic_from_seed() {
        let a = mixed_trace(100, 4e-4, 7, DType::F32);
        let b = mixed_trace(100, 4e-4, 7, DType::F32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.program, y.program);
            assert_eq!(x.arrive, y.arrive);
        }
        let c = mixed_trace(100, 4e-4, 8, DType::F32);
        assert!(a.iter().zip(&c).any(|(x, y)| x.program != y.program));
    }

    #[test]
    fn dispatch_envelope_covers_every_merged_trace_shape() {
        // Every space the generator emits — scaled on its merge axis
        // by the worst the lane caps allow (4 key-compatible requests
        // per batch) — must fall inside the configured horizons, so
        // that in the nominal (unclamped) case the whole trace is
        // table-answered with zero cold misses.
        let cfg = dispatch_config();
        let trace = mixed_trace(300, 4e-4, 9, DType::F32);
        for r in &trace {
            let space = r.program.space();
            let horizons = cfg.horizons_for(space.op);
            let merge_axis = match space.op {
                OpKind::GroupedConv2d => 1,
                _ => 0,
            };
            for (a, (&d, &h)) in
                space.dims.dims().iter().zip(&horizons).enumerate()
            {
                let worst = if a == merge_axis { d * 4 } else { d };
                assert!(
                    worst <= h,
                    "{}: axis {} worst-merged dim {} exceeds horizon {}",
                    r.program.id(),
                    a,
                    worst,
                    h
                );
            }
        }
    }

    #[test]
    fn burst_trace_saturates_every_lane() {
        let trace = burst_trace(100, 3, DType::F32);
        assert_eq!(trace.len(), 100);
        assert!(trace.windows(2).all(|w| w[0].arrive <= w[1].arrive));
        let mut lanes: HashSet<LaneClass> = HashSet::new();
        for r in &trace {
            assert!(r.program.validate().is_ok(), "{}", r.program.id());
            lanes.insert(LaneClass::of(&r.program));
        }
        assert_eq!(lanes.len(), LaneClass::ALL.len(), "lane not saturated");
        // The whole burst lands within a few hundred µs.
        assert!(trace.last().unwrap().arrive < 1e-3);
    }

    #[test]
    fn decode_trace_is_sorted_in_horizon_and_deterministic() {
        let a = decode_trace(200, 3e-4, 24, 11, DType::F32);
        assert_eq!(a.len(), 200);
        assert!(a.windows(2).all(|w| w[0].arrive <= w[1].arrive));
        let horizons = dispatch_config().horizons_for(OpKind::CausalAttention);
        for r in &a {
            assert!(r.program.validate().is_ok(), "{}", r.program.id());
            assert_eq!(LaneClass::of(&r.program), LaneClass::Decode);
            assert!(r.steps >= 1);
            match r.program {
                TensorProgram::CausalAttention { seq_q, seq_k, .. } => {
                    assert_eq!(seq_q, 1);
                    // The LAST step's KV depth stays inside the
                    // dispatch envelope — the 100%-table-hit setup.
                    assert!(seq_k + r.steps - 1 <= horizons[2]);
                }
                _ => panic!("decode trace must emit causal attention"),
            }
        }
        let b = decode_trace(200, 3e-4, 24, 11, DType::F32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, x.steps, x.arrive), (y.id, y.steps, y.arrive));
            assert_eq!(x.program, y.program);
        }
        // Output lengths actually vary (geometric, not constant).
        let lens: HashSet<usize> = a.iter().map(|r| r.steps).collect();
        assert!(lens.len() > 5, "only {} distinct lengths", lens.len());
    }

    #[test]
    fn slo_config_staggers_priorities() {
        let slo = crate::serve::LaneSlo::with_deadline(1e-3);
        let cfg = slo_serving_config(slo);
        for class in LaneClass::ALL {
            assert_eq!(cfg.lane(class).slo.deadline, Some(1e-3));
        }
        assert!(
            cfg.lane(LaneClass::Attention).slo.priority
                > cfg.lane(LaneClass::Gemm).slo.priority
        );
    }

    #[test]
    fn serving_config_caps_every_lane() {
        let cfg = serving_config();
        for class in LaneClass::ALL {
            assert_eq!(cfg.lane(class).max_batch, 4);
        }
        assert!(cfg.plan_cache.is_some());
    }
}
