//! Tensor-program IR and the `rKernel` unified abstraction (paper §4).
//!
//! A [`TensorProgram`] is the operator-level input (GEMM, batched GEMM,
//! the conv family or an attention-fused chain, with some dimensions
//! dynamic). Vortex canonicalizes every program to an operator-generic
//! [`IterSpace`] over batch / spatial / reduction axes — with the flat
//! *contraction view* (M, N, K) as the GEMM-only baselines' lens —
//! which is what the candidate generator, cost model and runtime
//! constructor operate on. Conv maps via implicit GEMM (im2col),
//! mirroring how the paper folds Conv's loop nest into the same
//! recursion (§4.2, Table 1); attention maps to the batched-GEMM space
//! of its two contractions with the softmax fused at the L1 boundary.
//!
//! [`RKernel`] is the top-down recursive notation of Fig. 10/Algorithm 1:
//! per-level metadata (loop classes, analyzer kind, load/store/compute
//! stage descriptors) that the bottom-up constructor instantiates with
//! concrete tiles.

pub mod op;

use std::fmt;

pub use op::{
    Axis, AxisRole, BatchedGemm, CausalAttention, Conv2d, FusedAttention, Gemm,
    GroupedConv2d, IterSpace, OpKind, OpSpec, Tile, MAX_AXES,
};

/// Element type of a tensor program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    Bf16,
    F16,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::Bf16 | DType::F16 => 2,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::Bf16 => "bf16",
            DType::F16 => "f16",
        }
    }
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "bf16" => Some(DType::Bf16),
            "f16" => Some(DType::F16),
            _ => None,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Loop classification (Algorithm 1: PL / TSL / TRL).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopKind {
    /// Parallel loop set: distributed over hardware units at a level.
    Parallel,
    /// Temporal spatial: serial, non-reduction (output-tiling) loops.
    TemporalSpatial,
    /// Temporal reduction: serial accumulation loops.
    TemporalReduction,
}

/// An operator-level tensor program with (possibly) dynamic dims.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum TensorProgram {
    /// C[M,N] = A[M,K] @ B[K,N]
    Gemm { m: usize, n: usize, k: usize, dtype: DType },
    /// C[B,M,N] = A[B,M,K] @ B[B,K,N] (independent per-batch operands).
    BatchedGemm { b: usize, m: usize, n: usize, k: usize, dtype: DType },
    /// NHWC conv: x[N,H,W,Cin] * w[KH,KW,Cin/G,Cout], with stride,
    /// symmetric zero padding and channel groups (depthwise when
    /// `groups == cin`). OH = (H + 2·pad − KH)/stride + 1.
    ///
    /// Prefer the fallible [`TensorProgram::conv2d`] constructor:
    /// literal construction of invalid geometry (zero stride, filter
    /// larger than the padded feature map, groups not dividing the
    /// channels) is caught by [`TensorProgram::validate`], which
    /// [`TensorProgram::space`] enforces with a panic.
    Conv2d {
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        dtype: DType,
    },
    /// Multi-head attention-fused chain over Q, K, V of shape
    /// (batch·heads, seq, d/heads): `score = Q·Kᵀ`, row-softmax,
    /// `ctx = P·V`, optimized as ONE [`FusedAttention`] space — the
    /// softmax fuses at the L1 tile boundary instead of dispatching
    /// two batched GEMMs with a materialized intermediate.
    ///
    /// Prefer the fallible [`TensorProgram::attention`] constructor:
    /// literal construction of invalid geometry (zero dims, `heads`
    /// not dividing the model dimension `d`) is caught by
    /// [`TensorProgram::validate`], which [`TensorProgram::space`]
    /// enforces with a panic.
    Attention { batch: usize, seq: usize, d: usize, heads: usize, dtype: DType },
    /// Causal-masked attention over a resident KV cache — the
    /// autoregressive serving chain. `seq_q` queries (the LAST `seq_q`
    /// positions of the sequence) attend a `seq_k`-entry K/V cache:
    /// decode is `seq_q = 1` with `seq_k` growing by one per token,
    /// prefill is `seq_q = seq_k`. Maps to ONE [`CausalAttention`]
    /// space whose masked traffic/FLOP formulas count only the
    /// lower-triangular work.
    ///
    /// Prefer the fallible [`TensorProgram::causal_attention`]
    /// constructor: invalid geometry (zero dims, `heads` not dividing
    /// `d`, `seq_q > seq_k`) is caught by [`TensorProgram::validate`],
    /// which [`TensorProgram::space`] enforces with a panic.
    CausalAttention {
        batch: usize,
        seq_q: usize,
        seq_k: usize,
        d: usize,
        heads: usize,
        dtype: DType,
    },
}

/// The canonical contraction view all levels operate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Contraction {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub dtype: DType,
}

impl Contraction {
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Bytes touched once (A + B read, C written), ignoring re-reads.
    pub fn min_bytes(&self) -> f64 {
        let e = self.dtype.bytes() as f64;
        (self.m * self.k) as f64 * e + (self.k * self.n) as f64 * e
            + (self.m * self.n) as f64 * 4.0
    }

    pub fn dims(&self) -> [usize; 3] {
        [self.m, self.n, self.k]
    }
}

impl TensorProgram {
    /// Fallible conv constructor: the ONLY way invalid conv geometry
    /// surfaces — at program construction, not as a silently-wrong
    /// iteration space downstream. `io` is the NHWC input, `filt` the
    /// (KH, KW, Cout) filter, `geom` the (stride, pad, groups) triple.
    pub fn conv2d(
        (n, h, w, cin): (usize, usize, usize, usize),
        (kh, kw, cout): (usize, usize, usize),
        (stride, pad, groups): (usize, usize, usize),
        dtype: DType,
    ) -> Result<TensorProgram, String> {
        let p = TensorProgram::Conv2d {
            n,
            h,
            w,
            cin,
            cout,
            kh,
            kw,
            stride,
            pad,
            groups,
            dtype,
        };
        p.validate()?;
        Ok(p)
    }

    /// Fallible attention constructor — the ONLY way invalid attention
    /// geometry surfaces, mirroring [`TensorProgram::conv2d`]. `io` is
    /// the (batch, seq) pair, `proj` the (d_model, heads) pair; the
    /// per-head dimension is `d_model / heads`, which `heads` must
    /// divide exactly.
    pub fn attention(
        (batch, seq): (usize, usize),
        (d, heads): (usize, usize),
        dtype: DType,
    ) -> Result<TensorProgram, String> {
        let p = TensorProgram::Attention { batch, seq, d, heads, dtype };
        p.validate()?;
        Ok(p)
    }

    /// Fallible causal-attention constructor — the ONLY way invalid
    /// decode/prefill geometry surfaces. `io` is the
    /// (batch, seq_q, seq_k) triple, `proj` the (d_model, heads) pair.
    /// `seq_q <= seq_k` is required: queries are the last `seq_q`
    /// positions of the `seq_k`-entry causal sequence.
    pub fn causal_attention(
        (batch, seq_q, seq_k): (usize, usize, usize),
        (d, heads): (usize, usize),
        dtype: DType,
    ) -> Result<TensorProgram, String> {
        let p = TensorProgram::CausalAttention { batch, seq_q, seq_k, d, heads, dtype };
        p.validate()?;
        Ok(p)
    }

    /// One-token decode step: `seq_q = 1` against a `seq_k`-entry KV
    /// cache — the shape the continuous-batching decode lane issues
    /// every event-clock step.
    pub fn decode_step(
        (batch, seq_k): (usize, usize),
        (d, heads): (usize, usize),
        dtype: DType,
    ) -> Result<TensorProgram, String> {
        Self::causal_attention((batch, 1, seq_k), (d, heads), dtype)
    }

    /// Check the program describes a well-formed iteration space.
    /// Every dimension must be positive; conv geometry must admit at
    /// least one output position and divide cleanly into groups;
    /// attention heads must divide the model dimension.
    pub fn validate(&self) -> Result<(), String> {
        let positive = |pairs: &[(&str, usize)]| -> Result<(), String> {
            for &(name, v) in pairs {
                if v == 0 {
                    return Err(format!("dimension {} must be positive", name));
                }
            }
            Ok(())
        };
        match *self {
            TensorProgram::Gemm { m, n, k, .. } => {
                positive(&[("m", m), ("n", n), ("k", k)])
            }
            TensorProgram::BatchedGemm { b, m, n, k, .. } => {
                positive(&[("b", b), ("m", m), ("n", n), ("k", k)])
            }
            TensorProgram::Conv2d {
                n, h, w, cin, cout, kh, kw, stride, pad, groups, ..
            } => {
                positive(&[
                    ("n", n),
                    ("h", h),
                    ("w", w),
                    ("cin", cin),
                    ("cout", cout),
                    ("kh", kh),
                    ("kw", kw),
                ])?;
                if stride == 0 {
                    return Err("conv stride must be >= 1".into());
                }
                if groups == 0 {
                    return Err("conv groups must be >= 1".into());
                }
                if cin % groups != 0 || cout % groups != 0 {
                    return Err(format!(
                        "groups {} must divide cin {} and cout {}",
                        groups, cin, cout
                    ));
                }
                let (oh, ow) = conv_out_dims((h, w), (kh, kw), stride, pad)
                    .ok_or_else(|| {
                        format!(
                            "filter {}x{} exceeds padded feature map {}x{} \
                             (pad {})",
                            kh,
                            kw,
                            h + 2 * pad,
                            w + 2 * pad,
                            pad
                        )
                    })?;
                debug_assert!(oh >= 1 && ow >= 1);
                Ok(())
            }
            TensorProgram::Attention { batch, seq, d, heads, .. } => {
                positive(&[("batch", batch), ("seq", seq), ("d", d), ("heads", heads)])?;
                if d % heads != 0 {
                    return Err(format!("heads {} must divide model dimension {}", heads, d));
                }
                Ok(())
            }
            TensorProgram::CausalAttention { batch, seq_q, seq_k, d, heads, .. } => {
                positive(&[
                    ("batch", batch),
                    ("seq_q", seq_q),
                    ("seq_k", seq_k),
                    ("d", d),
                    ("heads", heads),
                ])?;
                if d % heads != 0 {
                    return Err(format!("heads {} must divide model dimension {}", heads, d));
                }
                if seq_q > seq_k {
                    return Err(format!(
                        "causal seq_q {} exceeds seq_k {}: queries are the last \
                         seq_q positions of the seq_k-entry sequence",
                        seq_q, seq_k
                    ));
                }
                Ok(())
            }
        }
    }

    /// Output spatial extent (OH, OW) of a conv program; `None` for
    /// non-conv programs or invalid geometry.
    pub fn conv_output(&self) -> Option<(usize, usize)> {
        match *self {
            TensorProgram::Conv2d { h, w, kh, kw, stride, pad, .. } => {
                conv_out_dims((h, w), (kh, kw), stride, pad)
            }
            _ => None,
        }
    }

    pub fn dtype(&self) -> DType {
        match *self {
            TensorProgram::Gemm { dtype, .. } => dtype,
            TensorProgram::BatchedGemm { dtype, .. } => dtype,
            TensorProgram::Conv2d { dtype, .. } => dtype,
            TensorProgram::Attention { dtype, .. } => dtype,
            TensorProgram::CausalAttention { dtype, .. } => dtype,
        }
    }

    /// The operator-generic iteration space this program optimizes over
    /// — the input of the candgen → compile → select pipeline.
    ///
    /// Panics on invalid geometry (defense in depth for literally
    /// constructed programs that skipped [`TensorProgram::conv2d`]):
    /// no downstream layer — candgen, cost, selector, runtime — can
    /// ever observe a silently-wrong iteration space.
    pub fn space(&self) -> IterSpace {
        if let Err(e) = self.validate() {
            panic!("invalid tensor program {}: {}", self.id(), e);
        }
        match *self {
            TensorProgram::Gemm { m, n, k, dtype } => IterSpace::gemm(m, n, k, dtype),
            TensorProgram::BatchedGemm { b, m, n, k, dtype } => {
                IterSpace::batched_gemm(b, m, n, k, dtype)
            }
            TensorProgram::Conv2d {
                n, h, w, cin, cout, kh, kw, stride, pad, groups, dtype,
            } => {
                let (oh, ow) =
                    conv_out_dims((h, w), (kh, kw), stride, pad).unwrap();
                if groups == 1 {
                    // Implicit GEMM: the contraction space itself.
                    IterSpace {
                        op: OpKind::Conv2d,
                        dims: Tile::new(&[n * oh * ow, cout, kh * kw * cin]),
                        dtype,
                    }
                } else {
                    // Per-group implicit GEMM with the group axis as a
                    // batch axis (depthwise = groups == cin).
                    IterSpace {
                        op: OpKind::GroupedConv2d,
                        dims: Tile::new(&[
                            groups,
                            n * oh * ow,
                            cout / groups,
                            kh * kw * (cin / groups),
                        ]),
                        dtype,
                    }
                }
            }
            TensorProgram::Attention { batch, seq, d, heads, dtype } => {
                // The fused chain's space is the batched-GEMM space of
                // its two contractions: head groups are the batch axis,
                // (seq_q, seq_k) the spatial axes, head_dim the
                // reduction axis.
                IterSpace {
                    op: OpKind::FusedAttention,
                    dims: Tile::new(&[batch * heads, seq, seq, d / heads]),
                    dtype,
                }
            }
            TensorProgram::CausalAttention { batch, seq_q, seq_k, d, heads, dtype } => {
                // Same batched space as the fused chain, but the two
                // spatial axes are independent: seq_q queries against a
                // seq_k-entry KV cache (decode: seq_q = 1).
                IterSpace {
                    op: OpKind::CausalAttention,
                    dims: Tile::new(&[batch * heads, seq_q, seq_k, d / heads]),
                    dtype,
                }
            }
        }
    }

    /// Canonicalize to the flat contraction view (implicit GEMM for
    /// conv; batch folds into M) — the GEMM-only baselines' lens.
    pub fn contraction(&self) -> Contraction {
        self.space().contraction()
    }

    pub fn flops(&self) -> f64 {
        self.space().flops()
    }

    /// Human-readable id used in logs and benchmark CSVs.
    pub fn id(&self) -> String {
        match *self {
            TensorProgram::Gemm { m, n, k, dtype } => {
                format!("gemm_m{}n{}k{}_{}", m, n, k, dtype)
            }
            TensorProgram::BatchedGemm { b, m, n, k, dtype } => {
                format!("bgemm_b{}m{}n{}k{}_{}", b, m, n, k, dtype)
            }
            TensorProgram::Conv2d {
                n, h, w, cin, cout, kh, kw, stride, pad, groups, dtype,
            } => format!(
                "conv_n{}h{}w{}c{}f{}k{}x{}s{}p{}g{}_{}",
                n, h, w, cin, cout, kh, kw, stride, pad, groups, dtype
            ),
            TensorProgram::Attention { batch, seq, d, heads, dtype } => {
                format!("attn_b{}s{}d{}h{}_{}", batch, seq, d, heads, dtype)
            }
            TensorProgram::CausalAttention { batch, seq_q, seq_k, d, heads, dtype } => {
                format!("cattn_b{}q{}k{}d{}h{}_{}", batch, seq_q, seq_k, d, heads, dtype)
            }
        }
    }

    /// Loop classification at one hierarchy level (Algorithm 1 sets),
    /// derived from the op's axis roles: batch axes are always parallel,
    /// spatial axes are parallel above L0 and temporal-spatial at L0,
    /// the reduction axis is always temporal-reduction.
    pub fn loop_kinds(&self, level: usize) -> Vec<(char, LoopKind)> {
        self.space()
            .op
            .spec()
            .axes()
            .iter()
            .map(|a| {
                let kind = match a.role {
                    AxisRole::Reduction => LoopKind::TemporalReduction,
                    AxisRole::Batch => LoopKind::Parallel,
                    AxisRole::Spatial => {
                        if level == 0 {
                            LoopKind::TemporalSpatial
                        } else {
                            LoopKind::Parallel
                        }
                    }
                };
                (a.name, kind)
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// rKernel: the unified recursive abstraction (paper Fig. 10 / Algorithm 1)
// ---------------------------------------------------------------------------

/// Analyzer choice per level (paper Fig. 10 `ANALYZE_TYPE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnalyzeType {
    Empirical,
    Analytical,
}

/// Load/store stage descriptor (paper Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// e.g. GlobalMem -> SharedMem / CacheBuf / VMEM
    Transfer { from: &'static str, to: &'static str },
    /// '-' in Table 1.
    NoOp,
}

/// Compute stage at level 0 (paper Table 1 "Lower Level rKernel" column
/// bottoms out in an instruction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeStage {
    /// Named ISA op: "mma.sync.m16n8k16", "avx512_fma", "pallas_dot".
    Instruction(&'static str),
    /// Recurse into the next level down.
    LowerRKernel,
}

/// Per-level metadata of the recursive kernel template.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMeta {
    pub layer_depth: usize,
    /// (axis name, loop kind) — the map<axis, LOOP_TYPE> of Fig. 10.
    pub loop_types: Vec<(char, LoopKind)>,
    pub analyzer: AnalyzeType,
    pub load: Stage,
    pub store: Stage,
    pub compute: ComputeStage,
    /// Parallel binding name (Table 1): "warp", "cta", "grid", "thread",
    /// "process", or "-".
    pub binding: &'static str,
}

/// The full rKernel template for a (program, hardware) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct RKernel {
    pub hw_name: &'static str,
    pub layers: Vec<LayerMeta>, // index = layer depth (0 = innermost)
}

impl RKernel {
    /// Instantiate the paper's Table 1 for a hardware target.
    /// `empirical_levels` selects the hybrid analyzer split (§5.2).
    pub fn for_hw(hw: &crate::hw::HwSpec, empirical_levels: &[usize]) -> RKernel {
        let an = |l: usize| {
            if empirical_levels.contains(&l) {
                AnalyzeType::Empirical
            } else {
                AnalyzeType::Analytical
            }
        };
        let (bindings, instr): ([&'static str; 3], &'static str) = match hw.name {
            "a100" => (["warp", "cta", "grid"], "mma.sync.m16n8k16"),
            "xeon_8255c" => (["-", "thread", "process"], "avx512_fma"),
            _ => (["-", "vmem_block", "grid"], "pallas_dot"),
        };
        let names: Vec<&'static str> = hw.levels.iter().map(|l| l.name).collect();
        let layers = (0..hw.n_levels())
            .map(|l| LayerMeta {
                layer_depth: l,
                loop_types: vec![
                    (
                        'm',
                        if l == 0 {
                            LoopKind::TemporalSpatial
                        } else {
                            LoopKind::Parallel
                        },
                    ),
                    (
                        'n',
                        if l == 0 {
                            LoopKind::TemporalSpatial
                        } else {
                            LoopKind::Parallel
                        },
                    ),
                    ('k', LoopKind::TemporalReduction),
                ],
                analyzer: an(l),
                load: if l + 1 < hw.n_levels() {
                    Stage::Transfer { from: names[l + 1], to: names[l] }
                } else {
                    Stage::NoOp
                },
                store: if l + 1 < hw.n_levels() {
                    Stage::Transfer { from: names[l], to: names[l + 1] }
                } else {
                    Stage::NoOp
                },
                compute: if l == 0 {
                    ComputeStage::Instruction(instr)
                } else {
                    ComputeStage::LowerRKernel
                },
                binding: bindings[l],
            })
            .collect();
        RKernel { hw_name: hw.name, layers }
    }
}

// ---------------------------------------------------------------------------
// Shape algebra shared by the constructor and the baselines
// ---------------------------------------------------------------------------

/// Conv output extent: `(dim + 2·pad − k)/stride + 1` per axis, or
/// `None` when the filter exceeds the padded feature map or the stride
/// is zero — the strict replacement for the old `saturating_sub`
/// arithmetic that silently produced OH = OW = 1.
pub fn conv_out_dims(
    (h, w): (usize, usize),
    (kh, kw): (usize, usize),
    stride: usize,
    pad: usize,
) -> Option<(usize, usize)> {
    if stride == 0 || h + 2 * pad < kh || w + 2 * pad < kw {
        return None;
    }
    Some((
        (h + 2 * pad - kh) / stride + 1,
        (w + 2 * pad - kw) / stride + 1,
    ))
}

/// Round `x` up to a multiple of `q` (q > 0).
pub fn round_up(x: usize, q: usize) -> usize {
    debug_assert!(q > 0);
    x.div_ceil(q) * q
}

/// Ceil division.
pub fn ceil_div(x: usize, q: usize) -> usize {
    debug_assert!(q > 0);
    x.div_ceil(q)
}

/// Fraction of padded work that is waste when `shape` is padded up to
/// tile multiples: 1 - prod(shape) / prod(padded).
pub fn padding_waste(shape: [usize; 3], tile: [usize; 3]) -> f64 {
    let real: f64 = shape.iter().map(|&d| d as f64).product();
    let padded: f64 = shape
        .iter()
        .zip(tile.iter())
        .map(|(&d, &t)| round_up(d, t) as f64)
        .product();
    1.0 - real / padded
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn conv_maps_to_implicit_gemm() {
        let c = TensorProgram::conv2d((2, 10, 10, 4), (3, 3, 8), (1, 0, 1), DType::F32)
            .unwrap()
            .contraction();
        assert_eq!(c.m, 2 * 8 * 8);
        assert_eq!(c.n, 8);
        assert_eq!(c.k, 3 * 3 * 4);
    }

    #[test]
    fn strided_padded_conv_geometry_matches_formula() {
        // ResNet stem: 224x224, 7x7, stride 2, pad 3 -> 112x112.
        let p = TensorProgram::conv2d((1, 224, 224, 3), (7, 7, 64), (2, 3, 1), DType::F32)
            .unwrap();
        assert_eq!(p.conv_output(), Some((112, 112)));
        let s = p.space();
        assert_eq!(s.op, OpKind::Conv2d);
        assert_eq!(s.dims, Tile::new(&[112 * 112, 64, 7 * 7 * 3]));
        // AlexNet stem: 224x224, 11x11, stride 4, pad 2 -> 55x55.
        let p = TensorProgram::conv2d((1, 224, 224, 3), (11, 11, 64), (4, 2, 1), DType::F32)
            .unwrap();
        assert_eq!(p.conv_output(), Some((55, 55)));
    }

    #[test]
    fn depthwise_conv_space_has_group_batch_axis() {
        // MobileNet depthwise: groups == cin, one in/out channel per group.
        let p = TensorProgram::conv2d((2, 28, 28, 128), (3, 3, 128), (1, 1, 128), DType::F16)
            .unwrap();
        let s = p.space();
        assert_eq!(s.op, OpKind::GroupedConv2d);
        assert_eq!(s.dims, Tile::new(&[128, 2 * 28 * 28, 1, 9]));
        // Group axis is parallel at every level.
        assert_eq!(p.loop_kinds(0)[0], ('g', LoopKind::Parallel));
        assert_eq!(p.loop_kinds(0)[3], ('k', LoopKind::TemporalReduction));
    }

    #[test]
    fn invalid_conv_geometry_is_a_construction_error() {
        // Filter larger than the (padded) feature map.
        assert!(TensorProgram::conv2d((2, 2, 2, 4), (3, 3, 8), (1, 0, 1), DType::F32)
            .is_err());
        // Padding can rescue it...
        assert!(TensorProgram::conv2d((2, 2, 2, 4), (3, 3, 8), (1, 1, 1), DType::F32)
            .is_ok());
        // Zero stride.
        assert!(TensorProgram::conv2d((1, 8, 8, 4), (3, 3, 8), (0, 0, 1), DType::F32)
            .is_err());
        // Groups not dividing channels.
        assert!(TensorProgram::conv2d((1, 8, 8, 6), (3, 3, 8), (1, 0, 4), DType::F32)
            .is_err());
        assert!(TensorProgram::conv2d((1, 8, 8, 8), (3, 3, 6), (1, 0, 4), DType::F32)
            .is_err());
        // Zero-sized dims.
        assert!(TensorProgram::conv2d((0, 8, 8, 4), (3, 3, 8), (1, 0, 1), DType::F32)
            .is_err());
    }

    #[test]
    #[should_panic(expected = "invalid tensor program")]
    fn undersized_fmap_panics_instead_of_oh_equals_one() {
        // The old saturating_sub arithmetic yielded OH = OW = 1 here; a
        // literally-constructed invalid program must never reach candgen
        // or the selector as a bogus iteration space.
        let p = TensorProgram::Conv2d {
            n: 1,
            h: 2,
            w: 2,
            cin: 4,
            cout: 8,
            kh: 5,
            kw: 5,
            stride: 1,
            pad: 0,
            groups: 1,
            dtype: DType::F32,
        };
        let _ = p.space();
    }

    #[test]
    fn conv_out_dims_edges() {
        assert_eq!(conv_out_dims((5, 5), (5, 5), 1, 0), Some((1, 1)));
        assert_eq!(conv_out_dims((4, 4), (5, 5), 1, 0), None);
        assert_eq!(conv_out_dims((4, 4), (5, 5), 1, 1), Some((2, 2)));
        assert_eq!(conv_out_dims((5, 5), (5, 5), 0, 0), None);
        // Stride floor: (7 - 3)/2 + 1 = 3.
        assert_eq!(conv_out_dims((7, 7), (3, 3), 2, 0), Some((3, 3)));
    }

    #[test]
    fn gemm_flops() {
        let p = TensorProgram::Gemm { m: 2, n: 3, k: 4, dtype: DType::F32 };
        assert_eq!(p.flops(), 48.0);
    }

    #[test]
    fn loop_kinds_match_table1() {
        let p = TensorProgram::Gemm { m: 8, n: 8, k: 8, dtype: DType::F32 };
        // L0: m/n temporal-spatial, k reduction (warp-level serial loops)
        assert_eq!(p.loop_kinds(0)[0].1, LoopKind::TemporalSpatial);
        assert_eq!(p.loop_kinds(0)[2].1, LoopKind::TemporalReduction);
        // L1/L2: m/n parallel over units
        assert_eq!(p.loop_kinds(1)[0].1, LoopKind::Parallel);
        assert_eq!(p.loop_kinds(2)[1].1, LoopKind::Parallel);
    }

    #[test]
    fn rkernel_table1_gpu_row() {
        let rk = RKernel::for_hw(&presets::a100(), &[0, 1]);
        assert_eq!(rk.layers.len(), 3);
        assert_eq!(rk.layers[0].binding, "warp");
        assert_eq!(rk.layers[1].binding, "cta");
        assert_eq!(rk.layers[2].binding, "grid");
        assert_eq!(
            rk.layers[0].compute,
            ComputeStage::Instruction("mma.sync.m16n8k16")
        );
        assert_eq!(rk.layers[2].compute, ComputeStage::LowerRKernel);
        assert_eq!(rk.layers[2].load, Stage::NoOp); // Table 1: '-' at L2
        assert_eq!(rk.layers[0].analyzer, AnalyzeType::Empirical);
        assert_eq!(rk.layers[2].analyzer, AnalyzeType::Analytical);
    }

    #[test]
    fn rkernel_cpu_default_is_empirical_l0_only() {
        let rk = RKernel::for_hw(&presets::xeon_8255c(), &[0]);
        assert_eq!(rk.layers[0].analyzer, AnalyzeType::Empirical);
        assert_eq!(rk.layers[1].analyzer, AnalyzeType::Analytical);
        assert_eq!(rk.layers[1].binding, "thread");
        assert_eq!(rk.layers[2].binding, "process");
    }

    #[test]
    fn shape_algebra() {
        assert_eq!(round_up(5, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(ceil_div(9, 8), 2);
        assert!((padding_waste([5, 8, 8], [8, 8, 8]) - (1.0 - 5.0 / 8.0)).abs() < 1e-12);
        assert_eq!(padding_waste([8, 8, 8], [8, 8, 8]), 0.0);
    }

    #[test]
    fn batched_gemm_space_and_batch_loops_are_parallel() {
        let p = TensorProgram::BatchedGemm { b: 12, m: 64, n: 64, k: 32, dtype: DType::F16 };
        let s = p.space();
        assert_eq!(s.op, OpKind::BatchedGemm);
        assert_eq!(s.dims, Tile::new(&[12, 64, 64, 32]));
        assert_eq!(p.flops(), 2.0 * 12.0 * 64.0 * 64.0 * 32.0);
        // batch axis parallel at EVERY level, including L0
        let kinds = p.loop_kinds(0);
        assert_eq!(kinds[0], ('b', LoopKind::Parallel));
        assert_eq!(kinds[1], ('m', LoopKind::TemporalSpatial));
        assert_eq!(kinds[3], ('k', LoopKind::TemporalReduction));
    }

    #[test]
    fn attention_space_is_the_batched_contraction_space() {
        // BERT-base shape: 12 heads of 64 dims, dynamic seq.
        let p = TensorProgram::attention((2, 77), (768, 12), DType::F16).unwrap();
        let s = p.space();
        assert_eq!(s.op, OpKind::FusedAttention);
        assert_eq!(s.dims, Tile::new(&[2 * 12, 77, 77, 64]));
        // Both contractions counted: 4·b·h·s²·hd.
        assert_eq!(p.flops(), 4.0 * 24.0 * 77.0 * 77.0 * 64.0);
        assert_eq!(p.id(), "attn_b2s77d768h12_f16");
        // Head groups are a batch axis at every level; head_dim is the
        // reduction.
        let kinds = p.loop_kinds(0);
        assert_eq!(kinds[0], ('b', LoopKind::Parallel));
        assert_eq!(kinds[1], ('m', LoopKind::TemporalSpatial));
        assert_eq!(kinds[3], ('k', LoopKind::TemporalReduction));
    }

    #[test]
    fn causal_attention_space_decouples_seq_q_and_seq_k() {
        // Decode step: one query against a 477-entry KV cache.
        let p = TensorProgram::decode_step((4, 477), (768, 12), DType::F16).unwrap();
        let s = p.space();
        assert_eq!(s.op, OpKind::CausalAttention);
        assert_eq!(s.dims, Tile::new(&[4 * 12, 1, 477, 64]));
        // seq_q = 1 masks nothing: full fused-chain flops over the row.
        assert_eq!(p.flops(), 4.0 * 48.0 * 477.0 * 64.0);
        assert_eq!(p.id(), "cattn_b4q1k477d768h12_f16");
        // Square causal prefill counts only the lower triangle.
        let pre = TensorProgram::causal_attention((1, 64, 64, ), (768, 12), DType::F16)
            .unwrap();
        assert_eq!(pre.flops(), 4.0 * 12.0 * (64.0 * 65.0 / 2.0) * 64.0);
        let full = TensorProgram::attention((1, 64), (768, 12), DType::F16).unwrap();
        assert!(pre.flops() < full.flops());
    }

    #[test]
    fn invalid_causal_attention_geometry_is_a_construction_error() {
        // Queries past the causal frontier.
        assert!(TensorProgram::causal_attention((1, 65, 64), (768, 12), DType::F32).is_err());
        // Heads not dividing d, zero dims.
        assert!(TensorProgram::causal_attention((1, 1, 64), (768, 7), DType::F32).is_err());
        assert!(TensorProgram::causal_attention((0, 1, 64), (768, 12), DType::F32).is_err());
        assert!(TensorProgram::causal_attention((1, 0, 64), (768, 12), DType::F32).is_err());
        assert!(TensorProgram::causal_attention((1, 1, 0), (768, 12), DType::F32).is_err());
        // Decode at the horizon edge and non-power-of-two are valid.
        assert!(TensorProgram::decode_step((1, 1), (768, 12), DType::F32).is_ok());
        assert!(TensorProgram::decode_step((3, 333), (1024, 16), DType::F32).is_ok());
    }

    #[test]
    fn invalid_attention_geometry_is_a_construction_error() {
        // Heads not dividing the model dimension.
        assert!(TensorProgram::attention((1, 128), (768, 7), DType::F32).is_err());
        // Zero-sized dims.
        assert!(TensorProgram::attention((0, 128), (768, 12), DType::F32).is_err());
        assert!(TensorProgram::attention((1, 0), (768, 12), DType::F32).is_err());
        assert!(TensorProgram::attention((1, 128), (0, 12), DType::F32).is_err());
        assert!(TensorProgram::attention((1, 128), (768, 0), DType::F32).is_err());
        // seq = 1 (decode step) and non-power-of-two seq are VALID.
        assert!(TensorProgram::attention((1, 1), (768, 12), DType::F32).is_ok());
        assert!(TensorProgram::attention((3, 477), (1024, 16), DType::F32).is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid tensor program")]
    fn invalid_attention_space_panics_like_conv() {
        // A literally-constructed invalid program must never reach
        // candgen or the selector as a bogus iteration space.
        let p = TensorProgram::Attention { batch: 1, seq: 64, d: 768, heads: 7, dtype: DType::F32 };
        let _ = p.space();
    }

    #[test]
    fn dtype_round_trip() {
        for d in [DType::F32, DType::Bf16, DType::F16] {
            assert_eq!(DType::parse(d.name()), Some(d));
        }
        assert_eq!(DType::parse("f64"), None);
    }
}
