//! Operator-generic strategy space: the [`OpSpec`] abstraction.
//!
//! The paper's hierarchization recursion (Algorithm 1/2, Eqs. 2–4) is
//! not GEMM-specific: any operator whose iteration space factors into
//! batch / spatial / reduction axes can be tiled level-by-level. This
//! module owns everything that *was* hardwired to `[usize; 3]` (M, N, K)
//! tiles:
//!
//! * [`Tile`] — a fixed-capacity, rank-tagged tile over an op's axes
//!   (allocation-free `Copy` type, so the runtime selection hot path
//!   stays allocation-free).
//! * [`OpSpec`] — per-operator iteration-space rank, axis roles, FLOP
//!   count, working-set formula, per-level load/store traffic, padding /
//!   grid math and the AOT artifact-name convention.
//! * [`OpKind`] + the concrete [`Gemm`], [`BatchedGemm`], [`Conv2d`],
//!   [`GroupedConv2d`] and [`FusedAttention`] ops — `OpKind` is the
//!   compact `Copy` handle stored in candidates, strategies and
//!   libraries; `.spec()` dispatches to the behavior.
//! * [`IterSpace`] — a runtime problem: (op, concrete dims, dtype).
//!
//! Adding a new operator = implementing `OpSpec` for a unit struct and
//! registering it in `OpKind`; candgen, the cost model, the compiler,
//! the selector and the simulator pick it up unchanged. The full
//! per-layer recipe (with [`FusedAttention`] as the worked example)
//! lives in `docs/ARCHITECTURE.md`.

use std::fmt;
use std::ops::{Index, IndexMut};

use super::{ceil_div, round_up, Contraction, DType};

/// Maximum iteration-space rank any op may declare.
pub const MAX_AXES: usize = 4;

/// Role of one iteration-space axis in the tiling recursion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AxisRole {
    /// Embarrassingly parallel, no operand reuse across it (batch).
    Batch,
    /// Output-tiling axis: parallel at upper levels, temporal-spatial
    /// at L0 (M/N of a contraction).
    Spatial,
    /// Serial accumulation axis (K of a contraction).
    Reduction,
}

/// One named axis of an op's iteration space.
#[derive(Debug, Clone, Copy)]
pub struct Axis {
    pub name: char,
    pub role: AxisRole,
}

const fn ax(name: char, role: AxisRole) -> Axis {
    Axis { name, role }
}

// ---------------------------------------------------------------------------
// Tile
// ---------------------------------------------------------------------------

/// A tile over an op's axes: rank-tagged, fixed capacity, `Copy`.
///
/// Invariants:
///
/// * `1 <= rank <= MAX_AXES`, checked at construction;
/// * unused trailing dims are always 1, so `Eq`/`Hash`/`Ord` behave as
///   if only the first `rank` dims existed (for rank-3 contraction
///   tiles the lexicographic order matches the old `[usize; 3]`
///   order);
/// * the elementwise algebra (`ceil_div`, `mul`, `round_up_to`,
///   `is_multiple_of`, `zip_map`) requires equal ranks and panics on a
///   mismatch — a rank-3 conv tile never silently combines with a
///   rank-4 batched tile.
///
/// Being `Copy` with no heap payload keeps the runtime selection hot
/// path allocation-free.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Tile {
    rank: u8,
    dims: [usize; MAX_AXES],
}

impl Tile {
    pub fn new(dims: &[usize]) -> Tile {
        assert!(
            (1..=MAX_AXES).contains(&dims.len()),
            "tile rank {} out of range",
            dims.len()
        );
        let mut d = [1usize; MAX_AXES];
        d[..dims.len()].copy_from_slice(dims);
        Tile { rank: dims.len() as u8, dims: d }
    }

    /// All-ones tile of the given rank (multiplicative identity).
    pub fn ones(rank: usize) -> Tile {
        assert!((1..=MAX_AXES).contains(&rank));
        Tile { rank: rank as u8, dims: [1; MAX_AXES] }
    }

    /// Rank-3 (contraction-view) constructor, the old `[m, n, k]`.
    pub fn from3(d: [usize; 3]) -> Tile {
        Tile::new(&d)
    }

    /// Back to `[m, n, k]`; panics on non-contraction ranks.
    pub fn to3(self) -> [usize; 3] {
        assert_eq!(self.rank, 3, "tile {} is not rank 3", self);
        [self.dims[0], self.dims[1], self.dims[2]]
    }

    /// Rank-4 (batched-contraction) constructor, `[b, m, n, k]`.
    pub fn from4(d: [usize; 4]) -> Tile {
        Tile::new(&d)
    }

    /// Back to `[b, m, n, k]`; panics on other ranks. This is the
    /// block the runtime's batched constructor
    /// (`runtime::RealEngine::bgemm_dynamic`) executes.
    pub fn to4(self) -> [usize; 4] {
        assert_eq!(self.rank, 4, "tile {} is not rank 4", self);
        [self.dims[0], self.dims[1], self.dims[2], self.dims[3]]
    }

    pub fn rank(self) -> usize {
        self.rank as usize
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims[..self.rank as usize]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, usize> {
        self.dims().iter()
    }

    /// Product of all dims as f64 (iteration count).
    pub fn product_f64(self) -> f64 {
        self.dims().iter().map(|&d| d as f64).product()
    }

    /// Elementwise `ceil(self / t)` — the launch grid over tile `t`.
    pub fn ceil_div(self, t: Tile) -> Tile {
        self.zip_map(t, ceil_div)
    }

    /// Elementwise product (grid x tile = padded problem).
    pub fn mul(self, t: Tile) -> Tile {
        self.zip_map(t, |a, b| a * b)
    }

    /// Elementwise round-up to multiples of `t` (padding).
    pub fn round_up_to(self, t: Tile) -> Tile {
        self.zip_map(t, round_up)
    }

    /// True when every dim of `self` is a positive integer multiple of
    /// the corresponding dim of `child`.
    pub fn is_multiple_of(self, child: Tile) -> bool {
        self.rank == child.rank
            && self
                .dims()
                .iter()
                .zip(child.dims())
                .all(|(&p, &c)| c > 0 && p % c == 0)
    }

    fn zip_map(self, t: Tile, f: impl Fn(usize, usize) -> usize) -> Tile {
        assert_eq!(self.rank, t.rank, "rank mismatch: {} vs {}", self, t);
        let mut out = self;
        for i in 0..self.rank as usize {
            out.dims[i] = f(self.dims[i], t.dims[i]);
        }
        out
    }
}

impl Index<usize> for Tile {
    type Output = usize;
    fn index(&self, i: usize) -> &usize {
        &self.dims()[i]
    }
}

impl IndexMut<usize> for Tile {
    fn index_mut(&mut self, i: usize) -> &mut usize {
        assert!(i < self.rank as usize, "axis {} out of rank {}", i, self.rank);
        &mut self.dims[i]
    }
}

impl fmt::Debug for Tile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.dims()).finish()
    }
}

impl fmt::Display for Tile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, d) in self.dims().iter().enumerate() {
            if i > 0 {
                f.write_str("x")?;
            }
            write!(f, "{}", d)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// OpKind + OpSpec
// ---------------------------------------------------------------------------

/// Compact operator handle stored in candidates / strategies / libraries.
///
/// The `name()` strings double as the JSON `"op"` field of serialized
/// libraries and as the artifact-name prefix family; [`OpKind::parse`]
/// is the strict inverse. Note that `"softmax"` is deliberately NOT an
/// op: the row-softmax is the fused epilogue of the [`FusedAttention`]
/// chain (a profiler micro-measurement, see
/// `Profiler::measure_softmax`), never a standalone strategy space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Gemm,
    BatchedGemm,
    Conv2d,
    GroupedConv2d,
    FusedAttention,
    CausalAttention,
}

impl OpKind {
    pub const ALL: [OpKind; 6] = [
        OpKind::Gemm,
        OpKind::BatchedGemm,
        OpKind::Conv2d,
        OpKind::GroupedConv2d,
        OpKind::FusedAttention,
        OpKind::CausalAttention,
    ];

    pub fn spec(self) -> &'static dyn OpSpec {
        match self {
            OpKind::Gemm => &Gemm,
            OpKind::BatchedGemm => &BatchedGemm,
            OpKind::Conv2d => &Conv2d,
            OpKind::GroupedConv2d => &GroupedConv2d,
            OpKind::FusedAttention => &FusedAttention,
            OpKind::CausalAttention => &CausalAttention,
        }
    }

    pub fn name(self) -> &'static str {
        self.spec().name()
    }

    /// Strict inverse of [`OpKind::name`]: unknown strings (including
    /// `"softmax"`, which is an epilogue measurement, not an op) are
    /// `None`.
    pub fn parse(s: &str) -> Option<OpKind> {
        OpKind::ALL.into_iter().find(|o| o.name() == s)
    }
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-operator strategy-space definition: everything the candgen →
/// cost → compile → select pipeline needs to know about an operator.
///
/// Invariants every implementation must uphold:
///
/// * the reduction axis is LAST and there is exactly ONE — candgen's
///   capacity-break and the cost model's temporal loop rely on it;
/// * `working_set` is monotone in every tile dim (candgen's
///   ascending-reduction-ladder break assumes it);
/// * `rank()` is at most [`MAX_AXES`].
pub trait OpSpec: Sync {
    /// Stable name, also the JSON/artifact identifier ("gemm", ...).
    fn name(&self) -> &'static str;

    /// The compact handle this spec dispatches from.
    fn kind(&self) -> OpKind;

    /// Iteration-space axes, reduction last.
    fn axes(&self) -> &'static [Axis];

    /// Iteration-space rank (axis count), at most [`MAX_AXES`].
    fn rank(&self) -> usize {
        self.axes().len()
    }

    /// Lift a backend's 3-axis ISA granularity onto this op's axes
    /// (batch axes get granularity 1: an ISA instruction never spans
    /// independent batch elements).
    fn isa_tile(&self, isa: [usize; 3]) -> Tile {
        let mut t = Tile::ones(self.rank());
        let mut j = 0;
        for (i, a) in self.axes().iter().enumerate() {
            if a.role != AxisRole::Batch {
                t[i] = isa[j];
                j += 1;
            }
        }
        t
    }

    /// FLOPs of one full traversal of `iter` (multiply-accumulate = 2).
    /// Fused chains count every constituent kernel (FusedAttention:
    /// both contractions → 4·|iter|).
    fn flops(&self, iter: Tile) -> f64 {
        2.0 * iter.product_f64()
    }

    /// Bytes the operand slabs + accumulator of one tile occupy at a
    /// level (the Algorithm-2 capacity check). Must be monotone in
    /// every tile dim.
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64;

    /// Minimum DRAM traffic of a full problem (roofline memory term):
    /// each operand read once, the output written once. Fused chains
    /// exclude intermediates that never round-trip to DRAM.
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64;

    /// Bytes loaded per reduction step at a level: the input slabs of
    /// the child's reduction extent across the parent's other extents.
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64;

    /// Bytes stored once per level traversal (f32 accumulator).
    fn store_bytes(&self, parent: Tile) -> f64;

    /// Parallel (batch + spatial) child iterations inside a parent
    /// (the |ParallelLoop| of Eq. 3).
    fn spatial_iters(&self, parent: Tile, child: Tile) -> usize {
        self.axes()
            .iter()
            .enumerate()
            .filter(|(_, a)| a.role != AxisRole::Reduction)
            .map(|(i, _)| ceil_div(parent[i], child[i]))
            .product()
    }

    /// Temporal (reduction) child iterations inside a parent
    /// (the |TemporalLoop| of Eq. 2).
    fn reduce_iters(&self, parent: Tile, child: Tile) -> usize {
        self.axes()
            .iter()
            .enumerate()
            .filter(|(_, a)| a.role == AxisRole::Reduction)
            .map(|(i, _)| ceil_div(parent[i], child[i]))
            .product()
    }

    /// AOT artifact-name convention shared with python/compile/aot.py.
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String;

    /// The op whose blocks define empirical measurements of this op's
    /// strategies. Override when a subchain measurement of this op is
    /// expressible through that op's blocks — either because every
    /// cost-relevant formula is an exact delegation (Conv2d → Gemm,
    /// GroupedConv2d → BatchedGemm: the strategy space IS the alias's
    /// space), or because one block of this op executes a fixed chain
    /// of the alias's blocks ([`FusedAttention`] → BatchedGemm:
    /// [`OpSpec::chain_kernels`] contraction blocks plus the
    /// [`OpSpec::softmax_tile`] epilogue). The profiler measures under
    /// the alias's key, so aliased ops share measurements instead of
    /// re-taking them, and the selector serves a space with no native
    /// library through the alias chain's fixpoint.
    fn measurement_op(&self) -> OpKind {
        self.kind()
    }

    /// Contraction-kernel launches one block of this op executes per
    /// traversal. 1 for single-kernel ops; fused chains return the
    /// chain length (FusedAttention: 2 — the score and context
    /// contractions). A subchain measurement of a chain op is
    /// `chain_kernels()` × the measurement-op block cost (the
    /// constituent blocks are cost-symmetric: identical FLOPs and
    /// operand slab sizes up to accumulator width), plus the fused
    /// epilogue from [`OpSpec::softmax_tile`].
    fn chain_kernels(&self) -> usize {
        1
    }

    /// Dimensions (rows, cols) of the resident f32 score tile a fused
    /// row-softmax normalizes at the L1 boundary of `tile`, or `None`
    /// for ops without a fused epilogue. This is what the softmax
    /// micro-measurement (`Profiler::measure_softmax`) prices.
    fn softmax_tile(&self, tile: Tile) -> Option<(usize, usize)> {
        let _ = tile;
        None
    }

    /// The parallel write model of the runtime's `run_cells` launch
    /// grid: per OUTPUT axis, `(iteration-space axis, L1-tile axis
    /// whose extent tiles it)`. The output index box is the product of
    /// these axes' `[0, dims[axis])` ranges, and one grid cell's write
    /// region is the box of per-axis [`OpSpec::write_footprint`]
    /// intervals — per-axis separability is what lets the plan auditor
    /// ([`crate::analysis`]) prove pairwise disjointness and exact
    /// cover from the per-axis partitions alone.
    ///
    /// Default: every non-reduction axis is an output axis tiled by
    /// its own L1 extent (GEMM writes (m, n), batched GEMM (b, m, n),
    /// the conv family their delegated contraction view). Fused chains
    /// whose output lives on other axes override this (attention's
    /// context is (b, m, head_dim), with head_dim tiled by the L1
    /// *n*-extent — the context contraction's output-column position).
    fn write_axes(&self) -> Vec<(usize, usize)> {
        self.axes()
            .iter()
            .enumerate()
            .filter(|(_, a)| a.role != AxisRole::Reduction)
            .map(|(i, _)| (i, i))
            .collect()
    }

    /// The half-open interval of output coordinates grid cell `i`
    /// writes on one output axis of problem extent `d` tiled by L1
    /// extent `e` — this must mirror the runtime scatter's edge
    /// cropping exactly (`mrows = bm.min(m - m0)`), and cells at or
    /// beyond the grid (`i >= ceil(d / e)`) must be empty (the batched
    /// path's batch-edge `break`). The contract the auditor verifies
    /// symbolically: within any two consecutive multiples of `e`, the
    /// interval is an affine function of `d` (constant for non-terminal
    /// cells, end = `d` for the terminal cell), so checking both
    /// segment endpoints proves every in-segment shape.
    fn write_footprint(&self, d: usize, e: usize, i: usize) -> (usize, usize) {
        ((i * e).min(d), ((i + 1) * e).min(d))
    }

    /// Per-axis suprema of the admissible in-tile dim box of `tile` —
    /// the closed-form corner where the (documented-monotone)
    /// [`OpSpec::working_set`] formula attains its maximum over every
    /// admissible runtime shape. Edge tiles are zero-padded to the full
    /// tile, so the resident footprint never depends on the problem
    /// dims and the supremum is the tile itself. The capacity audit
    /// evaluates `working_set` once here instead of sampling shapes.
    fn axis_extrema(&self, tile: Tile) -> Tile {
        tile
    }
}

/// C[M,N] = A[M,K] @ B[K,N] — the canonical contraction.
pub struct Gemm;

impl OpSpec for Gemm {
    fn name(&self) -> &'static str {
        "gemm"
    }
    fn kind(&self) -> OpKind {
        OpKind::Gemm
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 3] = [
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        crate::hw::HwSpec::gemm_working_set(tile.to3(), in_bytes)
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        let [m, n, k] = iter.to3();
        let e = dtype.bytes() as f64;
        (m * k) as f64 * e + (k * n) as f64 * e + (m * n) as f64 * 4.0
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        let (m, n, ck) = (parent[0], parent[1], child[2]);
        ((m * ck + ck * n) * dtype.bytes()) as f64
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        (parent[0] * parent[1] * 4) as f64
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        format!("gemm_acc_{}x{}x{}_{}", l1[0], l1[1], l1[2], dtype.name())
    }
}

/// C[B,M,N] = A[B,M,K] @ B[B,K,N] — independent per-batch operands, so
/// the batch axis is purely parallel and every footprint scales by the
/// batch-tile extent (no cross-batch reuse, unlike folding B into M).
pub struct BatchedGemm;

impl OpSpec for BatchedGemm {
    fn name(&self) -> &'static str {
        "batched_gemm"
    }
    fn kind(&self) -> OpKind {
        OpKind::BatchedGemm
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 4] = [
            ax('b', AxisRole::Batch),
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        let (b, m, n, k) = (tile[0], tile[1], tile[2], tile[3]);
        (b * (m * k * in_bytes + k * n * in_bytes + m * n * 4)) as u64
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        let (b, m, n, k) = (iter[0], iter[1], iter[2], iter[3]);
        let e = dtype.bytes() as f64;
        b as f64 * ((m * k) as f64 * e + (k * n) as f64 * e + (m * n) as f64 * 4.0)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        let (b, m, n, ck) = (parent[0], parent[1], parent[2], child[3]);
        (b * (m * ck + ck * n) * dtype.bytes()) as f64
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        (parent[0] * parent[1] * parent[2] * 4) as f64
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        format!(
            "bgemm_acc_{}x{}x{}x{}_{}",
            l1[0],
            l1[1],
            l1[2],
            l1[3],
            dtype.name()
        )
    }
}

/// NHWC valid convolution in its implicit-GEMM (im2col) contraction
/// view (paper §4.2, Table 1): M = N·OH·OW, N = Cout, K = KH·KW·Cin.
/// The strategy space is the contraction space; what is conv-specific
/// is the program→space mapping ([`crate::ir::TensorProgram`]) and the
/// artifact convention — conv blocks ARE gemm blocks fed by im2col, so
/// a conv library references the shared `gemm_acc` artifacts.
pub struct Conv2d;

impl OpSpec for Conv2d {
    fn name(&self) -> &'static str {
        "conv2d"
    }
    fn kind(&self) -> OpKind {
        OpKind::Conv2d
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 3] = [
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        Gemm.working_set(tile, in_bytes)
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        Gemm.min_bytes(iter, dtype)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        Gemm.load_bytes_per_step(parent, child, dtype)
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        Gemm.store_bytes(parent)
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        // Implicit GEMM: conv blocks execute the shared gemm_acc
        // artifacts over the im2col patch matrix.
        Gemm.artifact_name(l1, dtype)
    }
    fn measurement_op(&self) -> OpKind {
        // Every formula above delegates to Gemm, so a conv subchain
        // measurement is a gemm subchain measurement.
        OpKind::Gemm
    }
}

/// Grouped NHWC convolution (depthwise when `groups == cin`) in its
/// per-group implicit-GEMM view: the iteration space is
/// (G, N·OH·OW, Cout/G, KH·KW·Cin/G). The group axis is a *batch* axis
/// — groups share no operands, exactly like the batch of a batched
/// GEMM — so candgen's short batch ladder, the cost model's
/// footprint scaling and the selector all treat it as purely parallel.
/// Every cost-relevant formula delegates to [`BatchedGemm`], so grouped
/// subchain measurements alias batched-GEMM measurements, and a grouped
/// block on the real runtime is a bgemm block over per-group im2col
/// patch matrices.
pub struct GroupedConv2d;

impl OpSpec for GroupedConv2d {
    fn name(&self) -> &'static str {
        "grouped_conv2d"
    }
    fn kind(&self) -> OpKind {
        OpKind::GroupedConv2d
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 4] = [
            ax('g', AxisRole::Batch),
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        BatchedGemm.working_set(tile, in_bytes)
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        BatchedGemm.min_bytes(iter, dtype)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        BatchedGemm.load_bytes_per_step(parent, child, dtype)
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        BatchedGemm.store_bytes(parent)
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        // Per-group implicit GEMM: grouped blocks execute the batched
        // gemm convention over per-group patch matrices.
        BatchedGemm.artifact_name(l1, dtype)
    }
    fn measurement_op(&self) -> OpKind {
        // Every formula above delegates to BatchedGemm, so a grouped
        // conv subchain measurement is a batched-gemm measurement.
        OpKind::BatchedGemm
    }
}

/// Attention-fused chain over one group of heads:
/// `score = Q·Kᵀ`, row-softmax, `ctx = P·V`, with the softmax fused at
/// the L1 tile boundary (the score tile stays resident on chip; the
/// probability matrix P never round-trips to DRAM).
///
/// The iteration space is the batched-GEMM space of the two
/// contractions — (b, m, n, k) = (batch·heads, seq_q, seq_k, head_dim)
/// — enumerated over the same per-role ladders as [`BatchedGemm`]. The
/// score contraction is the (b, m, n, k) block; the context
/// contraction is its (b, m, k, n) transpose, cost-symmetric to it
/// (identical FLOPs and operand slab sizes up to accumulator width),
/// which is why `chain_kernels() == 2` with `measurement_op() ==
/// BatchedGemm` prices the chain through the existing batched-GEMM
/// measurements, and why the selector can serve an attention space
/// with the batched-GEMM libraries when no native library is loaded.
///
/// What is attention-specific:
///
/// * `working_set` keeps the resident f32 score tile PLUS the staged V
///   slab, the f32 context accumulator and the per-row softmax stats
///   co-resident (the fusion's capacity price);
/// * `min_bytes` reads Q, K, V once and writes the context once — the
///   intermediate P round-trip of two separate [`BatchedGemm`]
///   dispatches is dropped (the fusion's traffic win);
/// * `softmax_tile` exposes the score-tile shape the fused row-softmax
///   normalizes, priced by the softmax micro-measurement;
/// * `flops` counts both contractions (4·|iter| instead of 2·|iter|).
pub struct FusedAttention;

impl OpSpec for FusedAttention {
    fn name(&self) -> &'static str {
        "attention"
    }
    fn kind(&self) -> OpKind {
        OpKind::FusedAttention
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 4] = [
            ax('b', AxisRole::Batch),
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn flops(&self, iter: Tile) -> f64 {
        // Two multiply-accumulate contractions share the (b, m, n, k)
        // volume: score (b,m,n over k) and context (b,m,k over n). The
        // O(b·m·n) softmax flops are noise against O(b·m·n·k).
        4.0 * iter.product_f64()
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        let (b, m, n, k) = (tile[0], tile[1], tile[2], tile[3]);
        // Q slab + K slab + resident f32 score tile (the BatchedGemm
        // working set) plus the fusion extras: the staged V slab, the
        // f32 context accumulator and the per-row softmax stats
        // (running max + rescaled sum, f32 each).
        BatchedGemm.working_set(tile, in_bytes)
            + (b * (n * k * in_bytes + m * k * 4 + m * 8)) as u64
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        let (b, m, n, k) = (iter[0], iter[1], iter[2], iter[3]);
        let e = dtype.bytes() as f64;
        // Q, K, V read once; context written once (f32). The b·m·n
        // score/probability intermediate never touches DRAM.
        b as f64 * ((m * k) as f64 * e + (n * k) as f64 * 2.0 * e + (m * k) as f64 * 4.0)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        let (b, m, n, ck) = (parent[0], parent[1], parent[2], child[3]);
        // Per reduction (head-dim) step: the Q and K slabs of the score
        // contraction plus the V slab staged for the context
        // contraction's output columns.
        (b * (m * ck + ck * n + n * ck) * dtype.bytes()) as f64
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        // The context output (b, m, k) in f32 — NOT the b·m·n score.
        (parent[0] * parent[1] * parent[3] * 4) as f64
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        // The chain's contraction blocks ARE batched-GEMM blocks; the
        // fused softmax is a measured epilogue, not an artifact.
        BatchedGemm.artifact_name(l1, dtype)
    }
    fn measurement_op(&self) -> OpKind {
        // One attention block = chain_kernels() cost-symmetric
        // batched-GEMM blocks + the softmax epilogue; the contraction
        // measurements alias BatchedGemm's.
        OpKind::BatchedGemm
    }
    fn chain_kernels(&self) -> usize {
        2
    }
    fn softmax_tile(&self, tile: Tile) -> Option<(usize, usize)> {
        // One block's resident score tile: (b·m) rows of n columns.
        Some((tile[0] * tile[1], tile[2]))
    }
    fn write_axes(&self) -> Vec<(usize, usize)> {
        // The chain's output is the context (b, m, head_dim) — seq_k
        // (axis 2) is reduced away by softmax·context. head_dim (the
        // space's k axis) sits in the context contraction's output-
        // column position, so the runtime tiles it by the L1 tile's
        // *n* extent (axis 2 of the tile), not its k extent.
        vec![(0, 0), (1, 1), (3, 2)]
    }
}

/// Attended (query, key) pairs of a causal tile whose queries are the
/// LAST `m` positions of an `n`-key causal sequence (the decode /
/// prefill-with-cache alignment): query row `i` attends keys
/// `0 ..= n - m + i`, so the count is `m·n − t(t−1)/2` with
/// `t = min(m, n)`. Exact for the semantic case `m <= n`
/// (`m = 1` → `n` pairs, `m = n` → `n(n+1)/2`); clamped by `min` for
/// padded tiles with `m > n` so the count stays monotone in BOTH dims
/// (the candgen/auditor monotonicity contract) and never exceeds
/// `m·n`.
fn causal_pairs(m: usize, n: usize) -> f64 {
    let t = m.min(n) as f64;
    m as f64 * n as f64 - t * (t - 1.0) / 2.0
}

/// Causal-masked attention chain with a resident KV cache — the
/// autoregressive serving variant of [`FusedAttention`]. The iteration
/// space is the same (b, m, n, k) = (batch·heads, seq_q, seq_k,
/// head_dim) batched-GEMM space, but `seq_q != seq_k` is the norm:
/// decode is seq_q = 1 against a seq_k that grows by one per token,
/// prefill is seq_q = seq_k with the triangular mask. Queries align to
/// the LAST seq_q positions of the key sequence.
///
/// What is causal/KV-cache-specific relative to [`FusedAttention`]:
///
/// * `flops` and `load_bytes_per_step` count only the lower-triangular
///   (attended) work — [`causal_pairs`] of the m·n rectangle — so the
///   cost model prices a decode step at O(n·k) per head, not O(m·n·k)
///   of the unmasked rectangle;
/// * `working_set` models the K/V slabs as RESIDENT cache slabs
///   streamed through the score contraction's staging window rather
///   than a second co-staged V operand: the fusion extras are only the
///   f32 context accumulator and the per-row softmax stats;
/// * `min_bytes` is unchanged in shape (Q read once, the K/V cache
///   slabs read once — the last query attends every key — and the
///   context written once; no P round-trip).
///
/// The contraction blocks remain cost-symmetric batched-GEMM blocks
/// (`chain_kernels() == 2`, `measurement_op() == BatchedGemm`), so a
/// causal space with no native library serves through the batched-GEMM
/// alias chain exactly like [`FusedAttention`].
pub struct CausalAttention;

impl OpSpec for CausalAttention {
    fn name(&self) -> &'static str {
        "causal_attention"
    }
    fn kind(&self) -> OpKind {
        OpKind::CausalAttention
    }
    fn axes(&self) -> &'static [Axis] {
        const AXES: [Axis; 4] = [
            ax('b', AxisRole::Batch),
            ax('m', AxisRole::Spatial),
            ax('n', AxisRole::Spatial),
            ax('k', AxisRole::Reduction),
        ];
        &AXES
    }
    fn flops(&self, iter: Tile) -> f64 {
        // Both contractions, masked: only attended (q, key) pairs do
        // multiply-accumulate work in score AND context.
        let (b, m, n, k) = (iter[0], iter[1], iter[2], iter[3]);
        4.0 * b as f64 * causal_pairs(m, n) * k as f64
    }
    fn working_set(&self, tile: Tile, in_bytes: usize) -> u64 {
        let (b, m, k) = (tile[0], tile[1], tile[3]);
        // Q slab + K slab + resident f32 score tile (the BatchedGemm
        // set; the K slab term IS the KV-cache staging window — V
        // streams through the same window for the context contraction,
        // so no second co-resident slab) plus the f32 context
        // accumulator and per-row softmax stats.
        BatchedGemm.working_set(tile, in_bytes) + (b * (m * k * 4 + m * 8)) as u64
    }
    fn min_bytes(&self, iter: Tile, dtype: DType) -> f64 {
        let (b, m, n, k) = (iter[0], iter[1], iter[2], iter[3]);
        let e = dtype.bytes() as f64;
        // Q read once; the resident K and V cache slabs read once each
        // (the last query attends every key, so the full n·k slabs are
        // a true lower bound); context written once (f32).
        b as f64 * ((m * k) as f64 * e + (n * k) as f64 * 2.0 * e + (m * k) as f64 * 4.0)
    }
    fn load_bytes_per_step(&self, parent: Tile, child: Tile, dtype: DType) -> f64 {
        let (b, m, n, ck) = (parent[0], parent[1], parent[2], child[3]);
        // Masked traffic: per head-dim step the Q slab is full, but the
        // K and V cache slabs are only streamed over the attended
        // columns — on average causal_pairs/m keys per query row.
        let n_eff = causal_pairs(m, n) / m as f64;
        b as f64 * ((m * ck) as f64 + 2.0 * n_eff * ck as f64) * dtype.bytes() as f64
    }
    fn store_bytes(&self, parent: Tile) -> f64 {
        // The context output (b, m, k) in f32 — identical to the
        // unmasked chain (masking thins reads, not the output).
        (parent[0] * parent[1] * parent[3] * 4) as f64
    }
    fn artifact_name(&self, l1: Tile, dtype: DType) -> String {
        // Same convention as FusedAttention: the chain's contraction
        // blocks ARE batched-GEMM blocks.
        BatchedGemm.artifact_name(l1, dtype)
    }
    fn measurement_op(&self) -> OpKind {
        OpKind::BatchedGemm
    }
    fn chain_kernels(&self) -> usize {
        2
    }
    fn softmax_tile(&self, tile: Tile) -> Option<(usize, usize)> {
        // The resident score tile shape is the full (b·m, n) rectangle
        // — masked lanes are normalized as -inf, not skipped, so the
        // epilogue measurement prices the same tile as the unmasked
        // chain.
        Some((tile[0] * tile[1], tile[2]))
    }
    fn write_axes(&self) -> Vec<(usize, usize)> {
        // Context output (b, m, head_dim), exactly like FusedAttention.
        vec![(0, 0), (1, 1), (3, 2)]
    }
}

// ---------------------------------------------------------------------------
// IterSpace
// ---------------------------------------------------------------------------

/// A concrete runtime problem: which op, its iteration dims, the dtype.
///
/// Invariant: `dims.rank() == op.spec().rank()` — every constructor
/// here and every [`super::TensorProgram::space`] mapping upholds it,
/// and the selector/cost layers rely on it (tile algebra panics on
/// rank mismatch rather than mis-tiling).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IterSpace {
    pub op: OpKind,
    pub dims: Tile,
    pub dtype: DType,
}

impl IterSpace {
    pub fn gemm(m: usize, n: usize, k: usize, dtype: DType) -> IterSpace {
        IterSpace { op: OpKind::Gemm, dims: Tile::new(&[m, n, k]), dtype }
    }

    pub fn batched_gemm(b: usize, m: usize, n: usize, k: usize, dtype: DType) -> IterSpace {
        IterSpace { op: OpKind::BatchedGemm, dims: Tile::new(&[b, m, n, k]), dtype }
    }

    pub fn flops(&self) -> f64 {
        self.op.spec().flops(self.dims)
    }

    pub fn min_bytes(&self) -> f64 {
        self.op.spec().min_bytes(self.dims, self.dtype)
    }

    /// Fold to the flat contraction view (batch folds into M) — the
    /// lens the GEMM-only baselines see a problem through. For a fused
    /// chain this is ONE constituent kernel (the attention score
    /// contraction); callers dispatching through this view pay one
    /// dispatch per [`OpSpec::chain_kernels`].
    pub fn contraction(&self) -> Contraction {
        match self.op {
            OpKind::Gemm | OpKind::Conv2d => Contraction {
                m: self.dims[0],
                n: self.dims[1],
                k: self.dims[2],
                dtype: self.dtype,
            },
            // Batch-like leading axes fold into M: the baselines see a
            // batched GEMM as one tall GEMM, a grouped conv as its
            // block-diagonal GEMM flattened along the group axis, and
            // an attention chain as its flattened score contraction.
            OpKind::BatchedGemm
            | OpKind::GroupedConv2d
            | OpKind::FusedAttention
            | OpKind::CausalAttention => {
                Contraction {
                    m: self.dims[0] * self.dims[1],
                    n: self.dims[2],
                    k: self.dims[3],
                    dtype: self.dtype,
                }
            }
        }
    }
}

impl From<Contraction> for IterSpace {
    fn from(c: Contraction) -> IterSpace {
        IterSpace::gemm(c.m, c.n, c.k, c.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_algebra() {
        let t = Tile::new(&[100, 64, 33]);
        let l1 = Tile::new(&[64, 64, 32]);
        assert_eq!(t.ceil_div(l1), Tile::new(&[2, 1, 2]));
        assert_eq!(t.round_up_to(l1), Tile::new(&[128, 64, 64]));
        assert_eq!(t.ceil_div(l1).mul(l1), t.round_up_to(l1));
        assert!(Tile::new(&[128, 64, 64]).is_multiple_of(l1));
        assert!(!t.is_multiple_of(l1));
        assert_eq!(t[2], 33);
        assert_eq!(t.rank(), 3);
        assert_eq!(format!("{}", t), "100x64x33");
    }

    #[test]
    fn tile_rank3_orders_like_arrays() {
        let mut tiles = vec![
            Tile::from3([64, 64, 32]),
            Tile::from3([16, 8, 16]),
            Tile::from3([64, 32, 64]),
        ];
        tiles.sort();
        assert_eq!(tiles[0], Tile::from3([16, 8, 16]));
        assert_eq!(tiles[1], Tile::from3([64, 32, 64]));
        assert_eq!(tiles[2], Tile::from3([64, 64, 32]));
    }

    #[test]
    fn gemm_working_set_matches_hw_formula() {
        let t = Tile::from3([64, 128, 256]);
        assert_eq!(
            Gemm.working_set(t, 4),
            crate::hw::HwSpec::gemm_working_set([64, 128, 256], 4)
        );
    }

    #[test]
    fn batched_footprints_scale_with_batch_tile() {
        let g = Tile::from3([64, 64, 32]);
        let b2 = Tile::new(&[2, 64, 64, 32]);
        let b1 = Tile::new(&[1, 64, 64, 32]);
        assert_eq!(BatchedGemm.working_set(b1, 2), Gemm.working_set(g, 2));
        assert_eq!(BatchedGemm.working_set(b2, 2), 2 * Gemm.working_set(g, 2));
        assert_eq!(
            BatchedGemm.store_bytes(b2),
            2.0 * Gemm.store_bytes(g)
        );
        assert_eq!(
            BatchedGemm.load_bytes_per_step(b2, b2, DType::F16),
            2.0 * Gemm.load_bytes_per_step(g, g, DType::F16)
        );
        assert_eq!(BatchedGemm.flops(b2), 2.0 * Gemm.flops(g));
    }

    #[test]
    fn batch_axis_is_parallel_not_temporal() {
        let parent = Tile::new(&[8, 128, 128, 256]);
        let child = Tile::new(&[2, 64, 64, 32]);
        assert_eq!(BatchedGemm.spatial_iters(parent, child), 4 * 2 * 2);
        assert_eq!(BatchedGemm.reduce_iters(parent, child), 8);
    }

    #[test]
    fn isa_lift_gives_batch_granularity_one() {
        let isa = [16, 8, 16];
        assert_eq!(Gemm.isa_tile(isa), Tile::from3([16, 8, 16]));
        assert_eq!(BatchedGemm.isa_tile(isa), Tile::new(&[1, 16, 8, 16]));
    }

    #[test]
    fn reduction_axis_is_last_for_every_op() {
        for op in OpKind::ALL {
            let axes = op.spec().axes();
            assert_eq!(axes.last().unwrap().role, AxisRole::Reduction, "{}", op);
            assert_eq!(
                axes.iter().filter(|a| a.role == AxisRole::Reduction).count(),
                1,
                "{}",
                op
            );
        }
    }

    #[test]
    fn opkind_name_round_trip() {
        for op in OpKind::ALL {
            assert_eq!(OpKind::parse(op.name()), Some(op));
        }
        assert_eq!(OpKind::parse("attention"), Some(OpKind::FusedAttention));
        // "softmax" is BY DESIGN not an op string: the row-softmax is
        // the fused epilogue of the attention chain — a profiler
        // micro-measurement (Profiler::measure_softmax), never a
        // standalone strategy space or library key.
        assert_eq!(OpKind::parse("softmax"), None);
    }

    #[test]
    fn artifact_names() {
        let l1 = Tile::from3([64, 256, 512]);
        assert_eq!(
            Gemm.artifact_name(l1, DType::F32),
            "gemm_acc_64x256x512_f32"
        );
        // conv shares the gemm_acc artifacts (implicit GEMM)
        assert_eq!(
            Conv2d.artifact_name(l1, DType::F32),
            "gemm_acc_64x256x512_f32"
        );
        assert_eq!(
            BatchedGemm.artifact_name(Tile::new(&[2, 64, 64, 32]), DType::F16),
            "bgemm_acc_2x64x64x32_f16"
        );
    }

    #[test]
    fn grouped_conv_delegates_every_formula_to_batched_gemm() {
        let parent = Tile::new(&[4, 128, 128, 256]);
        let child = Tile::new(&[2, 64, 64, 32]);
        assert_eq!(
            GroupedConv2d.working_set(child, 2),
            BatchedGemm.working_set(child, 2)
        );
        assert_eq!(
            GroupedConv2d.min_bytes(parent, DType::F16),
            BatchedGemm.min_bytes(parent, DType::F16)
        );
        assert_eq!(
            GroupedConv2d.load_bytes_per_step(parent, child, DType::F16),
            BatchedGemm.load_bytes_per_step(parent, child, DType::F16)
        );
        assert_eq!(GroupedConv2d.store_bytes(parent), BatchedGemm.store_bytes(parent));
        assert_eq!(
            GroupedConv2d.artifact_name(child, DType::F16),
            BatchedGemm.artifact_name(child, DType::F16)
        );
        assert_eq!(GroupedConv2d.measurement_op(), OpKind::BatchedGemm);
        // The group axis lifts like a batch axis: ISA granularity 1.
        assert_eq!(
            GroupedConv2d.isa_tile([16, 8, 16]),
            Tile::new(&[1, 16, 8, 16])
        );
    }

    #[test]
    fn grouped_conv_contraction_folds_groups_into_m() {
        let s = IterSpace {
            op: OpKind::GroupedConv2d,
            dims: Tile::new(&[32, 1568, 4, 288]),
            dtype: DType::F32,
        };
        let c = s.contraction();
        assert_eq!((c.m, c.n, c.k), (32 * 1568, 4, 288));
        assert_eq!(s.flops(), c.flops());
    }

    #[test]
    fn iterspace_contraction_folds_batch() {
        let s = IterSpace::batched_gemm(12, 128, 64, 64, DType::F32);
        let c = s.contraction();
        assert_eq!((c.m, c.n, c.k), (12 * 128, 64, 64));
        assert_eq!(s.flops(), c.flops());
    }

    #[test]
    fn attention_is_a_two_kernel_batched_gemm_chain() {
        // The chain's contraction blocks alias BatchedGemm: shared
        // artifact names, shared measurements, batch-granularity-1 ISA
        // lift — with two kernels per block and a softmax epilogue.
        let t = Tile::new(&[2, 64, 64, 32]);
        assert_eq!(FusedAttention.measurement_op(), OpKind::BatchedGemm);
        assert_eq!(FusedAttention.chain_kernels(), 2);
        assert_eq!(
            FusedAttention.artifact_name(t, DType::F16),
            BatchedGemm.artifact_name(t, DType::F16)
        );
        assert_eq!(
            FusedAttention.isa_tile([16, 8, 16]),
            Tile::new(&[1, 16, 8, 16])
        );
        // Both contractions counted: 2x the single-kernel flops.
        assert_eq!(FusedAttention.flops(t), 2.0 * BatchedGemm.flops(t));
        assert_eq!(FusedAttention.softmax_tile(t), Some((2 * 64, 64)));
        assert_eq!(BatchedGemm.softmax_tile(t), None);
        assert_eq!(BatchedGemm.chain_kernels(), 1);
    }

    #[test]
    fn attention_working_set_keeps_score_tile_and_fusion_extras_resident() {
        let t = Tile::new(&[2, 64, 48, 32]);
        let (b, m, n, k, e) = (2u64, 64u64, 48u64, 32u64, 2u64);
        // Q + K + score (the bgemm set) + V slab + ctx acc + row stats.
        let bgemm = b * (m * k * e + k * n * e + m * n * 4);
        let extras = b * (n * k * e + m * k * 4 + m * 8);
        assert_eq!(FusedAttention.working_set(t, 2), bgemm + extras);
        assert!(FusedAttention.working_set(t, 2) > BatchedGemm.working_set(t, 2));
    }

    #[test]
    fn causal_pairs_counts_the_attended_triangle() {
        // Decode: one query attends every key.
        assert_eq!(causal_pairs(1, 100), 100.0);
        // Square prefill: the lower triangle incl. the diagonal.
        assert_eq!(causal_pairs(8, 8), (8 * 9 / 2) as f64);
        // Chunked prefill (m < n): full rows over the cached prefix.
        assert_eq!(causal_pairs(4, 10), (4 * 10 - 6) as f64);
        // Padded tile with m > n stays clamped and monotone.
        assert_eq!(causal_pairs(10, 4), (10 * 4 - 6) as f64);
        for m in 1..20 {
            for n in 1..20 {
                assert!(causal_pairs(m, n) <= (m * n) as f64);
                assert!(causal_pairs(m + 1, n) >= causal_pairs(m, n));
                assert!(causal_pairs(m, n + 1) >= causal_pairs(m, n));
            }
        }
    }

    #[test]
    fn causal_attention_masks_flops_and_traffic() {
        // Decode tile: seq_q = 1 — the mask is a no-op (one query sees
        // every key), so the masked chain prices exactly like the
        // fused chain minus the duplicate V staging slab.
        let dec = Tile::new(&[12, 1, 256, 64]);
        assert_eq!(CausalAttention.flops(dec), FusedAttention.flops(dec));
        // Square prefill tile: roughly half the rectangle's work.
        let pre = Tile::new(&[12, 256, 256, 64]);
        let frac = causal_pairs(256, 256) / (256.0 * 256.0);
        assert_eq!(CausalAttention.flops(pre), FusedAttention.flops(pre) * frac);
        assert!(CausalAttention.flops(pre) < FusedAttention.flops(pre));
        assert!(
            CausalAttention.load_bytes_per_step(pre, Tile::new(&[1, 64, 64, 32]), DType::F16)
                < FusedAttention.load_bytes_per_step(pre, Tile::new(&[1, 64, 64, 32]), DType::F16)
        );
        // The output and the Q/K/V once-through lower bound are NOT
        // masked: the last query attends every cached key.
        assert_eq!(
            CausalAttention.min_bytes(pre, DType::F16),
            FusedAttention.min_bytes(pre, DType::F16)
        );
        assert_eq!(CausalAttention.store_bytes(pre), FusedAttention.store_bytes(pre));
    }

    #[test]
    fn causal_attention_kv_cache_working_set_drops_the_v_slab() {
        let t = Tile::new(&[2, 64, 48, 32]);
        let (b, m, n, k, e) = (2u64, 64u64, 48u64, 32u64, 2u64);
        // Q + K-staging-window + score (the bgemm set) + ctx acc + row
        // stats; no second co-resident V slab (V streams through the
        // K window from the resident cache).
        let bgemm = b * (m * k * e + k * n * e + m * n * 4);
        let extras = b * (m * k * 4 + m * 8);
        assert_eq!(CausalAttention.working_set(t, 2), bgemm + extras);
        assert!(CausalAttention.working_set(t, 2) < FusedAttention.working_set(t, 2));
        // Monotone in every dim (candgen/auditor contract).
        for axis in 0..4 {
            let mut bigger = t;
            bigger[axis] *= 2;
            assert!(CausalAttention.working_set(bigger, 2) > CausalAttention.working_set(t, 2));
        }
    }

    #[test]
    fn causal_attention_aliases_batched_gemm_like_the_fused_chain() {
        let t = Tile::new(&[2, 64, 64, 32]);
        assert_eq!(CausalAttention.measurement_op(), OpKind::BatchedGemm);
        assert_eq!(CausalAttention.chain_kernels(), 2);
        assert_eq!(
            CausalAttention.artifact_name(t, DType::F16),
            BatchedGemm.artifact_name(t, DType::F16)
        );
        assert_eq!(CausalAttention.softmax_tile(t), Some((2 * 64, 64)));
        assert_eq!(CausalAttention.write_axes(), FusedAttention.write_axes());
        assert_eq!(OpKind::parse("causal_attention"), Some(OpKind::CausalAttention));
    }

    #[test]
    fn attention_min_bytes_drops_the_intermediate_round_trip() {
        // Fused traffic = Q + K + V + ctx out. Two separate batched
        // dispatches additionally write the b·m·n f32 score and read
        // the b·m·n probability matrix back.
        let t = Tile::new(&[4, 128, 96, 64]);
        let (b, m, n, k) = (4.0, 128.0, 96.0, 64.0);
        let e = 2.0; // f16
        let fused = FusedAttention.min_bytes(t, DType::F16);
        assert_eq!(fused, b * (m * k * e + 2.0 * n * k * e + m * k * 4.0));
        // score dispatch: Q + K read, score written (f32 accumulator)
        let score = BatchedGemm.min_bytes(t, DType::F16);
        // ctx dispatch: P (b,m,n) + V (b,n,k) read, ctx (b,m,k) written
        let ctx = BatchedGemm.min_bytes(Tile::new(&[4, 128, 64, 96]), DType::F16);
        assert!(fused < score + ctx, "{} !< {}", fused, score + ctx);
    }
}
