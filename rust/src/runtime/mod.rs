//! Real execution runtime: the PJRT side of the three-layer stack.
//!
//! Loads the AOT artifacts (`artifacts/*.hlo.txt` + `manifest.json`)
//! produced once by `python/compile/aot.py`, compiles them on the PJRT
//! CPU client (`xla` crate), and exposes the *kernel constructor*
//! execution path: a dynamic-shape GEMM is served by padding to the
//! selected micro-kernel's block, looping the launch grid, and chaining
//! the `gemm_acc` block executable over K super-blocks — the runtime
//! stage of the paper realized with real binaries. Python is never on
//! this path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::Diagnostic;
use crate::ir::{ceil_div, DType};
use crate::util::json::Json;

/// Tensor I/O spec recorded by aot.py for every artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact (a static-shape compiled computation).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub params: Json,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactEntry {
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key)?.as_usize()
    }

    /// (bm, bn, bk) for gemm-family artifacts.
    pub fn block(&self) -> Option<[usize; 3]> {
        Some([
            self.param_usize("bm")?,
            self.param_usize("bn")?,
            self.param_usize("bk")?,
        ])
    }

    /// (bb, bm, bn, bk) for batched-gemm (`bgemm_acc`) artifacts.
    pub fn block4(&self) -> Option<[usize; 4]> {
        Some([
            self.param_usize("bb")?,
            self.param_usize("bm")?,
            self.param_usize("bn")?,
            self.param_usize("bk")?,
        ])
    }

    pub fn in_dtype(&self) -> DType {
        self.params
            .get("in_dtype")
            .and_then(|v| v.as_str())
            .and_then(DType::parse)
            .unwrap_or(DType::F32)
    }

    /// The Pallas inner tile (tm, tn, tk) recorded by aot.py — the L0
    /// tile of the micro-kernel library. A gemm-family entry without it
    /// is a malformed manifest, not an excuse for a plausible-looking
    /// default tile.
    pub fn l0_block(&self) -> Result<[usize; 3]> {
        let get = |key: &str| {
            self.param_usize(key).ok_or_else(|| {
                anyhow!(
                    "manifest entry {}: missing/invalid param {:?} \
                     (regenerate with `make artifacts`)",
                    self.name,
                    key
                )
            })
        };
        Ok([get("tm")?, get("tn")?, get("tk")?])
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

fn parse_io(v: &Json) -> Option<Vec<IoSpec>> {
    v.as_arr()?
        .iter()
        .map(|io| {
            Some(IoSpec {
                shape: io
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Option<Vec<_>>>()?,
                dtype: io.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?} (run `make artifacts`)", path))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {}", path.display(), e))?;
        let arr = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        // Per-entry parse with a context-rich rejection: the error
        // names the entry index and its name (when present) through
        // the auditor's diagnostic struct, so a 50-entry manifest
        // pinpoints the one bad entry instead of a bare "malformed".
        let mut entries = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let parse = || -> Option<ArtifactEntry> {
                Some(ArtifactEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    kind: e.get("kind")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    params: e.get("params")?.clone(),
                    inputs: parse_io(e.get("inputs")?)?,
                    outputs: parse_io(e.get("outputs")?)?,
                })
            };
            let entry = parse().ok_or_else(|| {
                let name = e.get("name").and_then(|n| n.as_str()).unwrap_or("<unnamed>");
                let d = Diagnostic::error(
                    "manifest.malformed_entry",
                    "missing/invalid name, kind, file, params, inputs or outputs",
                )
                .with_entry(format!("entry #{i} ({name})"));
                anyhow!("{}: {d}", path.display())
            })?;
            entries.push(entry);
        }
        // Duplicate artifact names would make `find` silently return
        // whichever entry comes first — reject the manifest instead.
        let mut seen = std::collections::HashSet::new();
        for e in &entries {
            if !seen.insert(e.name.as_str()) {
                let d = Diagnostic::error(
                    "manifest.duplicate_name",
                    format!("duplicate artifact name {:?}", e.name),
                )
                .with_entry(e.name.clone());
                bail!("{}: {d}", path.display());
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All gemm_acc blocks of a dtype, as (block, artifact name).
    pub fn gemm_acc_blocks(&self, dtype: DType) -> Vec<([usize; 3], String)> {
        self.entries
            .iter()
            .filter(|e| e.kind == "gemm_acc" && e.in_dtype() == dtype)
            .filter_map(|e| Some((e.block()?, e.name.clone())))
            .collect()
    }

    /// All bgemm_acc blocks of a dtype, as ((bb, bm, bn, bk), name).
    pub fn bgemm_acc_blocks(&self, dtype: DType) -> Vec<([usize; 4], String)> {
        self.entries
            .iter()
            .filter(|e| e.kind == "bgemm_acc" && e.in_dtype() == dtype)
            .filter_map(|e| Some((e.block4()?, e.name.clone())))
            .collect()
    }

    /// Stable fingerprint of the AOT artifact set: every entry's name,
    /// kind, parameters (deterministically serialized) and — when the
    /// artifact file is readable — its bytes. Feed this into
    /// [`crate::compiler::CompileOpts::aot_fingerprint`] so on-disk
    /// library caches keyed on real-testbed blocks invalidate when the
    /// Pallas blocks are regenerated (ROADMAP offline-stage item).
    pub fn fingerprint(&self) -> u64 {
        use crate::util::rng::{fnv1a, hash_key};
        let mut parts: Vec<u64> = Vec::with_capacity(self.entries.len() * 4);
        for e in &self.entries {
            parts.push(fnv1a(e.name.as_bytes()));
            parts.push(fnv1a(e.kind.as_bytes()));
            parts.push(fnv1a(e.params.dump().as_bytes()));
            if let Ok(bytes) = std::fs::read(self.dir.join(&e.file)) {
                parts.push(fnv1a(&bytes));
            }
        }
        hash_key(&parts)
    }
}

/// A virtual row-major `(rows x cols)` f32 operand the kernel
/// constructor gathers L1 blocks from — the zero-materialization half
/// of implicit GEMM. The constructor only ever asks for one
/// block-shaped window at a time (`gather_block`), so a conv patch
/// matrix or a transposed K operand never exists in memory: the view
/// packs each window on demand at the L1 tile boundary.
#[derive(Debug, Clone, Copy)]
pub enum OperandSource<'a> {
    /// A dense row-major matrix, optionally a column slab of a wider
    /// backing matrix (`row_stride` > `cols`, starting at `col0`) —
    /// this is how one group's filter slab is viewed inside the full
    /// (kh·kw·cg, cout) filter without the copy `filter_group` makes.
    Dense {
        data: &'a [f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col0: usize,
    },
    /// The im2col patch view of one conv channel group: rows are
    /// output positions (b, oy, ox), columns are filter taps in
    /// (i, j, c) order over `cg` channels starting at `chan.0` —
    /// exactly the matrix [`im2col_patches`] materializes, but never
    /// allocated. Taps in the zero-padding halo read as zero.
    Im2col {
        x: &'a [f32],
        /// (n, h, w, cin) of the NHWC input.
        io: (usize, usize, usize, usize),
        /// (kh, kw).
        filt: (usize, usize),
        /// (stride, pad).
        geom: (usize, usize),
        /// (c0, cg) channel slice of this group.
        chan: (usize, usize),
        /// (oh, ow), precomputed by the constructor.
        out: (usize, usize),
    },
    /// The transpose of a dense `(cols x rows)` row-major matrix:
    /// element (r, c) is `data[c * rows + r]`. Attention's per-group
    /// Kᵀ operand is this view — the explicit transpose copy is gone.
    Transpose { data: &'a [f32], rows: usize, cols: usize },
}

impl<'a> OperandSource<'a> {
    pub fn dense(data: &'a [f32], rows: usize, cols: usize) -> Self {
        Self::dense_strided(data, rows, cols, cols, 0)
    }

    /// A `(rows x cols)` column slab starting at `col0` of a dense
    /// backing matrix whose physical row length is `row_stride`.
    pub fn dense_strided(
        data: &'a [f32],
        rows: usize,
        cols: usize,
        row_stride: usize,
        col0: usize,
    ) -> Self {
        assert!(col0 + cols <= row_stride, "slab {}+{} exceeds stride {}", col0, cols, row_stride);
        assert!(
            data.len() >= rows * row_stride,
            "dense source: {} elems for {} rows of stride {}",
            data.len(),
            rows,
            row_stride
        );
        OperandSource::Dense { data, rows, cols, row_stride, col0 }
    }

    /// Im2col patch view; panics on invalid conv geometry (mirrors
    /// [`im2col_patches`] — geometry is validated at program
    /// construction, this is a defense-in-depth check).
    pub fn im2col(
        x: &'a [f32],
        io: (usize, usize, usize, usize),
        filt: (usize, usize),
        geom: (usize, usize),
        chan: (usize, usize),
    ) -> Self {
        let (n, h, wd, cin) = io;
        let out = crate::ir::conv_out_dims((h, wd), filt, geom.0, geom.1)
            .expect("OperandSource::im2col: invalid conv geometry");
        let (c0, cg) = chan;
        assert!(c0 + cg <= cin, "channel slice {}+{} exceeds cin {}", c0, cg, cin);
        assert_eq!(x.len(), n * h * wd * cin, "im2col source: input len mismatch");
        OperandSource::Im2col { x, io, filt, geom, chan, out }
    }

    /// Transposed view of a `(cols x rows)` row-major matrix.
    pub fn transpose(data: &'a [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "transpose source: len mismatch");
        OperandSource::Transpose { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        match *self {
            OperandSource::Dense { rows, .. } => rows,
            OperandSource::Im2col { io, out, .. } => io.0 * out.0 * out.1,
            OperandSource::Transpose { rows, .. } => rows,
        }
    }

    pub fn cols(&self) -> usize {
        match *self {
            OperandSource::Dense { cols, .. } => cols,
            OperandSource::Im2col { filt, chan, .. } => filt.0 * filt.1 * chan.1,
            OperandSource::Transpose { cols, .. } => cols,
        }
    }

    /// Gather the `(br x bc)` block at (r0, c0) into `dst` (row-major,
    /// row stride `bc`), zero-padding rows/columns past the operand
    /// edge — the one primitive every execution path (device fast
    /// path, batched path, host mirrors) packs L1 tiles with.
    pub fn gather_block(&self, dst: &mut [f32], r0: usize, c0: usize, br: usize, bc: usize) {
        assert_eq!(dst.len(), br * bc, "gather_block: dst {} for {}x{}", dst.len(), br, bc);
        let vr = self.rows().saturating_sub(r0).min(br);
        let vc = self.cols().saturating_sub(c0).min(bc);
        if vr == 0 || vc == 0 {
            dst.fill(0.0);
            return;
        }
        match *self {
            OperandSource::Dense { data, row_stride, col0, .. } => {
                if vr < br || vc < bc {
                    dst.fill(0.0);
                }
                for r in 0..vr {
                    let src = (r0 + r) * row_stride + col0 + c0;
                    dst[r * bc..r * bc + vc].copy_from_slice(&data[src..src + vc]);
                }
            }
            OperandSource::Transpose { data, rows, .. } => {
                if vr < br || vc < bc {
                    dst.fill(0.0);
                }
                for r in 0..vr {
                    let row = r * bc;
                    for c in 0..vc {
                        dst[row + c] = data[(c0 + c) * rows + (r0 + r)];
                    }
                }
            }
            OperandSource::Im2col { x, io, filt, geom, chan, out } => {
                dst.fill(0.0); // padding-halo taps must stay zero
                let (_n, h, wd, cin) = io;
                let (kh, kw) = filt;
                let (stride, pad) = geom;
                let (ch0, cg) = chan;
                let (oh, ow) = out;
                for r in 0..vr {
                    let row = r0 + r;
                    let b = row / (oh * ow);
                    let rem = row % (oh * ow);
                    let (oy, ox) = (rem / ow, rem % ow);
                    let iy0 = (oy * stride) as isize - pad as isize;
                    let ix0 = (ox * stride) as isize - pad as isize;
                    let drow = r * bc;
                    // Only the taps whose cg-channel runs intersect
                    // [c0, c0 + vc) are touched.
                    for tap in c0 / cg..(c0 + vc).div_ceil(cg) {
                        let (i, j) = (tap / kw, tap % kw);
                        debug_assert!(i < kh);
                        let iy = iy0 + i as isize;
                        let ix = ix0 + j as isize;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= wd as isize {
                            continue; // halo: stays zero
                        }
                        let lo = (tap * cg).max(c0);
                        let hi = ((tap + 1) * cg).min(c0 + vc);
                        let src = ((b * h + iy as usize) * wd + ix as usize) * cin
                            + ch0
                            + (lo - tap * cg);
                        dst[drow + (lo - c0)..drow + (hi - c0)]
                            .copy_from_slice(&x[src..src + hi - lo]);
                    }
                }
            }
        }
    }

    /// Materialize the full `(rows x cols)` matrix (reference/non-f32
    /// fallback paths and tests; the fast paths never call this).
    pub fn materialize(&self) -> Vec<f32> {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0f32; r * c];
        if r > 0 && c > 0 {
            self.gather_block(&mut out, 0, 0, r, c);
        }
        out
    }
}

/// Transient scratch f32 elements the tiled constructor holds per grid
/// cell: one A block, one B block, one C block. This is the O(tile)
/// bound implicit-GEMM conv is held to — compare the O(m · kh·kw·cg)
/// patch matrix the materializing [`im2col_patches`] baseline builds.
pub fn tile_scratch_elems([bm, bn, bk]: [usize; 3]) -> usize {
    bm * bk + bk * bn + bm * bn
}

/// Below this many (M, N) grid cells the walk stays sequential. A cell
/// is a whole K chain of device launches (tens of microseconds each),
/// so — unlike the dispatch layer's element-count threshold for
/// nanosecond-scale comparisons — a handful of cells already amortizes
/// thread spawn.
const PARALLEL_GRID_MIN_CELLS: usize = 4;

/// Worker count for the parallel grid walk (same clamp as the
/// compiler's per-L1 ranking pass).
fn grid_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 16)
}

/// Deterministic parallel map over grid cells: each cell's result is
/// computed into its own slot (scoped threads own disjoint chunks of
/// the slot array) and returned in cell order, so the caller's scatter
/// runs in the same order regardless of thread count — the output is
/// bit-identical to the sequential walk by construction. K chains
/// never cross a cell boundary, so they stay sequential per cell.
fn run_cells<T, F>(n_cells: usize, threads: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if threads <= 1 || n_cells <= 1 {
        return (0..n_cells).map(&f).collect();
    }
    let mut slots: Vec<Option<Result<T>>> = (0..n_cells).map(|_| None).collect();
    let chunk = n_cells.div_ceil(threads).max(1);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = slots
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, sc)| {
                s.spawn(move || {
                    for (off, slot) in sc.iter_mut().enumerate() {
                        *slot = Some(f(ci * chunk + off));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("grid worker panicked");
        }
    });
    slots.into_iter().map(|s| s.expect("cell not visited")).collect()
}

fn gemm_artifact_name([bm, bn, bk]: [usize; 3], dtype: DType) -> String {
    format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name())
}

fn bgemm_artifact_name([bb, bm, bn, bk]: [usize; 4], dtype: DType) -> String {
    format!("bgemm_acc_{}x{}x{}x{}_{}", bb, bm, bn, bk, dtype.name())
}

/// The real engine: PJRT CPU client + lazily compiled executables.
pub struct RealEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RwLock<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl RealEngine {
    pub fn load(artifacts_dir: &Path) -> Result<RealEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(RealEngine { client, manifest, exes: RwLock::new(HashMap::new()) })
    }

    /// Compile (once) and return the executable for an artifact. The
    /// handle is an `Arc` so the parallel grid walk can hand clones to
    /// scoped worker threads without touching the cache lock again.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.read().expect("exes lock").get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.exes.write().expect("exes lock").insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.read().expect("exes lock").len()
    }

    /// Build a literal of `dtype` with the given dims from f32 host data.
    fn literal(&self, data: &[f32], dims: &[i64], dtype: DType) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data).reshape(dims)?;
        match dtype {
            DType::F32 => Ok(lit),
            DType::Bf16 => Ok(lit.convert(xla::PrimitiveType::Bf16)?),
            DType::F16 => Ok(lit.convert(xla::PrimitiveType::F16)?),
        }
    }

    fn spec_dtype(spec: &IoSpec) -> DType {
        match spec.dtype.as_str() {
            "bfloat16" | "bf16" => DType::Bf16,
            "float16" | "f16" => DType::F16,
            _ => DType::F32,
        }
    }

    /// Run a 1-output artifact on f32 host buffers; returns f32 data.
    /// Inputs are converted to each declared input dtype.
    pub fn run_raw(&self, name: &str, inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let lits = inputs
            .iter()
            .zip(entry.inputs.iter())
            .map(|((data, dims), spec)| self.literal(data, dims, Self::spec_dtype(spec)))
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = if result.shape()?.is_tuple() {
            result.to_tuple1()?
        } else {
            result
        };
        let out = match out.ty()? {
            xla::ElementType::F32 => out,
            _ => out.convert(xla::PrimitiveType::F32)?,
        };
        Ok(out.to_vec::<f32>()?)
    }

    /// Dynamic-shape GEMM via the kernel constructor: pad to the block,
    /// loop the grid, chain `gemm_acc` over K super-blocks (paper §6.2).
    ///
    /// `a` is row-major (m x k), `b` is (k x n); returns row-major
    /// (m x n) f32. Dense wrapper over [`RealEngine::gemm_dynamic_src`].
    pub fn gemm_dynamic(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        if dtype != DType::F32 {
            return self.gemm_dynamic_literal(a, b, (m, n, k), block, dtype);
        }
        let a_src = OperandSource::dense(a, m, k);
        let b_src = OperandSource::dense(b, k, n);
        self.gemm_dynamic_src(&a_src, &b_src, block, dtype)
    }

    /// The kernel-constructor core over [`OperandSource`] operands:
    /// shapes come from the sources (`m = a.rows()`, `k = a.cols()`,
    /// `n = b.cols()`), and every L1 block is packed on demand by
    /// `gather_block` — an im2col or transposed operand is never
    /// materialized (transient scratch stays [`tile_scratch_elems`]).
    ///
    /// §Perf fast path (f32): every A and B block is gathered and
    /// uploaded to a device buffer exactly once (B blocks are hit `gm`
    /// times, A blocks `gn` times), the accumulator stays device-
    /// resident across each K chain (the untupled output buffer feeds
    /// the next call directly), a single shared zero buffer seeds
    /// every (M, N) cell, and the (M, N) grid cells run on scoped
    /// worker threads (`run_cells`) with deterministic output
    /// placement — bit-identical to the sequential walk.
    pub fn gemm_dynamic_src(
        &self,
        a: &OperandSource<'_>,
        b: &OperandSource<'_>,
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        if b.rows() != k {
            bail!("gemm_dynamic_src: inner dims {} vs {}", k, b.rows());
        }
        if dtype != DType::F32 {
            // Reference path: materialize through the same gathers.
            let (a_mat, b_mat) = (a.materialize(), b.materialize());
            return self.gemm_dynamic_literal(&a_mat, &b_mat, (m, n, k), block, dtype);
        }
        let [bm, bn, bk] = block;
        let name = gemm_artifact_name(block, dtype);
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let exe = self.executable(&name)?;
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));

        // Gather + upload every block once, before the grid walk, so
        // worker cells only touch device buffers.
        let mut blk = vec![0f32; (bm * bk).max(bk * bn)];
        let mut b_bufs: Vec<Vec<xla::PjRtBuffer>> = Vec::with_capacity(gk);
        for ki in 0..gk {
            let mut row = Vec::with_capacity(gn);
            for ni in 0..gn {
                let b_blk = &mut blk[..bk * bn];
                b.gather_block(b_blk, ki * bk, ni * bn, bk, bn);
                row.push(self.client.buffer_from_host_buffer(b_blk, &[bk, bn], None)?);
            }
            b_bufs.push(row);
        }
        let mut a_bufs: Vec<Vec<xla::PjRtBuffer>> = Vec::with_capacity(gm);
        for mi in 0..gm {
            let mut row = Vec::with_capacity(gk);
            for ki in 0..gk {
                let a_blk = &mut blk[..bm * bk];
                a.gather_block(a_blk, mi * bm, ki * bk, bm, bk);
                row.push(self.client.buffer_from_host_buffer(a_blk, &[bm, bk], None)?);
            }
            a_bufs.push(row);
        }
        let zeros = vec![0f32; bm * bn];
        let zero_buf = self.client.buffer_from_host_buffer(&zeros, &[bm, bn], None)?;

        let n_cells = gm * gn;
        let threads = if n_cells >= PARALLEL_GRID_MIN_CELLS { grid_threads() } else { 1 };
        let blocks = run_cells(n_cells, threads, |idx| {
            let (mi, ni) = (idx / gn, idx % gn);
            // Device-resident accumulator chain over K (sequential
            // within the cell by construction).
            let mut c_buf: Option<xla::PjRtBuffer> = None;
            for ki in 0..gk {
                let c_in = c_buf.as_ref().unwrap_or(&zero_buf);
                let mut res = exe.execute_b(&[&a_bufs[mi][ki], &b_bufs[ki][ni], c_in])?;
                c_buf = Some(res.swap_remove(0).swap_remove(0));
            }
            Ok(c_buf.unwrap().to_literal_sync()?.to_vec::<f32>()?)
        })?;

        // Scatter in cell order: placement is a pure function of the
        // cell index, so the parallel walk cannot reorder the output.
        let mut out = vec![0f32; m * n];
        for (idx, c_blk) in blocks.iter().enumerate() {
            let (mi, ni) = (idx / gn, idx % gn);
            let (m0, n0) = (mi * bm, ni * bn);
            let mrows = bm.min(m - m0);
            let ncols = bn.min(n - n0);
            for r in 0..mrows {
                let dst = (m0 + r) * n + n0;
                out[dst..dst + ncols].copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
            }
        }
        Ok(out)
    }

    /// Batched dynamic GEMM: `batch` independent (m x k) · (k x n)
    /// problems (one per [`OperandSource`] pair) served by the native
    /// `bgemm_acc` artifact — the batch/group/head loop runs on-device
    /// in chunks of the block's batch extent `bb`, with device-resident
    /// accumulator chains per (chunk, M, N) cell and the same parallel
    /// deterministic grid walk as [`RealEngine::gemm_dynamic_src`].
    /// Returns the concatenated (batch, m, n) result.
    ///
    /// When the manifest has no `bgemm_acc` artifact for the block (or
    /// dtype != f32), falls back to the per-group constructor loop
    /// through the same sources — still zero-materialization, so
    /// callers route through here unconditionally.
    pub fn bgemm_dynamic(
        &self,
        a_srcs: &[OperandSource<'_>],
        b_srcs: &[OperandSource<'_>],
        (m, n, k): (usize, usize, usize),
        block: [usize; 4],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let batch = a_srcs.len();
        if batch == 0 || b_srcs.len() != batch {
            bail!("bgemm_dynamic: {} A sources vs {} B sources", batch, b_srcs.len());
        }
        for (g, (a, b)) in a_srcs.iter().zip(b_srcs).enumerate() {
            if a.rows() != m || a.cols() != k || b.rows() != k || b.cols() != n {
                bail!(
                    "bgemm_dynamic: group {} is ({}x{})·({}x{}), want ({}x{})·({}x{})",
                    g,
                    a.rows(),
                    a.cols(),
                    b.rows(),
                    b.cols(),
                    m,
                    k,
                    k,
                    n
                );
            }
        }
        let [bb, bm, bn, bk] = block;
        let name = bgemm_artifact_name(block, dtype);
        if dtype != DType::F32 || self.manifest.find(&name).is_none() {
            // Per-group fallback through the same block providers.
            let mut out = vec![0f32; batch * m * n];
            for (g, (a, b)) in a_srcs.iter().zip(b_srcs).enumerate() {
                let c = self.gemm_dynamic_src(a, b, [bm, bn, bk], dtype)?;
                out[g * m * n..(g + 1) * m * n].copy_from_slice(&c);
            }
            return Ok(out);
        }
        let exe = self.executable(&name)?;
        let gb = ceil_div(batch, bb);
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));

        // Gather + upload every (batch-chunk, grid) block once. Groups
        // past the batch edge pad with zeros inside their chunk.
        let mut chunk = vec![0f32; bb * (bm * bk).max(bk * bn)];
        let mut b_bufs: Vec<Vec<Vec<xla::PjRtBuffer>>> = Vec::with_capacity(gb);
        for bi in 0..gb {
            let mut per_k = Vec::with_capacity(gk);
            for ki in 0..gk {
                let mut row = Vec::with_capacity(gn);
                for ni in 0..gn {
                    let buf = &mut chunk[..bb * bk * bn];
                    for g in 0..bb {
                        let sub = &mut buf[g * bk * bn..(g + 1) * bk * bn];
                        match b_srcs.get(bi * bb + g) {
                            Some(src) => src.gather_block(sub, ki * bk, ni * bn, bk, bn),
                            None => sub.fill(0.0),
                        }
                    }
                    row.push(self.client.buffer_from_host_buffer(buf, &[bb, bk, bn], None)?);
                }
                per_k.push(row);
            }
            b_bufs.push(per_k);
        }
        let mut a_bufs: Vec<Vec<Vec<xla::PjRtBuffer>>> = Vec::with_capacity(gb);
        for bi in 0..gb {
            let mut per_m = Vec::with_capacity(gm);
            for mi in 0..gm {
                let mut row = Vec::with_capacity(gk);
                for ki in 0..gk {
                    let buf = &mut chunk[..bb * bm * bk];
                    for g in 0..bb {
                        let sub = &mut buf[g * bm * bk..(g + 1) * bm * bk];
                        match a_srcs.get(bi * bb + g) {
                            Some(src) => src.gather_block(sub, mi * bm, ki * bk, bm, bk),
                            None => sub.fill(0.0),
                        }
                    }
                    row.push(self.client.buffer_from_host_buffer(buf, &[bb, bm, bk], None)?);
                }
                per_m.push(row);
            }
            a_bufs.push(per_m);
        }
        let zeros = vec![0f32; bb * bm * bn];
        let zero_buf = self.client.buffer_from_host_buffer(&zeros, &[bb, bm, bn], None)?;

        let n_cells = gb * gm * gn;
        let threads = if n_cells >= PARALLEL_GRID_MIN_CELLS { grid_threads() } else { 1 };
        let blocks = run_cells(n_cells, threads, |idx| {
            let bi = idx / (gm * gn);
            let (mi, ni) = ((idx / gn) % gm, idx % gn);
            let mut c_buf: Option<xla::PjRtBuffer> = None;
            for ki in 0..gk {
                let c_in = c_buf.as_ref().unwrap_or(&zero_buf);
                let mut res =
                    exe.execute_b(&[&a_bufs[bi][mi][ki], &b_bufs[bi][ki][ni], c_in])?;
                c_buf = Some(res.swap_remove(0).swap_remove(0));
            }
            Ok(c_buf.unwrap().to_literal_sync()?.to_vec::<f32>()?)
        })?;

        let mut out = vec![0f32; batch * m * n];
        for (idx, c_blk) in blocks.iter().enumerate() {
            let bi = idx / (gm * gn);
            let (mi, ni) = ((idx / gn) % gm, idx % gn);
            let (m0, n0) = (mi * bm, ni * bn);
            let mrows = bm.min(m - m0);
            let ncols = bn.min(n - n0);
            for g in 0..bb {
                let group = bi * bb + g;
                if group >= batch {
                    break; // batch-edge padding chunk
                }
                for r in 0..mrows {
                    let dst = group * m * n + (m0 + r) * n + n0;
                    let src = (g * bm + r) * bn;
                    out[dst..dst + ncols].copy_from_slice(&c_blk[src..src + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Literal-based reference path (all dtypes); also the baseline for
    /// the §Perf before/after comparison.
    pub fn gemm_dynamic_literal(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let [bm, bn, bk] = block;
        let name = format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name());
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));
        // Gather every B block once, before the mi loop — each is hit
        // `gm` times, so gathering inside the grid walk re-packed the
        // whole padded B matrix per row of M blocks.
        let b_src = OperandSource::dense(b, k, n);
        let mut b_blks: Vec<Vec<Vec<f32>>> = Vec::with_capacity(gk);
        for ki in 0..gk {
            let mut row = Vec::with_capacity(gn);
            for ni in 0..gn {
                let mut b_blk = vec![0f32; bk * bn];
                b_src.gather_block(&mut b_blk, ki * bk, ni * bn, bk, bn);
                row.push(b_blk);
            }
            b_blks.push(row);
        }
        let a_src = OperandSource::dense(a, m, k);
        let mut out = vec![0f32; m * n];
        let mut a_blk = vec![0f32; bm * bk];
        let zeros = vec![0f32; bm * bn];
        for mi in 0..gm {
            let m0 = mi * bm;
            let mrows = bm.min(m - m0);
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                let mut c_blk = zeros.clone();
                for ki in 0..gk {
                    a_src.gather_block(&mut a_blk, m0, ki * bk, bm, bk);
                    c_blk = self.run_raw(
                        &name,
                        &[
                            (&a_blk, vec![bm as i64, bk as i64]),
                            (&b_blks[ki][ni], vec![bk as i64, bn as i64]),
                            (&c_blk, vec![bm as i64, bn as i64]),
                        ],
                    )?;
                }
                // Scatter C block (crop padding).
                for r in 0..mrows {
                    let dst = (m0 + r) * n + n0;
                    out[dst..dst + ncols]
                        .copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Wall-clock one artifact launch (min over `reps`), seconds.
    /// This is the real-testbed empirical L0/L1 profiling primitive.
    ///
    /// Inputs are built, dtype-converted and uploaded to device
    /// buffers ONCE, before timing: each timed rep is a pure
    /// `execute_b` launch, so host→device transfer never inflates the
    /// empirical `base_cost` the selector's cost model is seeded with.
    pub fn time_artifact(&self, name: &str, reps: usize) -> Result<f64> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        let exe = self.executable(name)?;
        let bufs: Vec<xla::PjRtBuffer> = entry
            .inputs
            .iter()
            .map(|spec| {
                let count: usize = spec.shape.iter().product();
                let data = vec![0.1f32; count.max(1)];
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                let lit = self.literal(&data, &dims, Self::spec_dtype(spec))?;
                Ok(self.client.buffer_from_host_literal(None, &lit)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        // Warm-up (compiles on first use).
        exe.execute_b(&refs)?;
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            let res = exe.execute_b(&refs)?;
            best = best.min(t0.elapsed().as_secs_f64());
            drop(res);
        }
        Ok(best)
    }
}

/// Build the real-testbed micro-kernel library: every `gemm_acc` block
/// in the manifest is wall-clock profiled (`reps` launches, min taken)
/// — this is the empirical half of the hybrid analyzer running on real
/// hardware instead of the simulator. The L0 tile is the Pallas inner
/// tile (tm, tn, tk) recorded by aot.py.
pub fn build_real_library(
    engine: &RealEngine,
    hw: &crate::hw::HwSpec,
    dtype: DType,
    reps: usize,
) -> Result<crate::compiler::MicroKernelLibrary> {
    use crate::compiler::{MicroKernel, MicroKernelLibrary};
    use crate::ir::{OpKind, Tile};
    let backend_name = match dtype {
        DType::F32 => "mxu_f32",
        _ => "mxu_bf16",
    };
    let backend = hw
        .backend_idx(backend_name)
        .ok_or_else(|| anyhow!("hw {} lacks backend {}", hw.name, backend_name))?;
    let mut kernels = Vec::new();
    for (block, name) in engine.manifest.gemm_acc_blocks(dtype) {
        let entry = engine.manifest.find(&name).unwrap();
        let l0 = Tile::from3(entry.l0_block()?);
        let base_cost = engine.time_artifact(&name, reps)?;
        kernels.push(MicroKernel { l0, l1: Tile::from3(block), backend, base_cost });
    }
    if kernels.is_empty() {
        bail!("manifest has no gemm_acc blocks for {}", dtype.name());
    }
    kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
    Ok(MicroKernelLibrary {
        hw_name: hw.name.to_string(),
        op: OpKind::Gemm,
        dtype,
        analyzer: crate::cost::hybrid::AnalyzerConfig::empirical(1),
        kernels,
        dispatch: Vec::new(),
    })
}

/// Build every real-testbed library the manifest supports: the
/// `gemm_acc` library plus — when `bgemm_acc` artifacts are present —
/// a native [`crate::ir::OpKind::BatchedGemm`] library whose rank-4
/// blocks are wall-clock profiled the same way. With the batched
/// library loaded, rank-4 selections (grouped conv, attention head
/// groups) serve natively instead of only through the measurement
/// alias, and [`RealEngine::bgemm_dynamic`] finds its artifacts.
pub fn build_real_libraries(
    engine: &RealEngine,
    hw: &crate::hw::HwSpec,
    dtype: DType,
    reps: usize,
) -> Result<Vec<crate::compiler::MicroKernelLibrary>> {
    use crate::compiler::{MicroKernel, MicroKernelLibrary};
    use crate::ir::{OpKind, Tile};
    let mut libs = vec![build_real_library(engine, hw, dtype, reps)?];
    let batched = engine.manifest.bgemm_acc_blocks(dtype);
    if batched.is_empty() {
        return Ok(libs);
    }
    let backend_name = match dtype {
        DType::F32 => "mxu_f32",
        _ => "mxu_bf16",
    };
    let backend = hw
        .backend_idx(backend_name)
        .ok_or_else(|| anyhow!("hw {} lacks backend {}", hw.name, backend_name))?;
    let mut kernels = Vec::new();
    for (block, name) in batched {
        let entry = engine.manifest.find(&name).unwrap();
        // The Pallas grid walks one batch element per step: the inner
        // tile is (1, tm, tn, tk) under the (bb, bm, bn, bk) block.
        let [tm, tn, tk] = entry.l0_block()?;
        let l0 = Tile::new(&[1, tm, tn, tk]);
        let base_cost = engine.time_artifact(&name, reps)?;
        kernels.push(MicroKernel { l0, l1: Tile::new(&block), backend, base_cost });
    }
    kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
    libs.push(MicroKernelLibrary {
        hw_name: hw.name.to_string(),
        op: OpKind::BatchedGemm,
        dtype,
        analyzer: crate::cost::hybrid::AnalyzerConfig::empirical(1),
        kernels,
        dispatch: Vec::new(),
    });
    Ok(libs)
}

/// im2col patch matrix of one channel group (the data-layout half
/// Vortex folds into the rKernel recursion, §4.2), honoring stride and
/// symmetric zero padding.
///
/// `x` is NHWC row-major (n, h, w, cin). Rows are output positions
/// (b, oy, ox); columns are filter taps in (i, j, c) order over the
/// `cg` channels starting at `c0` — matching the group's filter slab
/// reshaped as a (kh·kw·cg, cout/g) row-major matrix. Taps that fall
/// in the zero-padding halo stay zero.
pub fn im2col_patches(
    x: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw): (usize, usize),
    (stride, pad): (usize, usize),
    (c0, cg): (usize, usize),
) -> Vec<f32> {
    let (oh, ow) = crate::ir::conv_out_dims((h, wd), (kh, kw), stride, pad)
        .expect("im2col_patches: invalid conv geometry");
    assert!(c0 + cg <= cin, "channel slice {}+{} exceeds cin {}", c0, cg, cin);
    let kdim = kh * kw * cg;
    let m = n * oh * ow;
    let mut patches = vec![0f32; m * kdim];
    for b in 0..n {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad as isize;
                let row = ((b * oh + oy) * ow + ox) * kdim;
                for i in 0..kh {
                    let iy = iy0 + i as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // padding halo: stays zero
                    }
                    for j in 0..kw {
                        let ix = ix0 + j as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src =
                            ((b * h + iy as usize) * wd + ix as usize) * cin + c0;
                        let dst = row + (i * kw + j) * cg;
                        patches[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
    patches
}

/// Group `g`'s filter slab as a (kh·kw·cg, cout/groups) row-major
/// matrix. `w` is (kh, kw, cin/groups, cout) row-major; output channel
/// `co` belongs to group `co / (cout/groups)`.
pub fn filter_group(
    w: &[f32],
    (kh, kw, cg, cout): (usize, usize, usize, usize),
    (g, groups): (usize, usize),
) -> Vec<f32> {
    let coutg = cout / groups;
    let kdim = kh * kw * cg;
    let mut out = vec![0f32; kdim * coutg];
    for r in 0..kdim {
        let src = r * cout + g * coutg;
        out[r * coutg..(r + 1) * coutg].copy_from_slice(&w[src..src + coutg]);
    }
    out
}

/// Host mirror of the f32 device fast path
/// ([`RealEngine::gemm_dynamic_src`]): identical block gathers
/// (`OperandSource::gather_block`), identical deterministic parallel
/// cell walk (`run_cells` with the given `threads`), identical
/// scatter — only the block multiply runs on host instead of the
/// device. CI property-tests the constructor through this mirror (no
/// PJRT device exists offline); each cell allocates exactly
/// [`tile_scratch_elems`] transient f32s, the bound implicit-GEMM conv
/// is held to.
pub fn gemm_tiled_host(
    a: &OperandSource<'_>,
    b: &OperandSource<'_>,
    block: [usize; 3],
    threads: usize,
) -> Vec<f32> {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "gemm_tiled_host: inner dims {} vs {}", k, b.rows());
    let [bm, bn, bk] = block;
    let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));
    let blocks = run_cells(gm * gn, threads, |idx| {
        let (mi, ni) = (idx / gn, idx % gn);
        // Exactly tile_scratch_elems(block) transient f32s per cell.
        let mut a_blk = vec![0f32; bm * bk];
        let mut b_blk = vec![0f32; bk * bn];
        let mut c_blk = vec![0f32; bm * bn];
        for ki in 0..gk {
            a.gather_block(&mut a_blk, mi * bm, ki * bk, bm, bk);
            b.gather_block(&mut b_blk, ki * bk, ni * bn, bk, bn);
            block_multiply_acc(&a_blk, &b_blk, &mut c_blk, bm, bn, bk);
        }
        Ok(c_blk)
    })
    .expect("host cells are infallible");
    let mut out = vec![0f32; m * n];
    for (idx, c_blk) in blocks.iter().enumerate() {
        let (mi, ni) = (idx / gn, idx % gn);
        let (m0, n0) = (mi * bm, ni * bn);
        let mrows = bm.min(m - m0);
        let ncols = bn.min(n - n0);
        for r in 0..mrows {
            let dst = (m0 + r) * n + n0;
            out[dst..dst + ncols].copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
        }
    }
    out
}

/// Host mirror of [`RealEngine::bgemm_dynamic`]'s native path: the
/// same batch chunking (groups walked in chunks of `bb`, edge chunks
/// zero-padded), the same (chunk, M, N) cell walk and the same scatter
/// index math, with host block multiplies. Returns the concatenated
/// (batch, m, n) result.
pub fn bgemm_tiled_host(
    a_srcs: &[OperandSource<'_>],
    b_srcs: &[OperandSource<'_>],
    block: [usize; 4],
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a_srcs.len(), b_srcs.len(), "bgemm_tiled_host: source count mismatch");
    let batch = a_srcs.len();
    if batch == 0 {
        return Vec::new();
    }
    let (m, k, n) = (a_srcs[0].rows(), a_srcs[0].cols(), b_srcs[0].cols());
    let [bb, bm, bn, bk] = block;
    let gb = ceil_div(batch, bb);
    let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));
    let blocks = run_cells(gb * gm * gn, threads, |idx| {
        let bi = idx / (gm * gn);
        let (mi, ni) = ((idx / gn) % gm, idx % gn);
        let mut a_blk = vec![0f32; bm * bk];
        let mut b_blk = vec![0f32; bk * bn];
        let mut c_chunk = vec![0f32; bb * bm * bn];
        for g in 0..bb {
            let Some(a) = a_srcs.get(bi * bb + g) else { break };
            let b = &b_srcs[bi * bb + g];
            let c_blk = &mut c_chunk[g * bm * bn..(g + 1) * bm * bn];
            for ki in 0..gk {
                a.gather_block(&mut a_blk, mi * bm, ki * bk, bm, bk);
                b.gather_block(&mut b_blk, ki * bk, ni * bn, bk, bn);
                block_multiply_acc(&a_blk, &b_blk, c_blk, bm, bn, bk);
            }
        }
        Ok(c_chunk)
    })
    .expect("host cells are infallible");
    let mut out = vec![0f32; batch * m * n];
    for (idx, c_chunk) in blocks.iter().enumerate() {
        let bi = idx / (gm * gn);
        let (mi, ni) = ((idx / gn) % gm, idx % gn);
        let (m0, n0) = (mi * bm, ni * bn);
        let mrows = bm.min(m - m0);
        let ncols = bn.min(n - n0);
        for g in 0..bb {
            let group = bi * bb + g;
            if group >= batch {
                break;
            }
            for r in 0..mrows {
                let dst = group * m * n + (m0 + r) * n + n0;
                let src = (g * bm + r) * bn;
                out[dst..dst + ncols].copy_from_slice(&c_chunk[src..src + ncols]);
            }
        }
    }
    out
}

/// One padded (bm x bk) · (bk x bn) block multiply, accumulated into
/// `c` — the host stand-in for one `gemm_acc` launch.
fn block_multiply_acc(a: &[f32], b: &[f32], c: &mut [f32], bm: usize, bn: usize, bk: usize) {
    for r in 0..bm {
        for l in 0..bk {
            let av = a[r * bk + l];
            let brow = l * bn;
            let crow = r * bn;
            for j in 0..bn {
                c[crow + j] += av * b[brow + j];
            }
        }
    }
}

/// Dynamic-shape convolution on the real engine via zero-
/// materialization implicit GEMM: the input is viewed through
/// [`OperandSource::Im2col`] (patch blocks packed on demand at the L1
/// tile boundary — no m × kh·kw·cg patch matrix is ever allocated;
/// transient scratch is [`tile_scratch_elems`]) and each group's
/// filter slab through a strided [`OperandSource::Dense`] view.
/// Grouped convs route through [`RealEngine::bgemm_dynamic`], so a
/// rank-4 selection with a native `bgemm_acc` artifact runs the group
/// loop on-device. Supports stride, symmetric zero padding and
/// channel groups (depthwise when `groups == cin`).
///
/// `x` is NHWC row-major (n, h, w, cin); `w` is (kh, kw, cin/groups,
/// cout); `geom` is (stride, pad, groups). Returns NHWC (n, oh, ow,
/// cout) f32 (inputs are converted to `dtype` on device).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
    (stride, pad, groups): (usize, usize, usize),
    dtype: DType,
) -> Result<Vec<f32>> {
    // Geometry is validated where every conv program is: at program
    // construction. The runtime never sees a bogus iteration space.
    let program = crate::ir::TensorProgram::conv2d(
        (n, h, wd, cin),
        (kh, kw, cout),
        (stride, pad, groups),
        dtype,
    )
    .map_err(|e| anyhow!("conv2d_dynamic: {}", e))?;
    let (oh, ow) = program.conv_output().unwrap();
    let (cg, coutg) = (cin / groups, cout / groups);
    let (m, kdim) = (n * oh * ow, kh * kw * cg);
    if x.len() != n * h * wd * cin {
        bail!("conv2d_dynamic: input has {} elems, want {}", x.len(), n * h * wd * cin);
    }
    if w.len() != kh * kw * cg * cout {
        bail!("conv2d_dynamic: filter has {} elems, want {}", w.len(), kh * kw * cg * cout);
    }
    // Select through the SAME op-aware selector as every other op: the
    // conv program's IterSpace goes straight in (rank 3 for ungrouped,
    // rank 4 with the group batch axis otherwise), and the selector
    // resolves it against a native library or the measurement-alias
    // fallback (no conv-specific selection side path here).
    let space = program.space();
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for conv space {:?}", space))?;
    let kern = selector.kernel(&sel);
    // The contraction block of the selected tile: rank-3 tiles are the
    // block; rank-4 (group-batched) tiles carry it after the group
    // axis. A rank-3 selection lifts to batch extent 1, for which
    // bgemm_dynamic degrades to the per-group constructor loop.
    let block4 = match kern.l1.rank() {
        3 => {
            let b = kern.l1.to3();
            [1, b[0], b[1], b[2]]
        }
        4 => kern.l1.to4(),
        r => bail!("unsupported conv kernel rank {}", r),
    };
    if groups == 1 {
        let patches = OperandSource::im2col(x, (n, h, wd, cin), (kh, kw), (stride, pad), (0, cin));
        let filt = OperandSource::dense(w, kdim, cout);
        let block = [block4[1], block4[2], block4[3]];
        return engine.gemm_dynamic_src(&patches, &filt, block, dtype);
    }
    // Per-group patch views + strided filter-slab views feeding the
    // batched constructor; group results interleave along the
    // output-channel axis.
    let a_srcs: Vec<OperandSource> = (0..groups)
        .map(|g| OperandSource::im2col(x, (n, h, wd, cin), (kh, kw), (stride, pad), (g * cg, cg)))
        .collect();
    let b_srcs: Vec<OperandSource> = (0..groups)
        .map(|g| OperandSource::dense_strided(w, kdim, coutg, cout, g * coutg))
        .collect();
    let grouped = engine.bgemm_dynamic(&a_srcs, &b_srcs, (m, coutg, kdim), block4, dtype)?;
    let mut out = vec![0f32; m * cout];
    for g in 0..groups {
        for r in 0..m {
            out[r * cout + g * coutg..r * cout + (g + 1) * coutg]
                .copy_from_slice(&grouped[(g * m + r) * coutg..(g * m + r + 1) * coutg]);
        }
    }
    Ok(out)
}

/// Numerically-stable streaming row-softmax, in place over a row-major
/// (rows x cols) matrix: one online pass per row keeps a running max
/// and a rescaled running sum (the flash-attention recurrence — each
/// new maximum rescales the sum by `exp(old_max - new_max)`), then one
/// normalization pass. This is the epilogue the fused attention chain
/// applies to the resident score tile at the L1 boundary, and the op
/// the softmax micro-measurement prices.
pub fn streaming_softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(
        x.len(),
        rows * cols,
        "streaming_softmax_rows: {} elems for {}x{}",
        x.len(),
        rows,
        cols
    );
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mut max = f32::NEG_INFINITY;
        let mut sum = 0f32;
        for &v in row.iter() {
            if v > max {
                sum *= (max - v).exp(); // exp(-inf) = 0 seeds the first step
                max = v;
            }
            sum += (v - max).exp();
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v = (*v - max).exp() * inv;
        }
    }
}

/// Dynamic-shape fused attention on the real engine: `score = Q·Kᵀ`
/// and `ctx = P·V` run as two [`RealEngine::bgemm_dynamic`] calls over
/// ALL head groups (K served through a transposed view — no transpose
/// copy), with the numerically-stable streaming row-softmax between
/// them — exactly the chain the [`crate::ir::FusedAttention`] strategy
/// space prices. With a native `bgemm_acc` artifact the head-group
/// loop runs on-device; otherwise it degrades to the per-group
/// constructor loop through the same views.
///
/// `q`, `k`, `v` are (batch·heads, seq, d/heads) row-major f32 (each
/// head group contiguous); returns the context in the same layout.
/// Geometry is validated where every attention program is — at program
/// construction via [`crate::ir::TensorProgram::attention`] — and the
/// block comes from the op-aware selector: the attention space goes
/// straight in and resolves against a native attention library or the
/// batched-GEMM measurement-alias fallback (no attention-specific
/// selection side path).
#[allow(clippy::too_many_arguments)]
pub fn attention_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    (batch, seq): (usize, usize),
    (d, heads): (usize, usize),
    dtype: DType,
) -> Result<Vec<f32>> {
    let program = crate::ir::TensorProgram::attention((batch, seq), (d, heads), dtype)
        .map_err(|e| anyhow!("attention_dynamic: {}", e))?;
    let hd = d / heads;
    let groups = batch * heads;
    let want = groups * seq * hd;
    for (name, buf) in [("q", q), ("k", k), ("v", v)] {
        if buf.len() != want {
            bail!("attention_dynamic: {} has {} elems, want {}", name, buf.len(), want);
        }
    }
    let space = program.space();
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for attention space {:?}", space))?;
    let kern = selector.kernel(&sel);
    // Rank-4 tiles carry the contraction block after the head-group
    // batch axis; a rank-3 tile (flat-contraction library) is the
    // block itself, lifted to batch extent 1 for bgemm_dynamic (which
    // then degrades to the per-group constructor loop).
    let block4 = match kern.l1.rank() {
        3 => {
            let b = kern.l1.to3();
            [1, b[0], b[1], b[2]]
        }
        4 => kern.l1.to4(),
        r => bail!("unsupported attention kernel rank {}", r),
    };
    // Stage 1, all head groups batched: score = Q·Kᵀ, with Kᵀ as a
    // transposed view — the per-group transpose copy is gone.
    let gsz = seq * hd;
    let scores = {
        let q_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense(&q[g * gsz..(g + 1) * gsz], seq, hd))
            .collect();
        let kt_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::transpose(&k[g * gsz..(g + 1) * gsz], hd, seq))
            .collect();
        let mut s = engine.bgemm_dynamic(&q_srcs, &kt_srcs, (seq, seq, hd), block4, dtype)?;
        for g in 0..groups {
            streaming_softmax_rows(&mut s[g * seq * seq..(g + 1) * seq * seq], seq, seq);
        }
        s
    };
    // Stage 2, batched again: ctx = P·V. The (groups, seq, hd) result
    // is already the output layout.
    let p_srcs: Vec<OperandSource> = (0..groups)
        .map(|g| OperandSource::dense(&scores[g * seq * seq..(g + 1) * seq * seq], seq, seq))
        .collect();
    let v_srcs: Vec<OperandSource> =
        (0..groups).map(|g| OperandSource::dense(&v[g * gsz..(g + 1) * gsz], seq, hd)).collect();
    engine.bgemm_dynamic(&p_srcs, &v_srcs, (seq, hd, seq), block4, dtype)
}

/// Append-only KV cache for autoregressive decode: per head group, a
/// preallocated (capacity x head-dim) K slab and a matching V slab.
/// [`KvCache::append`] writes one token's K/V rows into the next
/// prefix slot and NEVER reallocates — the slabs are sized once at
/// construction, so the steady-state decode path stays transient-
/// allocation-free and every step's operands are exact prefix slices
/// of stable storage. This is the KV-append operand source: stage 1
/// of a decode step reads the K prefix through a transposed
/// [`OperandSource`] view and stage 2 reads the V prefix dense — K
/// and V are never re-materialized per step.
pub struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    groups: usize,
    head_dim: usize,
    capacity: usize,
    len: usize,
}

impl KvCache {
    /// Allocate slabs for `groups` head groups of `capacity` tokens
    /// each. This is the ONLY allocation the cache ever performs.
    pub fn new(groups: usize, capacity: usize, head_dim: usize) -> Self {
        assert!(groups > 0 && capacity > 0 && head_dim > 0, "KvCache: empty geometry");
        KvCache {
            k: vec![0f32; groups * capacity * head_dim],
            v: vec![0f32; groups * capacity * head_dim],
            groups,
            head_dim,
            capacity,
            len: 0,
        }
    }

    /// Tokens appended so far (the decode step's `seq_k`).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Append one token: `k_rows` / `v_rows` are (groups x head-dim)
    /// row-major — one new K/V row per head group. Panics past
    /// capacity; never grows the slabs.
    pub fn append(&mut self, k_rows: &[f32], v_rows: &[f32]) {
        assert!(self.len < self.capacity, "KvCache: append past capacity {}", self.capacity);
        let hd = self.head_dim;
        assert_eq!(k_rows.len(), self.groups * hd, "KvCache: k rows");
        assert_eq!(v_rows.len(), self.groups * hd, "KvCache: v rows");
        for g in 0..self.groups {
            let dst = (g * self.capacity + self.len) * hd;
            self.k[dst..dst + hd].copy_from_slice(&k_rows[g * hd..(g + 1) * hd]);
            self.v[dst..dst + hd].copy_from_slice(&v_rows[g * hd..(g + 1) * hd]);
        }
        self.len += 1;
    }

    /// Group `g`'s K prefix: the first `len()` rows, contiguous
    /// (len x head-dim) row-major — an exact slice of stable storage.
    pub fn k_prefix(&self, g: usize) -> &[f32] {
        let base = g * self.capacity * self.head_dim;
        &self.k[base..base + self.len * self.head_dim]
    }

    /// Group `g`'s V prefix, same layout as [`KvCache::k_prefix`].
    pub fn v_prefix(&self, g: usize) -> &[f32] {
        let base = g * self.capacity * self.head_dim;
        &self.v[base..base + self.len * self.head_dim]
    }
}

/// One autoregressive decode step on the real engine: `q` holds one
/// query row per head group and the K/V prefixes live in an
/// append-only [`KvCache`]. The single query sits at the LAST causal
/// position, so it attends every cached key — the causal mask is the
/// prefix itself, and no score is ever computed just to be masked out
/// (the zero-waste formulation the [`crate::ir::OpKind::CausalAttention`]
/// strategy space prices). Runs as two [`RealEngine::bgemm_dynamic`]
/// calls over all head groups: stage 1 serves the K prefix through a
/// transposed view over the cache slab and stage 2 serves the V
/// prefix dense — nothing is copied or re-materialized per step.
///
/// `q` is (batch·heads, head-dim) row-major; returns the context rows
/// in the same layout. The block comes from the op-aware selector:
/// the decode-step space goes straight in and resolves against the
/// batched-GEMM measurement alias (no decode-specific side path).
pub fn causal_decode_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    q: &[f32],
    cache: &KvCache,
    (batch, heads): (usize, usize),
    dtype: DType,
) -> Result<Vec<f32>> {
    let hd = cache.head_dim();
    let seq_k = cache.len();
    let program = crate::ir::TensorProgram::decode_step((batch, seq_k), (hd * heads, heads), dtype)
        .map_err(|e| anyhow!("causal_decode_dynamic: {}", e))?;
    let groups = batch * heads;
    if cache.groups() != groups {
        bail!("causal_decode_dynamic: cache has {} groups, want {}", cache.groups(), groups);
    }
    if q.len() != groups * hd {
        bail!("causal_decode_dynamic: q has {} elems, want {}", q.len(), groups * hd);
    }
    let space = program.space();
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for decode space {:?}", space))?;
    let kern = selector.kernel(&sel);
    let block4 = match kern.l1.rank() {
        3 => {
            let b = kern.l1.to3();
            [1, b[0], b[1], b[2]]
        }
        4 => kern.l1.to4(),
        r => bail!("unsupported decode kernel rank {}", r),
    };
    // Stage 1: score row = q · K_prefixᵀ, the prefix served through a
    // transposed view over the cache slab — no transpose copy, no
    // masked-out work.
    let q_srcs: Vec<OperandSource> =
        (0..groups).map(|g| OperandSource::dense(&q[g * hd..(g + 1) * hd], 1, hd)).collect();
    let kt_srcs: Vec<OperandSource> =
        (0..groups).map(|g| OperandSource::transpose(cache.k_prefix(g), hd, seq_k)).collect();
    let mut scores = engine.bgemm_dynamic(&q_srcs, &kt_srcs, (1, seq_k, hd), block4, dtype)?;
    for g in 0..groups {
        streaming_softmax_rows(&mut scores[g * seq_k..(g + 1) * seq_k], 1, seq_k);
    }
    // Stage 2: ctx = p · V_prefix over the dense prefix slice.
    let p_srcs: Vec<OperandSource> = (0..groups)
        .map(|g| OperandSource::dense(&scores[g * seq_k..(g + 1) * seq_k], 1, seq_k))
        .collect();
    let v_srcs: Vec<OperandSource> =
        (0..groups).map(|g| OperandSource::dense(cache.v_prefix(g), seq_k, hd)).collect();
    engine.bgemm_dynamic(&p_srcs, &v_srcs, (1, hd, seq_k), block4, dtype)
}

/// Direct reference causal attention for verification: per head
/// group, query row `i` sits at absolute position `seq_k - seq_q + i`
/// and attends keys `0..=seq_k - seq_q + i` — naive two-pass-stable
/// softmax over the visible prefix only, then the context
/// accumulation. With `seq_q == seq_k` this is full causal prefill;
/// with `seq_q == 1` it is the decode step a
/// [`causal_decode_dynamic`] call performs against the KV cache.
///
/// `q` is (batch·heads, seq_q, d/heads) row-major, `k` / `v` are
/// (batch·heads, seq_k, d/heads); returns (batch·heads, seq_q,
/// d/heads). Panics on invalid causal geometry (validated where every
/// causal program is — at program construction).
pub fn causal_host_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    (batch, seq_q, seq_k): (usize, usize, usize),
    (d, heads): (usize, usize),
) -> Vec<f32> {
    crate::ir::TensorProgram::causal_attention((batch, seq_q, seq_k), (d, heads), DType::F32)
        .expect("causal_host_ref: invalid causal attention geometry");
    let hd = d / heads;
    let groups = batch * heads;
    let off = seq_k - seq_q;
    let mut out = vec![0f32; groups * seq_q * hd];
    let mut scores = vec![0f32; seq_k];
    for g in 0..groups {
        let qb = g * seq_q * hd;
        let kb = g * seq_k * hd;
        for i in 0..seq_q {
            let lim = off + i + 1; // keys 0..lim-1 are causally visible
            let mut max = f32::NEG_INFINITY;
            for (j, s) in scores[..lim].iter_mut().enumerate() {
                let mut acc = 0f32;
                for c in 0..hd {
                    acc += q[qb + i * hd + c] * k[kb + j * hd + c];
                }
                *s = acc;
                max = max.max(acc);
            }
            let mut sum = 0f32;
            for s in scores[..lim].iter_mut() {
                *s = (*s - max).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            for c in 0..hd {
                let mut acc = 0f32;
                for (j, &p) in scores[..lim].iter().enumerate() {
                    acc += p * v[kb + j * hd + c];
                }
                out[qb + i * hd + c] = acc * inv;
            }
        }
    }
    out
}

/// Direct reference attention for verification: per head group, naive
/// two-pass-stable softmax over explicitly accumulated score rows,
/// then the context accumulation — no GEMM helper involved, so it
/// cross-checks the `gemm_dynamic` → softmax → `gemm_dynamic` chain
/// (and its host composition) independently.
///
/// Layouts match [`attention_dynamic`]. Panics on invalid attention
/// geometry (mirrors `im2col_patches`).
pub fn attention_host_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    (batch, seq): (usize, usize),
    (d, heads): (usize, usize),
) -> Vec<f32> {
    crate::ir::TensorProgram::attention((batch, seq), (d, heads), DType::F32)
        .expect("attention_host_ref: invalid attention geometry");
    let hd = d / heads;
    let groups = batch * heads;
    let mut out = vec![0f32; groups * seq * hd];
    let mut scores = vec![0f32; seq];
    for g in 0..groups {
        let base = g * seq * hd;
        for i in 0..seq {
            let mut max = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for c in 0..hd {
                    acc += q[base + i * hd + c] * k[base + j * hd + c];
                }
                *s = acc;
                max = max.max(acc);
            }
            let mut sum = 0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            for c in 0..hd {
                let mut acc = 0f32;
                for (j, &p) in scores.iter().enumerate() {
                    acc += p * v[base + j * hd + c];
                }
                out[base + i * hd + c] = acc * inv;
            }
        }
    }
    out
}

/// Reference row-major triple-loop GEMM for verification in tests.
pub fn gemm_host_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            let row = l * n;
            let out = i * n;
            for j in 0..n {
                c[out + j] += av * b[row + j];
            }
        }
    }
    c
}

/// Reference direct NHWC convolution (for verification): stride,
/// symmetric zero padding and channel groups. `w` is (kh, kw,
/// cin/groups, cout) row-major.
pub fn conv2d_host_ref(
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
    (stride, pad, groups): (usize, usize, usize),
) -> Vec<f32> {
    let (oh, ow) = crate::ir::conv_out_dims((h, wd), (kh, kw), stride, pad)
        .expect("conv2d_host_ref: invalid conv geometry");
    let (cg, coutg) = (cin / groups, cout / groups);
    let mut out = vec![0f32; n * oh * ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad as isize;
                let dst = ((b * oh + oy) * ow + ox) * cout;
                for i in 0..kh {
                    let iy = iy0 + i as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for j in 0..kw {
                        let ix = ix0 + j as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * wd + ix as usize) * cin;
                        for co in 0..cout {
                            let g = co / coutg;
                            let mut acc = out[dst + co];
                            for c in 0..cg {
                                let xv = x[src + g * cg + c];
                                let wv = w[((i * kw + j) * cg + c) * cout + co];
                                acc += xv * wv;
                            }
                            out[dst + co] = acc;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::conv_out_dims;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn host_ref_gemm_known_values() {
        // [[1,2],[3,4]] @ I = same matrix
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(gemm_host_ref(&a, &b, 2, 2, 2), a);
    }

    #[test]
    fn manifest_parse_rejects_garbage() {
        let dir = std::env::temp_dir().join("vortex_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"entries\": [{}]}").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    fn entry_json(name: &str) -> String {
        format!(
            r#"{{"name": "{name}", "kind": "gemm_acc", "file": "{name}.hlo.txt",
                 "params": {{"bm": 8, "bn": 128, "bk": 128,
                             "tm": 8, "tn": 128, "tk": 128, "in_dtype": "f32"}},
                 "inputs": [], "outputs": []}}"#
        )
    }

    #[test]
    fn manifest_rejects_duplicate_artifact_names() {
        let dir = std::env::temp_dir().join("vortex_manifest_dup_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dup = format!(
            "{{\"entries\": [{}, {}]}}",
            entry_json("gemm_acc_8x128x128_f32"),
            entry_json("gemm_acc_8x128x128_f32")
        );
        std::fs::write(dir.join("manifest.json"), dup).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("duplicate artifact name"), "{}", err);
        // Distinct names load fine.
        let ok = format!(
            "{{\"entries\": [{}, {}]}}",
            entry_json("gemm_acc_8x128x128_f32"),
            entry_json("gemm_acc_16x128x128_f32")
        );
        std::fs::write(dir.join("manifest.json"), ok).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap().entries.len(), 2);
    }

    #[test]
    fn manifest_fingerprint_tracks_blocks_and_artifact_bytes() {
        let dir = std::env::temp_dir().join("vortex_manifest_fp_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let one = format!("{{\"entries\": [{}]}}", entry_json("gemm_acc_8x128x128_f32"));
        std::fs::write(dir.join("manifest.json"), &one).unwrap();
        let f1 = Manifest::load(&dir).unwrap().fingerprint();
        // Stable across reloads.
        assert_eq!(f1, Manifest::load(&dir).unwrap().fingerprint());
        // A (new) artifact binary enters the fingerprint...
        std::fs::write(dir.join("gemm_acc_8x128x128_f32.hlo.txt"), "HLO v1").unwrap();
        let f2 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f1, f2, "artifact bytes not fingerprinted");
        // ...and changed bytes change it (a regenerated Pallas block).
        std::fs::write(dir.join("gemm_acc_8x128x128_f32.hlo.txt"), "HLO v2").unwrap();
        let f3 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f2, f3, "changed artifact bytes aliased");
        // Changed block parameters change it even with the same file.
        let changed = one.replace("\"bn\": 128", "\"bn\": 256");
        assert_ne!(one, changed);
        std::fs::write(dir.join("manifest.json"), &changed).unwrap();
        let f4 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f3, f4, "changed params aliased");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn l0_block_requires_inner_tile_params() {
        let dir = std::env::temp_dir().join("vortex_manifest_l0_test");
        std::fs::create_dir_all(&dir).unwrap();
        let no_tile = r#"{"entries": [{"name": "gemm_acc_8x128x128_f32",
            "kind": "gemm_acc", "file": "x.hlo.txt",
            "params": {"bm": 8, "bn": 128, "bk": 128, "in_dtype": "f32"},
            "inputs": [], "outputs": []}]}"#;
        std::fs::write(dir.join("manifest.json"), no_tile).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let err = m.entries[0].l0_block().unwrap_err().to_string();
        assert!(err.contains("missing/invalid param \"tm\""), "{}", err);
        // A well-formed entry yields the recorded tile, not a default.
        let ok = format!("{{\"entries\": [{}]}}", entry_json("gemm_acc_8x128x128_f32"));
        std::fs::write(dir.join("manifest.json"), ok).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries[0].l0_block().unwrap(), [8, 128, 128]);
    }

    // -- generalized conv geometry -----------------------------------------

    /// im2col + per-group host GEMM: the exact compute conv2d_dynamic
    /// performs, minus the device.
    fn conv_via_im2col(
        x: &[f32],
        w: &[f32],
        io: (usize, usize, usize, usize),
        filt: (usize, usize, usize),
        geom: (usize, usize, usize),
    ) -> Vec<f32> {
        let (n, h, wd, cin) = io;
        let (kh, kw, cout) = filt;
        let (stride, pad, groups) = geom;
        let (oh, ow) = conv_out_dims((h, wd), (kh, kw), stride, pad).unwrap();
        let (cg, coutg) = (cin / groups, cout / groups);
        let (m, kdim) = (n * oh * ow, kh * kw * cg);
        let mut out = vec![0f32; m * cout];
        for g in 0..groups {
            let patches =
                im2col_patches(x, io, (kh, kw), (stride, pad), (g * cg, cg));
            let wg = filter_group(w, (kh, kw, cg, cout), (g, groups));
            let c = gemm_host_ref(&patches, &wg, m, coutg, kdim);
            for r in 0..m {
                out[r * cout + g * coutg..r * cout + (g + 1) * coutg]
                    .copy_from_slice(&c[r * coutg..(r + 1) * coutg]);
            }
        }
        out
    }

    fn assert_same(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
        if got.len() != want.len() {
            return Err(format!("{}: length {} vs {}", what, got.len(), want.len()));
        }
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            if (g - w).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("{}: elem {} differs: {} vs {}", what, i, g, w));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_im2col_gemm_matches_direct_conv_reference() {
        // Satellite: across random (stride, padding, groups, shape)
        // tuples — including partial tiles and depthwise groups == cin —
        // the generalized im2col + gemm_host_ref path computes exactly
        // what the direct conv2d_host_ref computes.
        forall(
            "im2col-gemm-equals-direct-conv",
            60,
            0xC0DE,
            |r: &mut Rng, size| {
                let kh = r.usize(1, 3);
                let kw = r.usize(1, 3);
                let stride = r.usize(1, 3);
                let pad = r.usize(0, 2);
                // Depthwise (cg = 1) in a third of the cases.
                let cg = if r.usize(0, 2) == 0 { 1 } else { r.usize(1, 3) };
                let groups = r.usize(1, 4);
                let coutg = r.usize(1, 3);
                let grow = 1 + size / 25;
                let h = (kh.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let w = (kw.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let n = r.usize(1, 2);
                ((n, h, w, cg * groups), (kh, kw, coutg * groups), (stride, pad, groups))
            },
            |&(io, filt, geom)| {
                let (n, h, w, cin) = io;
                let (kh, kw, cout) = filt;
                let cg = cin / geom.2;
                let mut rng = Rng::new(n as u64 + h as u64 * 31 + w as u64 * 7);
                let x = rng.normal_f32_vec(n * h * w * cin);
                let wgt = rng.normal_f32_vec(kh * kw * cg * cout);
                let got = conv_via_im2col(&x, &wgt, io, filt, geom);
                let want = conv2d_host_ref(&x, &wgt, io, filt, geom);
                assert_same(&got, &want, "im2col-vs-direct")
            },
        );
    }

    #[test]
    fn host_ref_conv_known_values() {
        // 1x1 conv with identity channel mix copies the input.
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|v| v as f32).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (1,1,2,2) identity
        let y = conv2d_host_ref(&x, &w, (2, 3, 3, 2), (1, 1, 2), (1, 0, 1));
        assert_eq!(y, x);
        // Stride 2 keeps every other position.
        let y2 = conv2d_host_ref(&x, &w, (2, 3, 3, 2), (1, 1, 2), (2, 0, 1));
        assert_eq!(y2.len(), 2 * 2 * 2 * 2);
        assert_eq!(&y2[..2], &x[..2]); // (0,0)
        assert_eq!(&y2[2..4], &x[4..6]); // (0,2)
        // Depthwise 1x1 with weights [2, 3]: channel c scales by w[c].
        let wdw = vec![2.0, 3.0]; // (1,1,1,2), groups = 2
        let ydw = conv2d_host_ref(&x, &wdw, (1, 2, 2, 2), (1, 1, 2), (1, 0, 2));
        for (i, v) in ydw.iter().enumerate() {
            let scale = if i % 2 == 0 { 2.0 } else { 3.0 };
            assert_eq!(*v, x[i] * scale);
        }
    }

    #[test]
    fn padded_conv_matches_manual_halo() {
        // 1x1x1 input, 3x3 sum filter, pad 1: output = input everywhere
        // the filter tap hits the single pixel.
        let x = vec![5.0f32];
        let w = vec![1.0f32; 9]; // (3,3,1,1) all-ones
        let y = conv2d_host_ref(&x, &w, (1, 1, 1, 1), (3, 3, 1), (1, 1, 1));
        assert_eq!(y, vec![5.0]); // only the center tap lands in-bounds
        // pad 2: 3x3 output, each position sees the pixel once.
        let y2 = conv2d_host_ref(&x, &w, (1, 1, 1, 1), (3, 3, 1), (1, 2, 1));
        assert_eq!(y2, vec![5.0; 9]);
    }

    #[test]
    fn im2col_rejects_invalid_geometry() {
        let x = vec![0f32; 4 * 4];
        let r = std::panic::catch_unwind(|| {
            im2col_patches(&x, (1, 2, 2, 4), (5, 5), (1, 0), (0, 4))
        });
        assert!(r.is_err(), "undersized feature map must not im2col");
    }

    // -- attention-fused chain ----------------------------------------------

    /// gemm -> streaming softmax -> gemm: the exact compute
    /// attention_dynamic performs, minus the device.
    fn attention_via_gemms(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        (batch, seq): (usize, usize),
        (d, heads): (usize, usize),
    ) -> Vec<f32> {
        let hd = d / heads;
        let groups = batch * heads;
        let mut out = vec![0f32; groups * seq * hd];
        let mut kt = vec![0f32; hd * seq];
        for g in 0..groups {
            let base = g * seq * hd;
            let kg = &k[base..base + seq * hd];
            for r in 0..seq {
                for c in 0..hd {
                    kt[c * seq + r] = kg[r * hd + c];
                }
            }
            let mut scores = gemm_host_ref(&q[base..base + seq * hd], &kt, seq, seq, hd);
            streaming_softmax_rows(&mut scores, seq, seq);
            let ctx = gemm_host_ref(&scores, &v[base..base + seq * hd], seq, hd, seq);
            out[base..base + seq * hd].copy_from_slice(&ctx);
        }
        out
    }

    #[test]
    fn prop_attention_ref_matches_softmax_of_gemms_composition() {
        // Satellite: attention_host_ref == softmax(gemm_host_ref) ·
        // gemm_host_ref across random (batch, heads, seq, head-dim)
        // tuples — the direct reference and the two-GEMM-plus-
        // streaming-softmax chain (what attention_dynamic runs on
        // device) compute the same thing.
        forall(
            "attention-ref-equals-gemm-softmax-chain",
            50,
            0xA77E,
            |r: &mut Rng, size| {
                let batch = r.usize(1, 2);
                let heads = r.usize(1, 3);
                let seq = r.usize(1, 3 + 20 * (1 + size / 30));
                let hd = r.usize(1, 8);
                (batch, heads, seq, hd)
            },
            |&(batch, heads, seq, hd)| {
                let groups = batch * heads;
                let mut rng = Rng::new(seq as u64 * 131 + hd as u64 * 7 + groups as u64);
                let q = rng.normal_f32_vec(groups * seq * hd);
                let k = rng.normal_f32_vec(groups * seq * hd);
                let v = rng.normal_f32_vec(groups * seq * hd);
                let io = (batch, seq);
                let proj = (heads * hd, heads);
                let want = attention_host_ref(&q, &k, &v, io, proj);
                let got = attention_via_gemms(&q, &k, &v, io, proj);
                assert_same(&got, &want, "attention-chain-vs-direct")
            },
        );
    }

    #[test]
    fn attention_ref_edge_sequences() {
        // seq = 1 (decode step): softmax over one logit is identity, so
        // the context is exactly V's single row.
        let q = vec![0.3f32, -1.2];
        let k = vec![0.7f32, 0.1];
        let v = vec![5.0f32, -3.0];
        let out = attention_host_ref(&q, &k, &v, (1, 1), (2, 1));
        assert_eq!(out, v);
        // Non-power-of-two seq with uniform scores: softmax is uniform,
        // context is the column mean of V.
        let (seq, hd) = (7usize, 3usize);
        let q0 = vec![0f32; seq * hd];
        let k0 = vec![0f32; seq * hd];
        let mut vv = vec![0f32; seq * hd];
        for (i, x) in vv.iter_mut().enumerate() {
            *x = i as f32;
        }
        let out = attention_host_ref(&q0, &k0, &vv, (1, seq), (hd, 1));
        for i in 0..seq {
            for c in 0..hd {
                let mean: f32 = (0..seq).map(|j| vv[j * hd + c]).sum::<f32>() / seq as f32;
                assert!((out[i * hd + c] - mean).abs() < 1e-4, "({}, {})", i, c);
            }
        }
    }

    #[test]
    fn streaming_softmax_matches_two_pass_and_is_stable() {
        // Rows sum to 1 and match the naive two-pass computation, even
        // with large magnitudes that overflow a non-stabilized exp.
        let mut x = vec![1000.0f32, 1001.0, 999.0, -2000.0, 3.5, 0.0];
        let y = x.clone();
        streaming_softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let row = &y[r * 3..(r + 1) * 3];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
            let s: f32 = exps.iter().sum();
            for c in 0..3 {
                assert!((x[r * 3 + c] - exps[c] / s).abs() < 1e-6);
                assert!(x[r * 3 + c].is_finite());
            }
            let rowsum: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((rowsum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_ref_rejects_invalid_geometry() {
        // Runtime layer: the reference (and attention_dynamic, which
        // validates through the same TensorProgram::attention door)
        // refuses geometry the program layer rejects.
        let buf = vec![0f32; 64];
        let r = std::panic::catch_unwind(|| {
            attention_host_ref(&buf, &buf, &buf, (1, 4), (7, 2))
        });
        assert!(r.is_err(), "heads not dividing d must not run");
        let r = std::panic::catch_unwind(|| {
            attention_host_ref(&buf, &buf, &buf, (1, 0), (8, 2))
        });
        assert!(r.is_err(), "zero seq must not run");
    }

    // -- block providers & the tiled constructor ----------------------------

    #[test]
    fn dense_strided_source_matches_filter_group() {
        let (kh, kw, cg, cout, groups) = (3, 2, 2, 6, 3);
        let kdim = kh * kw * cg;
        let mut rng = Rng::new(42);
        let w = rng.normal_f32_vec(kdim * cout);
        let coutg = cout / groups;
        for g in 0..groups {
            let src = OperandSource::dense_strided(&w, kdim, coutg, cout, g * coutg);
            let want = filter_group(&w, (kh, kw, cg, cout), (g, groups));
            assert_eq!(src.materialize(), want, "group {}", g);
        }
    }

    #[test]
    fn transpose_source_matches_explicit_transpose() {
        let (rows, cols) = (5, 7); // view is rows x cols over (cols x rows) data
        let mut rng = Rng::new(7);
        let d = rng.normal_f32_vec(rows * cols);
        let src = OperandSource::transpose(&d, rows, cols);
        let mat = src.materialize();
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(mat[r * cols + c], d[c * rows + r], "({}, {})", r, c);
            }
        }
        // A block hanging off both edges zero-pads (scratch reuse: dst
        // starts dirty).
        let mut blk = vec![1f32; 4 * 4];
        src.gather_block(&mut blk, 3, 5, 4, 4);
        for r in 0..4 {
            for c in 0..4 {
                let want = if 3 + r < rows && 5 + c < cols {
                    d[(5 + c) * rows + (3 + r)]
                } else {
                    0.0
                };
                assert_eq!(blk[r * 4 + c], want, "edge ({}, {})", r, c);
            }
        }
    }

    #[test]
    fn prop_im2col_source_blocks_match_materialized_patches() {
        // The virtual patch view gathers exactly the blocks of the
        // materialized patch matrix — including partial edge blocks
        // and padding-halo taps — across random conv geometry.
        forall(
            "im2col-source-equals-patch-matrix-blocks",
            60,
            0x51DE,
            |r: &mut Rng, size| {
                let kh = r.usize(1, 3);
                let kw = r.usize(1, 3);
                let stride = r.usize(1, 2);
                let pad = r.usize(0, 2);
                let cg = r.usize(1, 3);
                let groups = r.usize(1, 3);
                let grow = 1 + size / 30;
                let h = (kh.saturating_sub(2 * pad)).max(1) + r.usize(0, 3 * grow);
                let w = (kw.saturating_sub(2 * pad)).max(1) + r.usize(0, 3 * grow);
                let g = r.usize(0, groups - 1);
                let (br, bc) = (r.usize(1, 6), r.usize(1, 6));
                ((1usize, h, w, cg * groups), (kh, kw), (stride, pad), (g, cg), (br, bc))
            },
            |&(io, filt, geom, (g, cg), (br, bc))| {
                let (n, h, w, cin) = io;
                let mut rng = Rng::new(h as u64 * 17 + w as u64 + cg as u64);
                let x = rng.normal_f32_vec(n * h * w * cin);
                let src = OperandSource::im2col(&x, io, filt, geom, (g * cg, cg));
                let want = im2col_patches(&x, io, filt, geom, (g * cg, cg));
                let (rows, cols) = (src.rows(), src.cols());
                let mut blk = vec![0f32; br * bc];
                for r0 in (0..rows).step_by(br) {
                    for c0 in (0..cols).step_by(bc) {
                        src.gather_block(&mut blk, r0, c0, br, bc);
                        for r in 0..br {
                            for c in 0..bc {
                                let want_v = if r0 + r < rows && c0 + c < cols {
                                    want[(r0 + r) * cols + (c0 + c)]
                                } else {
                                    0.0
                                };
                                if blk[r * bc + c] != want_v {
                                    return Err(format!(
                                        "block ({}, {}) elem ({}, {}): {} vs {}",
                                        r0,
                                        c0,
                                        r,
                                        c,
                                        blk[r * bc + c],
                                        want_v
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_tiled_host_gemm_matches_reference() {
        // The tiled constructor mirror (same gathers / cell walk /
        // scatter as the device fast path) equals the triple-loop
        // reference, including blocks that do not divide the problem.
        forall(
            "tiled-host-gemm-equals-reference",
            40,
            0x7E57,
            |r: &mut Rng, size| {
                let m = r.usize(1, 3 + size / 4);
                let n = r.usize(1, 3 + size / 4);
                let k = r.usize(1, 3 + size / 4);
                let block = [r.usize(1, 5), r.usize(1, 5), r.usize(1, 5)];
                (m, n, k, block)
            },
            |&(m, n, k, block)| {
                let mut rng = Rng::new((m * 31 + n * 7 + k) as u64);
                let a = rng.normal_f32_vec(m * k);
                let b = rng.normal_f32_vec(k * n);
                let got = gemm_tiled_host(
                    &OperandSource::dense(&a, m, k),
                    &OperandSource::dense(&b, k, n),
                    block,
                    1,
                );
                assert_same(&got, &gemm_host_ref(&a, &b, m, n, k), "tiled-host-vs-ref")
            },
        );
    }

    /// Block-provider conv: per-group implicit GEMM over virtual
    /// im2col + strided filter views through the batched tiled
    /// constructor, interleaved along output channels — the compute
    /// `conv2d_dynamic` performs, minus the device.
    fn conv_via_sources(
        x: &[f32],
        w: &[f32],
        io: (usize, usize, usize, usize),
        filt: (usize, usize, usize),
        geom: (usize, usize, usize),
        block: [usize; 4],
        threads: usize,
    ) -> Vec<f32> {
        let (n, h, wd, cin) = io;
        let (kh, kw, cout) = filt;
        let (stride, pad, groups) = geom;
        let (cg, coutg) = (cin / groups, cout / groups);
        let (oh, ow) = conv_out_dims((h, wd), (kh, kw), stride, pad).unwrap();
        let m = n * oh * ow;
        let kdim = kh * kw * cg;
        let a_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::im2col(x, io, (kh, kw), (stride, pad), (g * cg, cg)))
            .collect();
        let b_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense_strided(w, kdim, coutg, cout, g * coutg))
            .collect();
        let grouped = bgemm_tiled_host(&a_srcs, &b_srcs, block, threads);
        let mut out = vec![0f32; m * cout];
        for g in 0..groups {
            for r in 0..m {
                out[r * cout + g * coutg..r * cout + (g + 1) * coutg]
                    .copy_from_slice(&grouped[(g * m + r) * coutg..(g * m + r + 1) * coutg]);
            }
        }
        out
    }

    #[test]
    fn prop_block_provider_conv_matches_direct_reference() {
        // Satellite: the zero-materialization provider path equals
        // conv2d_host_ref across random (stride, pad, groups, shape) —
        // including depthwise (cg = 1) and blocks that leave partial
        // edge tiles on every axis.
        forall(
            "block-provider-conv-equals-direct-conv",
            60,
            0xB10C,
            |r: &mut Rng, size| {
                let kh = r.usize(1, 3);
                let kw = r.usize(1, 3);
                let stride = r.usize(1, 3);
                let pad = r.usize(0, 2);
                let cg = if r.usize(0, 2) == 0 { 1 } else { r.usize(1, 3) };
                let groups = r.usize(1, 4);
                let coutg = r.usize(1, 3);
                let grow = 1 + size / 25;
                let h = (kh.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let w = (kw.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let n = r.usize(1, 2);
                let block = [r.usize(1, 3), r.usize(1, 5), r.usize(1, 4), r.usize(1, 6)];
                ((n, h, w, cg * groups), (kh, kw, coutg * groups), (stride, pad, groups), block)
            },
            |&(io, filt, geom, block)| {
                let (n, h, w, cin) = io;
                let (kh, kw, cout) = filt;
                let cg = cin / geom.2;
                let mut rng = Rng::new(n as u64 + h as u64 * 31 + w as u64 * 7 + cout as u64);
                let x = rng.normal_f32_vec(n * h * w * cin);
                let wgt = rng.normal_f32_vec(kh * kw * cg * cout);
                let got = conv_via_sources(&x, &wgt, io, filt, geom, block, 1);
                let want = conv2d_host_ref(&x, &wgt, io, filt, geom);
                assert_same(&got, &want, "provider-conv-vs-direct")
            },
        );
    }

    #[test]
    fn prop_bgemm_host_matches_per_group_loop() {
        // Satellite: the batched chunked walk (native bgemm layout:
        // batch chunks of bb, zero-padded edge chunks, chunk-local
        // scatter) equals the concatenated per-group constructor loop.
        forall(
            "bgemm-equals-per-group-gemm",
            40,
            0xBA7C,
            |r: &mut Rng, size| {
                let batch = r.usize(1, 5);
                let m = r.usize(1, 3 + size / 5);
                let n = r.usize(1, 3 + size / 5);
                let k = r.usize(1, 3 + size / 5);
                let block = [r.usize(1, 3), r.usize(1, 4), r.usize(1, 4), r.usize(1, 4)];
                (batch, m, n, k, block)
            },
            |&(batch, m, n, k, block)| {
                let mut rng = Rng::new((batch * 131 + m * 31 + n * 7 + k) as u64);
                let a: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_f32_vec(m * k)).collect();
                let b: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_f32_vec(k * n)).collect();
                let a_srcs: Vec<OperandSource> =
                    a.iter().map(|v| OperandSource::dense(v, m, k)).collect();
                let b_srcs: Vec<OperandSource> =
                    b.iter().map(|v| OperandSource::dense(v, k, n)).collect();
                let got = bgemm_tiled_host(&a_srcs, &b_srcs, block, 1);
                let [_, bm, bn, bk] = block;
                let mut want = Vec::new();
                for g in 0..batch {
                    want.extend(gemm_tiled_host(&a_srcs[g], &b_srcs[g], [bm, bn, bk], 1));
                }
                assert_same(&got, &want, "bgemm-vs-group-loop")
            },
        );
    }

    #[test]
    fn parallel_grid_is_bit_identical_to_sequential() {
        // Determinism satellite: the scoped-thread grid walk and the
        // sequential walk produce the same bits — exercised through a
        // ragged im2col provider so partial tiles are in play.
        let io = (2, 9, 7, 6);
        let (kh, kw) = (3, 2);
        let geom = (2, 1);
        let mut rng = Rng::new(0xD17);
        let x = rng.normal_f32_vec(2 * 9 * 7 * 6);
        let a = OperandSource::im2col(&x, io, (kh, kw), geom, (2, 4));
        let wv = rng.normal_f32_vec(kh * kw * 4 * 10);
        let b = OperandSource::dense(&wv, kh * kw * 4, 10);
        let block = [5, 3, 4];
        let seq = gemm_tiled_host(&a, &b, block, 1);
        for threads in [2, 3, 8] {
            assert_eq!(seq, gemm_tiled_host(&a, &b, block, threads), "threads={}", threads);
        }
        let a_srcs = vec![a; 3];
        let b_srcs = vec![b; 3];
        let seq_b = bgemm_tiled_host(&a_srcs, &b_srcs, [2, 5, 3, 4], 1);
        for threads in [2, 5] {
            assert_eq!(
                seq_b,
                bgemm_tiled_host(&a_srcs, &b_srcs, [2, 5, 3, 4], threads),
                "batched threads={}",
                threads
            );
        }
    }

    #[test]
    fn conv_transient_scratch_is_tile_bounded() {
        // Acceptance: implicit-GEMM conv's transient allocation is
        // O(tile), not O(m · kh·kw·cg). The per-cell scratch is exactly
        // the three blocks the constructor stages; for a ResNet-ish
        // layer the materialized patch matrix is orders of magnitude
        // larger.
        let (kh, kw, cin) = (3, 3, 64);
        let (oh, ow) = conv_out_dims((56, 56), (kh, kw), 1, 1).unwrap();
        let m = 2 * oh * ow;
        let kdim = kh * kw * cin;
        let block = [8, 128, 128];
        assert_eq!(tile_scratch_elems(block), 8 * 128 + 128 * 128 + 8 * 128);
        assert!(
            tile_scratch_elems(block) * 16 < m * kdim,
            "scratch {} not O(tile) vs patch matrix {}",
            tile_scratch_elems(block),
            m * kdim
        );
    }

    #[test]
    fn transpose_provider_attention_matches_reference() {
        // Attention through providers: dense Q, transposed K view (no
        // kt copy), streaming softmax, dense P·V — equals the direct
        // reference.
        let (batch, heads, seq, hd) = (2, 3, 9, 5);
        let groups = batch * heads;
        let mut rng = Rng::new(0xA77);
        let q = rng.normal_f32_vec(groups * seq * hd);
        let k = rng.normal_f32_vec(groups * seq * hd);
        let v = rng.normal_f32_vec(groups * seq * hd);
        let gsz = seq * hd;
        let block = [2, 4, 3, 4];
        let q_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense(&q[g * gsz..(g + 1) * gsz], seq, hd))
            .collect();
        let kt_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::transpose(&k[g * gsz..(g + 1) * gsz], hd, seq))
            .collect();
        let mut scores = bgemm_tiled_host(&q_srcs, &kt_srcs, block, 2);
        for g in 0..groups {
            streaming_softmax_rows(&mut scores[g * seq * seq..(g + 1) * seq * seq], seq, seq);
        }
        let p_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense(&scores[g * seq * seq..(g + 1) * seq * seq], seq, seq))
            .collect();
        let v_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense(&v[g * gsz..(g + 1) * gsz], seq, hd))
            .collect();
        let got = bgemm_tiled_host(&p_srcs, &v_srcs, block, 2);
        let want = attention_host_ref(&q, &k, &v, (batch, seq), (heads * hd, heads));
        assert_same(&got, &want, "provider-attention-vs-ref").unwrap();
    }

    // -- KV-cache decode ----------------------------------------------------

    /// One decode step through providers: dense q rows, transposed
    /// K-prefix views over the cache slabs, streaming softmax, dense
    /// P·V over the V prefixes — the exact compute
    /// `causal_decode_dynamic` performs, minus the device.
    fn decode_via_sources(
        q: &[f32],
        cache: &KvCache,
        block: [usize; 4],
        threads: usize,
    ) -> Vec<f32> {
        let (groups, hd, len) = (cache.groups(), cache.head_dim(), cache.len());
        let q_srcs: Vec<OperandSource> =
            (0..groups).map(|g| OperandSource::dense(&q[g * hd..(g + 1) * hd], 1, hd)).collect();
        let kt_srcs: Vec<OperandSource> =
            (0..groups).map(|g| OperandSource::transpose(cache.k_prefix(g), hd, len)).collect();
        let mut scores = bgemm_tiled_host(&q_srcs, &kt_srcs, block, threads);
        for g in 0..groups {
            streaming_softmax_rows(&mut scores[g * len..(g + 1) * len], 1, len);
        }
        let p_srcs: Vec<OperandSource> = (0..groups)
            .map(|g| OperandSource::dense(&scores[g * len..(g + 1) * len], 1, len))
            .collect();
        let v_srcs: Vec<OperandSource> =
            (0..groups).map(|g| OperandSource::dense(cache.v_prefix(g), len, hd)).collect();
        bgemm_tiled_host(&p_srcs, &v_srcs, block, threads)
    }

    #[test]
    fn prop_kv_cache_decode_matches_causal_reference_tail() {
        // Tentpole: across random (batch, heads, head-dim) and a
        // GROWING seq_k, every decode step through the append-only
        // cache (transposed K-prefix view + dense V prefix) equals the
        // LAST row of the full causal-prefill reference over the
        // entire history — the mask-as-prefix formulation is exact at
        // every cache length, including length 1 and lengths that
        // leave partial tiles on the seq_k axis.
        forall(
            "kv-decode-equals-causal-tail",
            30,
            0xDECD,
            |r: &mut Rng, size| {
                let batch = r.usize(1, 2);
                let heads = r.usize(1, 3);
                let hd = r.usize(1, 6);
                let steps = r.usize(1, 3 + size / 8);
                let block = [r.usize(1, 3), r.usize(1, 3), r.usize(1, 5), r.usize(1, 4)];
                (batch, heads, hd, steps, block)
            },
            |&(batch, heads, hd, steps, block)| {
                let groups = batch * heads;
                let mut rng = Rng::new((groups * 131 + hd * 7 + steps) as u64);
                let mut cache = KvCache::new(groups, steps, hd);
                // Per-group histories in the (groups, t, hd) reference
                // layout.
                let mut qh: Vec<Vec<f32>> = vec![Vec::new(); groups];
                let mut kh: Vec<Vec<f32>> = vec![Vec::new(); groups];
                let mut vh: Vec<Vec<f32>> = vec![Vec::new(); groups];
                for t in 0..steps {
                    let q = rng.normal_f32_vec(groups * hd);
                    let kr = rng.normal_f32_vec(groups * hd);
                    let vr = rng.normal_f32_vec(groups * hd);
                    cache.append(&kr, &vr);
                    for g in 0..groups {
                        qh[g].extend_from_slice(&q[g * hd..(g + 1) * hd]);
                        kh[g].extend_from_slice(&kr[g * hd..(g + 1) * hd]);
                        vh[g].extend_from_slice(&vr[g * hd..(g + 1) * hd]);
                    }
                    let got = decode_via_sources(&q, &cache, block, 1);
                    let (qf, kf, vf) = (qh.concat(), kh.concat(), vh.concat());
                    let full = causal_host_ref(
                        &qf,
                        &kf,
                        &vf,
                        (batch, t + 1, t + 1),
                        (heads * hd, heads),
                    );
                    let mut want = vec![0f32; groups * hd];
                    for g in 0..groups {
                        let tail = (g * (t + 1) + t) * hd;
                        want[g * hd..(g + 1) * hd].copy_from_slice(&full[tail..tail + hd]);
                    }
                    assert_same(&got, &want, &format!("decode-vs-causal-tail step {}", t))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn kv_cache_slabs_are_stable_and_append_only() {
        let (groups, cap, hd) = (3, 5, 4);
        let mut cache = KvCache::new(groups, cap, hd);
        assert!(cache.is_empty());
        assert_eq!(cache.capacity(), cap);
        let slab = cache.k_prefix(0).as_ptr();
        let mut rng = Rng::new(0xCAFE);
        let mut rows = Vec::new();
        for _ in 0..cap {
            let kr = rng.normal_f32_vec(groups * hd);
            let vr = rng.normal_f32_vec(groups * hd);
            cache.append(&kr, &vr);
            rows.push((kr, vr));
        }
        assert_eq!(cache.len(), cap);
        // The slab never moved: append writes in place into storage
        // sized once at construction — the zero-transient-allocation
        // steady-state claim, observable as pointer stability.
        assert_eq!(cache.k_prefix(0).as_ptr(), slab);
        // Prefixes are exact row-major per-group histories.
        for g in 0..groups {
            for (t, (kr, vr)) in rows.iter().enumerate() {
                assert_eq!(&cache.k_prefix(g)[t * hd..(t + 1) * hd], &kr[g * hd..(g + 1) * hd]);
                assert_eq!(&cache.v_prefix(g)[t * hd..(t + 1) * hd], &vr[g * hd..(g + 1) * hd]);
            }
        }
        // Past capacity: refuse, never grow.
        let kr = rng.normal_f32_vec(groups * hd);
        let vr = rng.normal_f32_vec(groups * hd);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.append(&kr, &vr)
        }));
        assert!(r.is_err(), "append past capacity must panic");
    }

    #[test]
    fn causal_ref_known_values_and_suffix_semantics() {
        let (seq, hd) = (4usize, 2usize);
        let mut rng = Rng::new(0xCA05);
        let q = rng.normal_f32_vec(seq * hd);
        let k = rng.normal_f32_vec(seq * hd);
        let v = rng.normal_f32_vec(seq * hd);
        // Full prefill, row 0 attends only key 0: softmax over one
        // logit is identity, so context row 0 is exactly V row 0.
        let full = causal_host_ref(&q, &k, &v, (1, seq, seq), (hd, 1));
        assert_eq!(&full[..hd], &v[..hd]);
        // The last row attends everything — identical to the unmasked
        // reference's last row.
        let un = attention_host_ref(&q, &k, &v, (1, seq), (hd, 1));
        for c in 0..hd {
            let (a, b) = (full[(seq - 1) * hd + c], un[(seq - 1) * hd + c]);
            assert!((a - b).abs() < 1e-5, "tail col {}: {} vs {}", c, a, b);
        }
        // seq_q < seq_k: queries are the LAST seq_q positions, so a
        // suffix call reproduces the matching rows of the full prefill
        // bit for bit (same visible-prefix arithmetic).
        let tail = causal_host_ref(&q[2 * hd..], &k, &v, (1, seq - 2, seq), (hd, 1));
        assert_eq!(tail, full[2 * hd..].to_vec());
        // Geometry the program layer rejects never runs.
        let r = std::panic::catch_unwind(|| {
            causal_host_ref(&q, &k, &v, (1, seq, seq - 1), (hd, 1))
        });
        assert!(r.is_err(), "seq_q > seq_k must not run");
    }

    #[test]
    fn run_cells_preserves_order_and_propagates_errors() {
        let vals = run_cells(10, 3, |i| Ok(i * 2)).unwrap();
        assert_eq!(vals, (0..10).map(|i| i * 2).collect::<Vec<_>>());
        let err = run_cells(10, 4, |i| if i == 7 { Err(anyhow!("boom")) } else { Ok(i) });
        assert!(err.is_err(), "worker error must surface");
    }

    #[test]
    fn manifest_bgemm_blocks_parse_rank4_params() {
        let dir = std::env::temp_dir().join("vortex_manifest_bgemm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bentry = r#"{"name": "bgemm_acc_4x8x128x128_f32", "kind": "bgemm_acc",
            "file": "b.hlo.txt",
            "params": {"bb": 4, "bm": 8, "bn": 128, "bk": 128,
                       "tm": 8, "tn": 128, "tk": 128, "in_dtype": "f32"},
            "inputs": [], "outputs": []}"#;
        let text =
            format!("{{\"entries\": [{}, {}]}}", entry_json("gemm_acc_8x128x128_f32"), bentry);
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(
            m.bgemm_acc_blocks(DType::F32),
            vec![([4, 8, 128, 128], "bgemm_acc_4x8x128x128_f32".to_string())]
        );
        // gemm_acc listing is unaffected by the batched entries.
        assert_eq!(m.gemm_acc_blocks(DType::F32).len(), 1);
        assert!(m.bgemm_acc_blocks(DType::Bf16).is_empty());
    }

    /// Miri UB gate over the threaded / unsafe-adjacent runtime paths
    /// introduced with parallel execution: everything in here is
    /// device-free, filesystem-free and xla-shim-free, so CI runs
    /// exactly `cargo +nightly miri test --lib -- miri_gate
    /// tile_algebra` (libtest filters OR together) and nothing else.
    /// Keep these tests tiny — Miri is ~100× slower than native.
    mod miri_gate {
        use super::*;

        #[test]
        fn run_cells_matches_sequential_across_thread_counts() {
            let seq = run_cells(9, 1, |i| Ok(i * i)).unwrap();
            for threads in [2, 3, 8] {
                assert_eq!(seq, run_cells(9, threads, |i| Ok(i * i)).unwrap());
            }
        }

        #[test]
        fn run_cells_propagates_worker_errors() {
            let r = run_cells(6, 3, |i| if i == 4 { Err(anyhow!("boom")) } else { Ok(i) });
            assert!(r.is_err(), "worker error must surface");
        }

        #[test]
        fn gather_block_zero_fills_past_the_dense_edge() {
            let data: Vec<f32> = (0..6).map(|x| x as f32).collect();
            let src = OperandSource::dense(&data, 2, 3);
            let mut dst = vec![7.0f32; 4 * 4];
            src.gather_block(&mut dst, 1, 2, 4, 4);
            // Only (row 1, col 2) = 5.0 is in range; the rest of the
            // block is the zero padding the edge-tile contract needs.
            assert_eq!(dst[0], 5.0);
            assert!(dst[1..].iter().all(|&x| x == 0.0));
        }

        #[test]
        fn transpose_view_window_matches_manual_transpose() {
            // Backing is (cols x rows) = 2x3 row-major; the view is its
            // 3x2 transpose: view(r, c) = data[c * rows + r].
            let data: Vec<f32> = (0..6).map(|x| x as f32).collect();
            let src = OperandSource::transpose(&data, 3, 2);
            assert_eq!(src.materialize(), vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
            // Edge window: one valid element, zero-filled remainder.
            let mut dst = vec![7.0f32; 4];
            src.gather_block(&mut dst, 2, 1, 2, 2);
            assert_eq!(dst, vec![5.0, 0.0, 0.0, 0.0]);
        }

        #[test]
        fn im2col_view_keeps_halo_taps_zero() {
            // 1x2x2x1 NHWC input, 3x3 filter, stride 1, pad 1 →
            // 2x2 output, patch row = 9 taps with a padding halo.
            let x = [1.0f32, 2.0, 3.0, 4.0];
            let src = OperandSource::im2col(&x, (1, 2, 2, 1), (3, 3), (1, 1), (0, 1));
            assert_eq!((src.rows(), src.cols()), (4, 9));
            let full = src.materialize();
            // Output (0, 0): taps above/left of the image are halo.
            assert_eq!(&full[0..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
            // A mid-row window exercises the tap-intersection path.
            let mut dst = vec![7.0f32; 4];
            src.gather_block(&mut dst, 0, 3, 1, 4);
            assert_eq!(dst, vec![0.0, 1.0, 2.0, 0.0]);
        }

        #[test]
        fn tile_scratch_is_exactly_three_blocks() {
            assert_eq!(tile_scratch_elems([2, 3, 4]), 2 * 4 + 4 * 3 + 2 * 3);
        }

        #[test]
        fn kv_prefix_transpose_view_reads_only_the_prefix() {
            // A 2-token prefix of a capacity-3 slab served through the
            // decode stage-1 transposed view: in-bounds reads only,
            // zero fill past the prefix edge.
            let mut cache = KvCache::new(1, 3, 2);
            cache.append(&[1.0, 2.0], &[5.0, 6.0]);
            cache.append(&[3.0, 4.0], &[7.0, 8.0]);
            let src = OperandSource::transpose(cache.k_prefix(0), 2, 2);
            assert_eq!(src.materialize(), vec![1.0, 3.0, 2.0, 4.0]);
            let mut dst = vec![9.0f32; 4];
            src.gather_block(&mut dst, 1, 1, 2, 2);
            assert_eq!(dst, vec![4.0, 0.0, 0.0, 0.0]);
        }
    }
}
