//! Real execution runtime: the PJRT side of the three-layer stack.
//!
//! Loads the AOT artifacts (`artifacts/*.hlo.txt` + `manifest.json`)
//! produced once by `python/compile/aot.py`, compiles them on the PJRT
//! CPU client (`xla` crate), and exposes the *kernel constructor*
//! execution path: a dynamic-shape GEMM is served by padding to the
//! selected micro-kernel's block, looping the launch grid, and chaining
//! the `gemm_acc` block executable over K super-blocks — the runtime
//! stage of the paper realized with real binaries. Python is never on
//! this path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::{ceil_div, DType};
use crate::util::json::Json;

/// Tensor I/O spec recorded by aot.py for every artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact (a static-shape compiled computation).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub params: Json,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactEntry {
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key)?.as_usize()
    }

    /// (bm, bn, bk) for gemm-family artifacts.
    pub fn block(&self) -> Option<[usize; 3]> {
        Some([
            self.param_usize("bm")?,
            self.param_usize("bn")?,
            self.param_usize("bk")?,
        ])
    }

    pub fn in_dtype(&self) -> DType {
        self.params
            .get("in_dtype")
            .and_then(|v| v.as_str())
            .and_then(DType::parse)
            .unwrap_or(DType::F32)
    }

    /// The Pallas inner tile (tm, tn, tk) recorded by aot.py — the L0
    /// tile of the micro-kernel library. A gemm-family entry without it
    /// is a malformed manifest, not an excuse for a plausible-looking
    /// default tile.
    pub fn l0_block(&self) -> Result<[usize; 3]> {
        let get = |key: &str| {
            self.param_usize(key).ok_or_else(|| {
                anyhow!(
                    "manifest entry {}: missing/invalid param {:?} \
                     (regenerate with `make artifacts`)",
                    self.name,
                    key
                )
            })
        };
        Ok([get("tm")?, get("tn")?, get("tk")?])
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

fn parse_io(v: &Json) -> Option<Vec<IoSpec>> {
    v.as_arr()?
        .iter()
        .map(|io| {
            Some(IoSpec {
                shape: io
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Option<Vec<_>>>()?,
                dtype: io.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?} (run `make artifacts`)", path))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {}", path.display(), e))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                Some(ArtifactEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    kind: e.get("kind")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    params: e.get("params")?.clone(),
                    inputs: parse_io(e.get("inputs")?)?,
                    outputs: parse_io(e.get("outputs")?)?,
                })
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("malformed manifest entry"))?;
        // Duplicate artifact names would make `find` silently return
        // whichever entry comes first — reject the manifest instead.
        let mut seen = std::collections::HashSet::new();
        for e in &entries {
            if !seen.insert(e.name.as_str()) {
                bail!("{}: duplicate artifact name {:?}", path.display(), e.name);
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All gemm_acc blocks of a dtype, as (block, artifact name).
    pub fn gemm_acc_blocks(&self, dtype: DType) -> Vec<([usize; 3], String)> {
        self.entries
            .iter()
            .filter(|e| e.kind == "gemm_acc" && e.in_dtype() == dtype)
            .filter_map(|e| Some((e.block()?, e.name.clone())))
            .collect()
    }

    /// Stable fingerprint of the AOT artifact set: every entry's name,
    /// kind, parameters (deterministically serialized) and — when the
    /// artifact file is readable — its bytes. Feed this into
    /// [`crate::compiler::CompileOpts::aot_fingerprint`] so on-disk
    /// library caches keyed on real-testbed blocks invalidate when the
    /// Pallas blocks are regenerated (ROADMAP offline-stage item).
    pub fn fingerprint(&self) -> u64 {
        use crate::util::rng::{fnv1a, hash_key};
        let mut parts: Vec<u64> = Vec::with_capacity(self.entries.len() * 4);
        for e in &self.entries {
            parts.push(fnv1a(e.name.as_bytes()));
            parts.push(fnv1a(e.kind.as_bytes()));
            parts.push(fnv1a(e.params.dump().as_bytes()));
            if let Ok(bytes) = std::fs::read(self.dir.join(&e.file)) {
                parts.push(fnv1a(&bytes));
            }
        }
        hash_key(&parts)
    }
}

/// The real engine: PJRT CPU client + lazily compiled executables.
pub struct RealEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl RealEngine {
    pub fn load(artifacts_dir: &Path) -> Result<RealEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(RealEngine { client, manifest, exes: RefCell::new(HashMap::new()) })
    }

    /// Compile (once) and return the executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    /// Build a literal of `dtype` with the given dims from f32 host data.
    fn literal(&self, data: &[f32], dims: &[i64], dtype: DType) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data).reshape(dims)?;
        match dtype {
            DType::F32 => Ok(lit),
            DType::Bf16 => Ok(lit.convert(xla::PrimitiveType::Bf16)?),
            DType::F16 => Ok(lit.convert(xla::PrimitiveType::F16)?),
        }
    }

    fn spec_dtype(spec: &IoSpec) -> DType {
        match spec.dtype.as_str() {
            "bfloat16" | "bf16" => DType::Bf16,
            "float16" | "f16" => DType::F16,
            _ => DType::F32,
        }
    }

    /// Run a 1-output artifact on f32 host buffers; returns f32 data.
    /// Inputs are converted to each declared input dtype.
    pub fn run_raw(&self, name: &str, inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let lits = inputs
            .iter()
            .zip(entry.inputs.iter())
            .map(|((data, dims), spec)| self.literal(data, dims, Self::spec_dtype(spec)))
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = if result.shape()?.is_tuple() {
            result.to_tuple1()?
        } else {
            result
        };
        let out = match out.ty()? {
            xla::ElementType::F32 => out,
            _ => out.convert(xla::PrimitiveType::F32)?,
        };
        Ok(out.to_vec::<f32>()?)
    }

    /// Dynamic-shape GEMM via the kernel constructor: pad to the block,
    /// loop the grid, chain `gemm_acc` over K super-blocks (paper §6.2).
    ///
    /// `a` is row-major (m x k), `b` is (k x n); returns row-major
    /// (m x n) f32.
    ///
    /// §Perf fast path (f32): A/B blocks are uploaded to device buffers
    /// once and reused across the grid (B blocks are hit `gm` times),
    /// the accumulator stays device-resident across the K chain (the
    /// untupled output buffer feeds the next call directly), and a
    /// single shared zero buffer seeds every (M, N) block.
    pub fn gemm_dynamic(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        if dtype != DType::F32 {
            return self.gemm_dynamic_literal(a, b, (m, n, k), block, dtype);
        }
        let [bm, bn, bk] = block;
        let name = format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name());
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let exe = self.executable(&name)?;
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));

        // Pre-upload B blocks: indexed [ki][ni], reused for every mi.
        let mut b_blk = vec![0f32; bk * bn];
        let mut b_bufs: Vec<Vec<xla::PjRtBuffer>> = Vec::with_capacity(gk);
        for ki in 0..gk {
            let k0 = ki * bk;
            let kdep = bk.min(k - k0);
            let mut row = Vec::with_capacity(gn);
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                if kdep < bk || ncols < bn {
                    b_blk.iter_mut().for_each(|x| *x = 0.0);
                }
                for r in 0..kdep {
                    let src = (k0 + r) * n + n0;
                    b_blk[r * bn..r * bn + ncols].copy_from_slice(&b[src..src + ncols]);
                }
                row.push(self.client.buffer_from_host_buffer(&b_blk, &[bk, bn], None)?);
            }
            b_bufs.push(row);
        }

        let zeros = vec![0f32; bm * bn];
        let zero_buf = self.client.buffer_from_host_buffer(&zeros, &[bm, bn], None)?;
        let mut a_blk = vec![0f32; bm * bk];
        let mut out = vec![0f32; m * n];
        for mi in 0..gm {
            let m0 = mi * bm;
            let mrows = bm.min(m - m0);
            // Upload this row's A blocks once; reused for every ni.
            let mut a_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(gk);
            for ki in 0..gk {
                let k0 = ki * bk;
                let kdep = bk.min(k - k0);
                if kdep < bk || mrows < bm {
                    a_blk.iter_mut().for_each(|x| *x = 0.0);
                }
                for r in 0..mrows {
                    let src = (m0 + r) * k + k0;
                    a_blk[r * bk..r * bk + kdep].copy_from_slice(&a[src..src + kdep]);
                }
                a_bufs.push(self.client.buffer_from_host_buffer(&a_blk, &[bm, bk], None)?);
            }
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                // Device-resident accumulator chain over K.
                let mut c_buf: Option<xla::PjRtBuffer> = None;
                for ki in 0..gk {
                    let c_in = c_buf.as_ref().unwrap_or(&zero_buf);
                    let mut res =
                        exe.execute_b(&[&a_bufs[ki], &b_bufs[ki][ni], c_in])?;
                    c_buf = Some(res.swap_remove(0).swap_remove(0));
                }
                let lit = c_buf.unwrap().to_literal_sync()?;
                let c_blk = lit.to_vec::<f32>()?;
                for r in 0..mrows {
                    let dst = (m0 + r) * n + n0;
                    out[dst..dst + ncols]
                        .copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Literal-based reference path (all dtypes); also the baseline for
    /// the §Perf before/after comparison.
    pub fn gemm_dynamic_literal(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let [bm, bn, bk] = block;
        let name = format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name());
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));
        let mut out = vec![0f32; m * n];
        let mut a_blk = vec![0f32; bm * bk];
        let mut b_blk = vec![0f32; bk * bn];
        let zeros = vec![0f32; bm * bn];
        for mi in 0..gm {
            let m0 = mi * bm;
            let mrows = bm.min(m - m0);
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                let mut c_blk = zeros.clone();
                for ki in 0..gk {
                    let k0 = ki * bk;
                    let kdep = bk.min(k - k0);
                    // Gather A block (zero-padded).
                    a_blk.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..mrows {
                        let src = (m0 + r) * k + k0;
                        a_blk[r * bk..r * bk + kdep]
                            .copy_from_slice(&a[src..src + kdep]);
                    }
                    // Gather B block (zero-padded).
                    b_blk.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..kdep {
                        let src = (k0 + r) * n + n0;
                        b_blk[r * bn..r * bn + ncols]
                            .copy_from_slice(&b[src..src + ncols]);
                    }
                    c_blk = self.run_raw(
                        &name,
                        &[
                            (&a_blk, vec![bm as i64, bk as i64]),
                            (&b_blk, vec![bk as i64, bn as i64]),
                            (&c_blk, vec![bm as i64, bn as i64]),
                        ],
                    )?;
                }
                // Scatter C block (crop padding).
                for r in 0..mrows {
                    let dst = (m0 + r) * n + n0;
                    out[dst..dst + ncols]
                        .copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Wall-clock one artifact launch (min over `reps`), seconds.
    /// This is the real-testbed empirical L0/L1 profiling primitive.
    pub fn time_artifact(&self, name: &str, reps: usize) -> Result<f64> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        let bufs: Vec<(Vec<f32>, Vec<i64>)> = entry
            .inputs
            .iter()
            .map(|spec| {
                let n: usize = spec.shape.iter().product();
                (
                    vec![0.1f32; n.max(1)],
                    spec.shape.iter().map(|&d| d as i64).collect(),
                )
            })
            .collect();
        let refs: Vec<(&[f32], Vec<i64>)> =
            bufs.iter().map(|(d, s)| (d.as_slice(), s.clone())).collect();
        // Warm-up (compiles on first use).
        self.run_raw(&entry.name, &refs)?;
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            self.run_raw(&entry.name, &refs)?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok(best)
    }
}

/// Build the real-testbed micro-kernel library: every `gemm_acc` block
/// in the manifest is wall-clock profiled (`reps` launches, min taken)
/// — this is the empirical half of the hybrid analyzer running on real
/// hardware instead of the simulator. The L0 tile is the Pallas inner
/// tile (tm, tn, tk) recorded by aot.py.
pub fn build_real_library(
    engine: &RealEngine,
    hw: &crate::hw::HwSpec,
    dtype: DType,
    reps: usize,
) -> Result<crate::compiler::MicroKernelLibrary> {
    use crate::compiler::{MicroKernel, MicroKernelLibrary};
    use crate::ir::{OpKind, Tile};
    let backend_name = match dtype {
        DType::F32 => "mxu_f32",
        _ => "mxu_bf16",
    };
    let backend = hw
        .backend_idx(backend_name)
        .ok_or_else(|| anyhow!("hw {} lacks backend {}", hw.name, backend_name))?;
    let mut kernels = Vec::new();
    for (block, name) in engine.manifest.gemm_acc_blocks(dtype) {
        let entry = engine.manifest.find(&name).unwrap();
        let l0 = Tile::from3(entry.l0_block()?);
        let base_cost = engine.time_artifact(&name, reps)?;
        kernels.push(MicroKernel { l0, l1: Tile::from3(block), backend, base_cost });
    }
    if kernels.is_empty() {
        bail!("manifest has no gemm_acc blocks for {}", dtype.name());
    }
    kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
    Ok(MicroKernelLibrary {
        hw_name: hw.name.to_string(),
        op: OpKind::Gemm,
        dtype,
        analyzer: crate::cost::hybrid::AnalyzerConfig::empirical(1),
        kernels,
        dispatch: Vec::new(),
    })
}

/// im2col patch matrix of one channel group (the data-layout half
/// Vortex folds into the rKernel recursion, §4.2), honoring stride and
/// symmetric zero padding.
///
/// `x` is NHWC row-major (n, h, w, cin). Rows are output positions
/// (b, oy, ox); columns are filter taps in (i, j, c) order over the
/// `cg` channels starting at `c0` — matching the group's filter slab
/// reshaped as a (kh·kw·cg, cout/g) row-major matrix. Taps that fall
/// in the zero-padding halo stay zero.
pub fn im2col_patches(
    x: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw): (usize, usize),
    (stride, pad): (usize, usize),
    (c0, cg): (usize, usize),
) -> Vec<f32> {
    let (oh, ow) = crate::ir::conv_out_dims((h, wd), (kh, kw), stride, pad)
        .expect("im2col_patches: invalid conv geometry");
    assert!(c0 + cg <= cin, "channel slice {}+{} exceeds cin {}", c0, cg, cin);
    let kdim = kh * kw * cg;
    let m = n * oh * ow;
    let mut patches = vec![0f32; m * kdim];
    for b in 0..n {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad as isize;
                let row = ((b * oh + oy) * ow + ox) * kdim;
                for i in 0..kh {
                    let iy = iy0 + i as isize;
                    if iy < 0 || iy >= h as isize {
                        continue; // padding halo: stays zero
                    }
                    for j in 0..kw {
                        let ix = ix0 + j as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src =
                            ((b * h + iy as usize) * wd + ix as usize) * cin + c0;
                        let dst = row + (i * kw + j) * cg;
                        patches[dst..dst + cg].copy_from_slice(&x[src..src + cg]);
                    }
                }
            }
        }
    }
    patches
}

/// Group `g`'s filter slab as a (kh·kw·cg, cout/groups) row-major
/// matrix. `w` is (kh, kw, cin/groups, cout) row-major; output channel
/// `co` belongs to group `co / (cout/groups)`.
pub fn filter_group(
    w: &[f32],
    (kh, kw, cg, cout): (usize, usize, usize, usize),
    (g, groups): (usize, usize),
) -> Vec<f32> {
    let coutg = cout / groups;
    let kdim = kh * kw * cg;
    let mut out = vec![0f32; kdim * coutg];
    for r in 0..kdim {
        let src = r * cout + g * coutg;
        out[r * coutg..(r + 1) * coutg].copy_from_slice(&w[src..src + coutg]);
    }
    out
}

/// Dynamic-shape convolution on the real engine via (per-group)
/// implicit GEMM: im2col in Rust + the dynamic GEMM kernel constructor
/// for compute. Supports stride, symmetric zero padding and channel
/// groups (depthwise when `groups == cin`).
///
/// `x` is NHWC row-major (n, h, w, cin); `w` is (kh, kw, cin/groups,
/// cout); `geom` is (stride, pad, groups). Returns NHWC (n, oh, ow,
/// cout) f32 (inputs are converted to `dtype` on device).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
    (stride, pad, groups): (usize, usize, usize),
    dtype: DType,
) -> Result<Vec<f32>> {
    // Geometry is validated where every conv program is: at program
    // construction. The runtime never sees a bogus iteration space.
    let program = crate::ir::TensorProgram::conv2d(
        (n, h, wd, cin),
        (kh, kw, cout),
        (stride, pad, groups),
        dtype,
    )
    .map_err(|e| anyhow!("conv2d_dynamic: {}", e))?;
    let (oh, ow) = program.conv_output().unwrap();
    let (cg, coutg) = (cin / groups, cout / groups);
    let (m, kdim) = (n * oh * ow, kh * kw * cg);
    if x.len() != n * h * wd * cin {
        bail!("conv2d_dynamic: input has {} elems, want {}", x.len(), n * h * wd * cin);
    }
    if w.len() != kh * kw * cg * cout {
        bail!("conv2d_dynamic: filter has {} elems, want {}", w.len(), kh * kw * cg * cout);
    }
    // Select through the SAME op-aware selector as every other op: the
    // conv program's IterSpace goes straight in (rank 3 for ungrouped,
    // rank 4 with the group batch axis otherwise), and the selector
    // resolves it against a native library or the measurement-alias
    // fallback (no conv-specific selection side path here).
    let space = program.space();
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for conv space {:?}", space))?;
    let kern = selector.kernel(&sel);
    // The contraction block of the selected tile: rank-3 tiles are the
    // block; rank-4 (group-batched) tiles carry it after the group axis.
    let block = match kern.l1.rank() {
        3 => kern.l1.to3(),
        4 => [kern.l1[1], kern.l1[2], kern.l1[3]],
        r => bail!("unsupported conv kernel rank {}", r),
    };
    if groups == 1 {
        let patches = im2col_patches(x, (n, h, wd, cin), (kh, kw), (stride, pad), (0, cin));
        return engine.gemm_dynamic(&patches, w, (m, cout, kdim), block, dtype);
    }
    // Per-group patch matrices feeding the same kernel constructor;
    // group results interleave along the output-channel axis.
    let mut out = vec![0f32; m * cout];
    for g in 0..groups {
        let patches =
            im2col_patches(x, (n, h, wd, cin), (kh, kw), (stride, pad), (g * cg, cg));
        let wg = filter_group(w, (kh, kw, cg, cout), (g, groups));
        let c = engine.gemm_dynamic(&patches, &wg, (m, coutg, kdim), block, dtype)?;
        for r in 0..m {
            out[r * cout + g * coutg..r * cout + (g + 1) * coutg]
                .copy_from_slice(&c[r * coutg..(r + 1) * coutg]);
        }
    }
    Ok(out)
}

/// Numerically-stable streaming row-softmax, in place over a row-major
/// (rows x cols) matrix: one online pass per row keeps a running max
/// and a rescaled running sum (the flash-attention recurrence — each
/// new maximum rescales the sum by `exp(old_max - new_max)`), then one
/// normalization pass. This is the epilogue the fused attention chain
/// applies to the resident score tile at the L1 boundary, and the op
/// the softmax micro-measurement prices.
pub fn streaming_softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(
        x.len(),
        rows * cols,
        "streaming_softmax_rows: {} elems for {}x{}",
        x.len(),
        rows,
        cols
    );
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mut max = f32::NEG_INFINITY;
        let mut sum = 0f32;
        for &v in row.iter() {
            if v > max {
                sum *= (max - v).exp(); // exp(-inf) = 0 seeds the first step
                max = v;
            }
            sum += (v - max).exp();
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v = (*v - max).exp() * inv;
        }
    }
}

/// Dynamic-shape fused attention on the real engine: per head group,
/// `score = Q·Kᵀ` and `ctx = P·V` run as two [`RealEngine::gemm_dynamic`]
/// calls through the SAME kernel-constructor block, with the
/// numerically-stable streaming row-softmax between them — exactly the
/// chain the [`crate::ir::FusedAttention`] strategy space prices.
///
/// `q`, `k`, `v` are (batch·heads, seq, d/heads) row-major f32 (each
/// head group contiguous); returns the context in the same layout.
/// Geometry is validated where every attention program is — at program
/// construction via [`crate::ir::TensorProgram::attention`] — and the
/// block comes from the op-aware selector: the attention space goes
/// straight in and resolves against a native attention library or the
/// batched-GEMM measurement-alias fallback (no attention-specific
/// selection side path).
#[allow(clippy::too_many_arguments)]
pub fn attention_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    (batch, seq): (usize, usize),
    (d, heads): (usize, usize),
    dtype: DType,
) -> Result<Vec<f32>> {
    let program = crate::ir::TensorProgram::attention((batch, seq), (d, heads), dtype)
        .map_err(|e| anyhow!("attention_dynamic: {}", e))?;
    let hd = d / heads;
    let groups = batch * heads;
    let want = groups * seq * hd;
    for (name, buf) in [("q", q), ("k", k), ("v", v)] {
        if buf.len() != want {
            bail!("attention_dynamic: {} has {} elems, want {}", name, buf.len(), want);
        }
    }
    let space = program.space();
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for attention space {:?}", space))?;
    let kern = selector.kernel(&sel);
    // Rank-4 tiles carry the contraction block after the head-group
    // batch axis; a rank-3 tile (flat-contraction library) is the
    // block itself.
    let block = match kern.l1.rank() {
        3 => kern.l1.to3(),
        4 => [kern.l1[1], kern.l1[2], kern.l1[3]],
        r => bail!("unsupported attention kernel rank {}", r),
    };
    let mut out = vec![0f32; want];
    let mut kt = vec![0f32; hd * seq];
    for g in 0..groups {
        let base = g * seq * hd;
        let qg = &q[base..base + seq * hd];
        let kg = &k[base..base + seq * hd];
        let vg = &v[base..base + seq * hd];
        // Kᵀ as an (hd x seq) row-major operand for the score GEMM.
        for r in 0..seq {
            for c in 0..hd {
                kt[c * seq + r] = kg[r * hd + c];
            }
        }
        let mut scores = engine.gemm_dynamic(qg, &kt, (seq, seq, hd), block, dtype)?;
        streaming_softmax_rows(&mut scores, seq, seq);
        let ctx = engine.gemm_dynamic(&scores, vg, (seq, hd, seq), block, dtype)?;
        out[base..base + seq * hd].copy_from_slice(&ctx);
    }
    Ok(out)
}

/// Direct reference attention for verification: per head group, naive
/// two-pass-stable softmax over explicitly accumulated score rows,
/// then the context accumulation — no GEMM helper involved, so it
/// cross-checks the `gemm_dynamic` → softmax → `gemm_dynamic` chain
/// (and its host composition) independently.
///
/// Layouts match [`attention_dynamic`]. Panics on invalid attention
/// geometry (mirrors `im2col_patches`).
pub fn attention_host_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    (batch, seq): (usize, usize),
    (d, heads): (usize, usize),
) -> Vec<f32> {
    crate::ir::TensorProgram::attention((batch, seq), (d, heads), DType::F32)
        .expect("attention_host_ref: invalid attention geometry");
    let hd = d / heads;
    let groups = batch * heads;
    let mut out = vec![0f32; groups * seq * hd];
    let mut scores = vec![0f32; seq];
    for g in 0..groups {
        let base = g * seq * hd;
        for i in 0..seq {
            let mut max = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate() {
                let mut acc = 0f32;
                for c in 0..hd {
                    acc += q[base + i * hd + c] * k[base + j * hd + c];
                }
                *s = acc;
                max = max.max(acc);
            }
            let mut sum = 0f32;
            for s in scores.iter_mut() {
                *s = (*s - max).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            for c in 0..hd {
                let mut acc = 0f32;
                for (j, &p) in scores.iter().enumerate() {
                    acc += p * v[base + j * hd + c];
                }
                out[base + i * hd + c] = acc * inv;
            }
        }
    }
    out
}

/// Reference row-major triple-loop GEMM for verification in tests.
pub fn gemm_host_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            let row = l * n;
            let out = i * n;
            for j in 0..n {
                c[out + j] += av * b[row + j];
            }
        }
    }
    c
}

/// Reference direct NHWC convolution (for verification): stride,
/// symmetric zero padding and channel groups. `w` is (kh, kw,
/// cin/groups, cout) row-major.
pub fn conv2d_host_ref(
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
    (stride, pad, groups): (usize, usize, usize),
) -> Vec<f32> {
    let (oh, ow) = crate::ir::conv_out_dims((h, wd), (kh, kw), stride, pad)
        .expect("conv2d_host_ref: invalid conv geometry");
    let (cg, coutg) = (cin / groups, cout / groups);
    let mut out = vec![0f32; n * oh * ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            let iy0 = (oy * stride) as isize - pad as isize;
            for ox in 0..ow {
                let ix0 = (ox * stride) as isize - pad as isize;
                let dst = ((b * oh + oy) * ow + ox) * cout;
                for i in 0..kh {
                    let iy = iy0 + i as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for j in 0..kw {
                        let ix = ix0 + j as isize;
                        if ix < 0 || ix >= wd as isize {
                            continue;
                        }
                        let src = ((b * h + iy as usize) * wd + ix as usize) * cin;
                        for co in 0..cout {
                            let g = co / coutg;
                            let mut acc = out[dst + co];
                            for c in 0..cg {
                                let xv = x[src + g * cg + c];
                                let wv = w[((i * kw + j) * cg + c) * cout + co];
                                acc += xv * wv;
                            }
                            out[dst + co] = acc;
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::conv_out_dims;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn host_ref_gemm_known_values() {
        // [[1,2],[3,4]] @ I = same matrix
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(gemm_host_ref(&a, &b, 2, 2, 2), a);
    }

    #[test]
    fn manifest_parse_rejects_garbage() {
        let dir = std::env::temp_dir().join("vortex_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"entries\": [{}]}").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    fn entry_json(name: &str) -> String {
        format!(
            r#"{{"name": "{name}", "kind": "gemm_acc", "file": "{name}.hlo.txt",
                 "params": {{"bm": 8, "bn": 128, "bk": 128,
                             "tm": 8, "tn": 128, "tk": 128, "in_dtype": "f32"}},
                 "inputs": [], "outputs": []}}"#
        )
    }

    #[test]
    fn manifest_rejects_duplicate_artifact_names() {
        let dir = std::env::temp_dir().join("vortex_manifest_dup_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dup = format!(
            "{{\"entries\": [{}, {}]}}",
            entry_json("gemm_acc_8x128x128_f32"),
            entry_json("gemm_acc_8x128x128_f32")
        );
        std::fs::write(dir.join("manifest.json"), dup).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("duplicate artifact name"), "{}", err);
        // Distinct names load fine.
        let ok = format!(
            "{{\"entries\": [{}, {}]}}",
            entry_json("gemm_acc_8x128x128_f32"),
            entry_json("gemm_acc_16x128x128_f32")
        );
        std::fs::write(dir.join("manifest.json"), ok).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap().entries.len(), 2);
    }

    #[test]
    fn manifest_fingerprint_tracks_blocks_and_artifact_bytes() {
        let dir = std::env::temp_dir().join("vortex_manifest_fp_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let one = format!("{{\"entries\": [{}]}}", entry_json("gemm_acc_8x128x128_f32"));
        std::fs::write(dir.join("manifest.json"), &one).unwrap();
        let f1 = Manifest::load(&dir).unwrap().fingerprint();
        // Stable across reloads.
        assert_eq!(f1, Manifest::load(&dir).unwrap().fingerprint());
        // A (new) artifact binary enters the fingerprint...
        std::fs::write(dir.join("gemm_acc_8x128x128_f32.hlo.txt"), "HLO v1").unwrap();
        let f2 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f1, f2, "artifact bytes not fingerprinted");
        // ...and changed bytes change it (a regenerated Pallas block).
        std::fs::write(dir.join("gemm_acc_8x128x128_f32.hlo.txt"), "HLO v2").unwrap();
        let f3 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f2, f3, "changed artifact bytes aliased");
        // Changed block parameters change it even with the same file.
        let changed = one.replace("\"bn\": 128", "\"bn\": 256");
        assert_ne!(one, changed);
        std::fs::write(dir.join("manifest.json"), &changed).unwrap();
        let f4 = Manifest::load(&dir).unwrap().fingerprint();
        assert_ne!(f3, f4, "changed params aliased");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn l0_block_requires_inner_tile_params() {
        let dir = std::env::temp_dir().join("vortex_manifest_l0_test");
        std::fs::create_dir_all(&dir).unwrap();
        let no_tile = r#"{"entries": [{"name": "gemm_acc_8x128x128_f32",
            "kind": "gemm_acc", "file": "x.hlo.txt",
            "params": {"bm": 8, "bn": 128, "bk": 128, "in_dtype": "f32"},
            "inputs": [], "outputs": []}]}"#;
        std::fs::write(dir.join("manifest.json"), no_tile).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let err = m.entries[0].l0_block().unwrap_err().to_string();
        assert!(err.contains("missing/invalid param \"tm\""), "{}", err);
        // A well-formed entry yields the recorded tile, not a default.
        let ok = format!("{{\"entries\": [{}]}}", entry_json("gemm_acc_8x128x128_f32"));
        std::fs::write(dir.join("manifest.json"), ok).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries[0].l0_block().unwrap(), [8, 128, 128]);
    }

    // -- generalized conv geometry -----------------------------------------

    /// im2col + per-group host GEMM: the exact compute conv2d_dynamic
    /// performs, minus the device.
    fn conv_via_im2col(
        x: &[f32],
        w: &[f32],
        io: (usize, usize, usize, usize),
        filt: (usize, usize, usize),
        geom: (usize, usize, usize),
    ) -> Vec<f32> {
        let (n, h, wd, cin) = io;
        let (kh, kw, cout) = filt;
        let (stride, pad, groups) = geom;
        let (oh, ow) = conv_out_dims((h, wd), (kh, kw), stride, pad).unwrap();
        let (cg, coutg) = (cin / groups, cout / groups);
        let (m, kdim) = (n * oh * ow, kh * kw * cg);
        let mut out = vec![0f32; m * cout];
        for g in 0..groups {
            let patches =
                im2col_patches(x, io, (kh, kw), (stride, pad), (g * cg, cg));
            let wg = filter_group(w, (kh, kw, cg, cout), (g, groups));
            let c = gemm_host_ref(&patches, &wg, m, coutg, kdim);
            for r in 0..m {
                out[r * cout + g * coutg..r * cout + (g + 1) * coutg]
                    .copy_from_slice(&c[r * coutg..(r + 1) * coutg]);
            }
        }
        out
    }

    fn assert_same(got: &[f32], want: &[f32], what: &str) -> Result<(), String> {
        if got.len() != want.len() {
            return Err(format!("{}: length {} vs {}", what, got.len(), want.len()));
        }
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            if (g - w).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("{}: elem {} differs: {} vs {}", what, i, g, w));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_im2col_gemm_matches_direct_conv_reference() {
        // Satellite: across random (stride, padding, groups, shape)
        // tuples — including partial tiles and depthwise groups == cin —
        // the generalized im2col + gemm_host_ref path computes exactly
        // what the direct conv2d_host_ref computes.
        forall(
            "im2col-gemm-equals-direct-conv",
            60,
            0xC0DE,
            |r: &mut Rng, size| {
                let kh = r.usize(1, 3);
                let kw = r.usize(1, 3);
                let stride = r.usize(1, 3);
                let pad = r.usize(0, 2);
                // Depthwise (cg = 1) in a third of the cases.
                let cg = if r.usize(0, 2) == 0 { 1 } else { r.usize(1, 3) };
                let groups = r.usize(1, 4);
                let coutg = r.usize(1, 3);
                let grow = 1 + size / 25;
                let h = (kh.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let w = (kw.saturating_sub(2 * pad)).max(1) + r.usize(0, 4 * grow);
                let n = r.usize(1, 2);
                ((n, h, w, cg * groups), (kh, kw, coutg * groups), (stride, pad, groups))
            },
            |&(io, filt, geom)| {
                let (n, h, w, cin) = io;
                let (kh, kw, cout) = filt;
                let cg = cin / geom.2;
                let mut rng = Rng::new(n as u64 + h as u64 * 31 + w as u64 * 7);
                let x = rng.normal_f32_vec(n * h * w * cin);
                let wgt = rng.normal_f32_vec(kh * kw * cg * cout);
                let got = conv_via_im2col(&x, &wgt, io, filt, geom);
                let want = conv2d_host_ref(&x, &wgt, io, filt, geom);
                assert_same(&got, &want, "im2col-vs-direct")
            },
        );
    }

    #[test]
    fn host_ref_conv_known_values() {
        // 1x1 conv with identity channel mix copies the input.
        let x: Vec<f32> = (0..2 * 3 * 3 * 2).map(|v| v as f32).collect();
        let w = vec![1.0, 0.0, 0.0, 1.0]; // (1,1,2,2) identity
        let y = conv2d_host_ref(&x, &w, (2, 3, 3, 2), (1, 1, 2), (1, 0, 1));
        assert_eq!(y, x);
        // Stride 2 keeps every other position.
        let y2 = conv2d_host_ref(&x, &w, (2, 3, 3, 2), (1, 1, 2), (2, 0, 1));
        assert_eq!(y2.len(), 2 * 2 * 2 * 2);
        assert_eq!(&y2[..2], &x[..2]); // (0,0)
        assert_eq!(&y2[2..4], &x[4..6]); // (0,2)
        // Depthwise 1x1 with weights [2, 3]: channel c scales by w[c].
        let wdw = vec![2.0, 3.0]; // (1,1,1,2), groups = 2
        let ydw = conv2d_host_ref(&x, &wdw, (1, 2, 2, 2), (1, 1, 2), (1, 0, 2));
        for (i, v) in ydw.iter().enumerate() {
            let scale = if i % 2 == 0 { 2.0 } else { 3.0 };
            assert_eq!(*v, x[i] * scale);
        }
    }

    #[test]
    fn padded_conv_matches_manual_halo() {
        // 1x1x1 input, 3x3 sum filter, pad 1: output = input everywhere
        // the filter tap hits the single pixel.
        let x = vec![5.0f32];
        let w = vec![1.0f32; 9]; // (3,3,1,1) all-ones
        let y = conv2d_host_ref(&x, &w, (1, 1, 1, 1), (3, 3, 1), (1, 1, 1));
        assert_eq!(y, vec![5.0]); // only the center tap lands in-bounds
        // pad 2: 3x3 output, each position sees the pixel once.
        let y2 = conv2d_host_ref(&x, &w, (1, 1, 1, 1), (3, 3, 1), (1, 2, 1));
        assert_eq!(y2, vec![5.0; 9]);
    }

    #[test]
    fn im2col_rejects_invalid_geometry() {
        let x = vec![0f32; 4 * 4];
        let r = std::panic::catch_unwind(|| {
            im2col_patches(&x, (1, 2, 2, 4), (5, 5), (1, 0), (0, 4))
        });
        assert!(r.is_err(), "undersized feature map must not im2col");
    }

    // -- attention-fused chain ----------------------------------------------

    /// gemm -> streaming softmax -> gemm: the exact compute
    /// attention_dynamic performs, minus the device.
    fn attention_via_gemms(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        (batch, seq): (usize, usize),
        (d, heads): (usize, usize),
    ) -> Vec<f32> {
        let hd = d / heads;
        let groups = batch * heads;
        let mut out = vec![0f32; groups * seq * hd];
        let mut kt = vec![0f32; hd * seq];
        for g in 0..groups {
            let base = g * seq * hd;
            let kg = &k[base..base + seq * hd];
            for r in 0..seq {
                for c in 0..hd {
                    kt[c * seq + r] = kg[r * hd + c];
                }
            }
            let mut scores = gemm_host_ref(&q[base..base + seq * hd], &kt, seq, seq, hd);
            streaming_softmax_rows(&mut scores, seq, seq);
            let ctx = gemm_host_ref(&scores, &v[base..base + seq * hd], seq, hd, seq);
            out[base..base + seq * hd].copy_from_slice(&ctx);
        }
        out
    }

    #[test]
    fn prop_attention_ref_matches_softmax_of_gemms_composition() {
        // Satellite: attention_host_ref == softmax(gemm_host_ref) ·
        // gemm_host_ref across random (batch, heads, seq, head-dim)
        // tuples — the direct reference and the two-GEMM-plus-
        // streaming-softmax chain (what attention_dynamic runs on
        // device) compute the same thing.
        forall(
            "attention-ref-equals-gemm-softmax-chain",
            50,
            0xA77E,
            |r: &mut Rng, size| {
                let batch = r.usize(1, 2);
                let heads = r.usize(1, 3);
                let seq = r.usize(1, 3 + 20 * (1 + size / 30));
                let hd = r.usize(1, 8);
                (batch, heads, seq, hd)
            },
            |&(batch, heads, seq, hd)| {
                let groups = batch * heads;
                let mut rng = Rng::new(seq as u64 * 131 + hd as u64 * 7 + groups as u64);
                let q = rng.normal_f32_vec(groups * seq * hd);
                let k = rng.normal_f32_vec(groups * seq * hd);
                let v = rng.normal_f32_vec(groups * seq * hd);
                let io = (batch, seq);
                let proj = (heads * hd, heads);
                let want = attention_host_ref(&q, &k, &v, io, proj);
                let got = attention_via_gemms(&q, &k, &v, io, proj);
                assert_same(&got, &want, "attention-chain-vs-direct")
            },
        );
    }

    #[test]
    fn attention_ref_edge_sequences() {
        // seq = 1 (decode step): softmax over one logit is identity, so
        // the context is exactly V's single row.
        let q = vec![0.3f32, -1.2];
        let k = vec![0.7f32, 0.1];
        let v = vec![5.0f32, -3.0];
        let out = attention_host_ref(&q, &k, &v, (1, 1), (2, 1));
        assert_eq!(out, v);
        // Non-power-of-two seq with uniform scores: softmax is uniform,
        // context is the column mean of V.
        let (seq, hd) = (7usize, 3usize);
        let q0 = vec![0f32; seq * hd];
        let k0 = vec![0f32; seq * hd];
        let mut vv = vec![0f32; seq * hd];
        for (i, x) in vv.iter_mut().enumerate() {
            *x = i as f32;
        }
        let out = attention_host_ref(&q0, &k0, &vv, (1, seq), (hd, 1));
        for i in 0..seq {
            for c in 0..hd {
                let mean: f32 = (0..seq).map(|j| vv[j * hd + c]).sum::<f32>() / seq as f32;
                assert!((out[i * hd + c] - mean).abs() < 1e-4, "({}, {})", i, c);
            }
        }
    }

    #[test]
    fn streaming_softmax_matches_two_pass_and_is_stable() {
        // Rows sum to 1 and match the naive two-pass computation, even
        // with large magnitudes that overflow a non-stabilized exp.
        let mut x = vec![1000.0f32, 1001.0, 999.0, -2000.0, 3.5, 0.0];
        let y = x.clone();
        streaming_softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let row = &y[r * 3..(r + 1) * 3];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
            let s: f32 = exps.iter().sum();
            for c in 0..3 {
                assert!((x[r * 3 + c] - exps[c] / s).abs() < 1e-6);
                assert!(x[r * 3 + c].is_finite());
            }
            let rowsum: f32 = x[r * 3..(r + 1) * 3].iter().sum();
            assert!((rowsum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn attention_ref_rejects_invalid_geometry() {
        // Runtime layer: the reference (and attention_dynamic, which
        // validates through the same TensorProgram::attention door)
        // refuses geometry the program layer rejects.
        let buf = vec![0f32; 64];
        let r = std::panic::catch_unwind(|| {
            attention_host_ref(&buf, &buf, &buf, (1, 4), (7, 2))
        });
        assert!(r.is_err(), "heads not dividing d must not run");
        let r = std::panic::catch_unwind(|| {
            attention_host_ref(&buf, &buf, &buf, (1, 0), (8, 2))
        });
        assert!(r.is_err(), "zero seq must not run");
    }
}
