//! Real execution runtime: the PJRT side of the three-layer stack.
//!
//! Loads the AOT artifacts (`artifacts/*.hlo.txt` + `manifest.json`)
//! produced once by `python/compile/aot.py`, compiles them on the PJRT
//! CPU client (`xla` crate), and exposes the *kernel constructor*
//! execution path: a dynamic-shape GEMM is served by padding to the
//! selected micro-kernel's block, looping the launch grid, and chaining
//! the `gemm_acc` block executable over K super-blocks — the runtime
//! stage of the paper realized with real binaries. Python is never on
//! this path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::ir::{ceil_div, DType};
use crate::util::json::Json;

/// Tensor I/O spec recorded by aot.py for every artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact (a static-shape compiled computation).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub params: Json,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactEntry {
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key)?.as_usize()
    }

    /// (bm, bn, bk) for gemm-family artifacts.
    pub fn block(&self) -> Option<[usize; 3]> {
        Some([
            self.param_usize("bm")?,
            self.param_usize("bn")?,
            self.param_usize("bk")?,
        ])
    }

    pub fn in_dtype(&self) -> DType {
        self.params
            .get("in_dtype")
            .and_then(|v| v.as_str())
            .and_then(DType::parse)
            .unwrap_or(DType::F32)
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

fn parse_io(v: &Json) -> Option<Vec<IoSpec>> {
    v.as_arr()?
        .iter()
        .map(|io| {
            Some(IoSpec {
                shape: io
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Option<Vec<_>>>()?,
                dtype: io.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {:?} (run `make artifacts`)", path))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {}", path.display(), e))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(|e| {
                Some(ArtifactEntry {
                    name: e.get("name")?.as_str()?.to_string(),
                    kind: e.get("kind")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    params: e.get("params")?.clone(),
                    inputs: parse_io(e.get("inputs")?)?,
                    outputs: parse_io(e.get("outputs")?)?,
                })
            })
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("malformed manifest entry"))?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All gemm_acc blocks of a dtype, as (block, artifact name).
    pub fn gemm_acc_blocks(&self, dtype: DType) -> Vec<([usize; 3], String)> {
        self.entries
            .iter()
            .filter(|e| e.kind == "gemm_acc" && e.in_dtype() == dtype)
            .filter_map(|e| Some((e.block()?, e.name.clone())))
            .collect()
    }
}

/// The real engine: PJRT CPU client + lazily compiled executables.
pub struct RealEngine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl RealEngine {
    pub fn load(artifacts_dir: &Path) -> Result<RealEngine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(RealEngine { client, manifest, exes: RefCell::new(HashMap::new()) })
    }

    /// Compile (once) and return the executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?;
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    /// Build a literal of `dtype` with the given dims from f32 host data.
    fn literal(&self, data: &[f32], dims: &[i64], dtype: DType) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data).reshape(dims)?;
        match dtype {
            DType::F32 => Ok(lit),
            DType::Bf16 => Ok(lit.convert(xla::PrimitiveType::Bf16)?),
            DType::F16 => Ok(lit.convert(xla::PrimitiveType::F16)?),
        }
    }

    fn spec_dtype(spec: &IoSpec) -> DType {
        match spec.dtype.as_str() {
            "bfloat16" | "bf16" => DType::Bf16,
            "float16" | "f16" => DType::F16,
            _ => DType::F32,
        }
    }

    /// Run a 1-output artifact on f32 host buffers; returns f32 data.
    /// Inputs are converted to each declared input dtype.
    pub fn run_raw(&self, name: &str, inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                name,
                entry.inputs.len(),
                inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let lits = inputs
            .iter()
            .zip(entry.inputs.iter())
            .map(|((data, dims), spec)| self.literal(data, dims, Self::spec_dtype(spec)))
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = if result.shape()?.is_tuple() {
            result.to_tuple1()?
        } else {
            result
        };
        let out = match out.ty()? {
            xla::ElementType::F32 => out,
            _ => out.convert(xla::PrimitiveType::F32)?,
        };
        Ok(out.to_vec::<f32>()?)
    }

    /// Dynamic-shape GEMM via the kernel constructor: pad to the block,
    /// loop the grid, chain `gemm_acc` over K super-blocks (paper §6.2).
    ///
    /// `a` is row-major (m x k), `b` is (k x n); returns row-major
    /// (m x n) f32.
    ///
    /// §Perf fast path (f32): A/B blocks are uploaded to device buffers
    /// once and reused across the grid (B blocks are hit `gm` times),
    /// the accumulator stays device-resident across the K chain (the
    /// untupled output buffer feeds the next call directly), and a
    /// single shared zero buffer seeds every (M, N) block.
    pub fn gemm_dynamic(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        if dtype != DType::F32 {
            return self.gemm_dynamic_literal(a, b, (m, n, k), block, dtype);
        }
        let [bm, bn, bk] = block;
        let name = format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name());
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let exe = self.executable(&name)?;
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));

        // Pre-upload B blocks: indexed [ki][ni], reused for every mi.
        let mut b_blk = vec![0f32; bk * bn];
        let mut b_bufs: Vec<Vec<xla::PjRtBuffer>> = Vec::with_capacity(gk);
        for ki in 0..gk {
            let k0 = ki * bk;
            let kdep = bk.min(k - k0);
            let mut row = Vec::with_capacity(gn);
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                if kdep < bk || ncols < bn {
                    b_blk.iter_mut().for_each(|x| *x = 0.0);
                }
                for r in 0..kdep {
                    let src = (k0 + r) * n + n0;
                    b_blk[r * bn..r * bn + ncols].copy_from_slice(&b[src..src + ncols]);
                }
                row.push(self.client.buffer_from_host_buffer(&b_blk, &[bk, bn], None)?);
            }
            b_bufs.push(row);
        }

        let zeros = vec![0f32; bm * bn];
        let zero_buf = self.client.buffer_from_host_buffer(&zeros, &[bm, bn], None)?;
        let mut a_blk = vec![0f32; bm * bk];
        let mut out = vec![0f32; m * n];
        for mi in 0..gm {
            let m0 = mi * bm;
            let mrows = bm.min(m - m0);
            // Upload this row's A blocks once; reused for every ni.
            let mut a_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(gk);
            for ki in 0..gk {
                let k0 = ki * bk;
                let kdep = bk.min(k - k0);
                if kdep < bk || mrows < bm {
                    a_blk.iter_mut().for_each(|x| *x = 0.0);
                }
                for r in 0..mrows {
                    let src = (m0 + r) * k + k0;
                    a_blk[r * bk..r * bk + kdep].copy_from_slice(&a[src..src + kdep]);
                }
                a_bufs.push(self.client.buffer_from_host_buffer(&a_blk, &[bm, bk], None)?);
            }
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                // Device-resident accumulator chain over K.
                let mut c_buf: Option<xla::PjRtBuffer> = None;
                for ki in 0..gk {
                    let c_in = c_buf.as_ref().unwrap_or(&zero_buf);
                    let mut res =
                        exe.execute_b(&[&a_bufs[ki], &b_bufs[ki][ni], c_in])?;
                    c_buf = Some(res.swap_remove(0).swap_remove(0));
                }
                let lit = c_buf.unwrap().to_literal_sync()?;
                let c_blk = lit.to_vec::<f32>()?;
                for r in 0..mrows {
                    let dst = (m0 + r) * n + n0;
                    out[dst..dst + ncols]
                        .copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Literal-based reference path (all dtypes); also the baseline for
    /// the §Perf before/after comparison.
    pub fn gemm_dynamic_literal(
        &self,
        a: &[f32],
        b: &[f32],
        (m, n, k): (usize, usize, usize),
        block: [usize; 3],
        dtype: DType,
    ) -> Result<Vec<f32>> {
        let [bm, bn, bk] = block;
        let name = format!("gemm_acc_{}x{}x{}_{}", bm, bn, bk, dtype.name());
        if self.manifest.find(&name).is_none() {
            bail!("no artifact for block {:?} {}", block, dtype.name());
        }
        let (gm, gn, gk) = (ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk));
        let mut out = vec![0f32; m * n];
        let mut a_blk = vec![0f32; bm * bk];
        let mut b_blk = vec![0f32; bk * bn];
        let zeros = vec![0f32; bm * bn];
        for mi in 0..gm {
            let m0 = mi * bm;
            let mrows = bm.min(m - m0);
            for ni in 0..gn {
                let n0 = ni * bn;
                let ncols = bn.min(n - n0);
                let mut c_blk = zeros.clone();
                for ki in 0..gk {
                    let k0 = ki * bk;
                    let kdep = bk.min(k - k0);
                    // Gather A block (zero-padded).
                    a_blk.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..mrows {
                        let src = (m0 + r) * k + k0;
                        a_blk[r * bk..r * bk + kdep]
                            .copy_from_slice(&a[src..src + kdep]);
                    }
                    // Gather B block (zero-padded).
                    b_blk.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..kdep {
                        let src = (k0 + r) * n + n0;
                        b_blk[r * bn..r * bn + ncols]
                            .copy_from_slice(&b[src..src + ncols]);
                    }
                    c_blk = self.run_raw(
                        &name,
                        &[
                            (&a_blk, vec![bm as i64, bk as i64]),
                            (&b_blk, vec![bk as i64, bn as i64]),
                            (&c_blk, vec![bm as i64, bn as i64]),
                        ],
                    )?;
                }
                // Scatter C block (crop padding).
                for r in 0..mrows {
                    let dst = (m0 + r) * n + n0;
                    out[dst..dst + ncols]
                        .copy_from_slice(&c_blk[r * bn..r * bn + ncols]);
                }
            }
        }
        Ok(out)
    }

    /// Wall-clock one artifact launch (min over `reps`), seconds.
    /// This is the real-testbed empirical L0/L1 profiling primitive.
    pub fn time_artifact(&self, name: &str, reps: usize) -> Result<f64> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {} not in manifest", name))?
            .clone();
        let bufs: Vec<(Vec<f32>, Vec<i64>)> = entry
            .inputs
            .iter()
            .map(|spec| {
                let n: usize = spec.shape.iter().product();
                (
                    vec![0.1f32; n.max(1)],
                    spec.shape.iter().map(|&d| d as i64).collect(),
                )
            })
            .collect();
        let refs: Vec<(&[f32], Vec<i64>)> =
            bufs.iter().map(|(d, s)| (d.as_slice(), s.clone())).collect();
        // Warm-up (compiles on first use).
        self.run_raw(&entry.name, &refs)?;
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            self.run_raw(&entry.name, &refs)?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok(best)
    }
}

/// Build the real-testbed micro-kernel library: every `gemm_acc` block
/// in the manifest is wall-clock profiled (`reps` launches, min taken)
/// — this is the empirical half of the hybrid analyzer running on real
/// hardware instead of the simulator. The L0 tile is the Pallas inner
/// tile (tm, tn, tk) recorded by aot.py.
pub fn build_real_library(
    engine: &RealEngine,
    hw: &crate::hw::HwSpec,
    dtype: DType,
    reps: usize,
) -> Result<crate::compiler::MicroKernelLibrary> {
    use crate::compiler::{MicroKernel, MicroKernelLibrary};
    use crate::ir::{OpKind, Tile};
    let backend_name = match dtype {
        DType::F32 => "mxu_f32",
        _ => "mxu_bf16",
    };
    let backend = hw
        .backend_idx(backend_name)
        .ok_or_else(|| anyhow!("hw {} lacks backend {}", hw.name, backend_name))?;
    let mut kernels = Vec::new();
    for (block, name) in engine.manifest.gemm_acc_blocks(dtype) {
        let entry = engine.manifest.find(&name).unwrap();
        let l0 = Tile::from3([
            entry.param_usize("tm").unwrap_or(8),
            entry.param_usize("tn").unwrap_or(128),
            entry.param_usize("tk").unwrap_or(128),
        ]);
        let base_cost = engine.time_artifact(&name, reps)?;
        kernels.push(MicroKernel { l0, l1: Tile::from3(block), backend, base_cost });
    }
    if kernels.is_empty() {
        bail!("manifest has no gemm_acc blocks for {}", dtype.name());
    }
    kernels.sort_by(|a, b| (a.l1, a.l0).cmp(&(b.l1, b.l0)));
    Ok(MicroKernelLibrary {
        hw_name: hw.name.to_string(),
        op: OpKind::Gemm,
        dtype,
        analyzer: crate::cost::hybrid::AnalyzerConfig::empirical(1),
        kernels,
    })
}

/// Dynamic-shape convolution on the real engine via implicit GEMM:
/// im2col in Rust (the data-layout half Vortex folds into the rKernel
/// recursion, §4.2) + the dynamic GEMM kernel constructor for compute.
///
/// `x` is NHWC row-major (n, h, w, cin); `w` is (kh, kw, cin, cout);
/// valid padding, stride 1. Returns NHWC (n, oh, ow, cout) f32.
pub fn conv2d_dynamic(
    engine: &RealEngine,
    selector: &crate::coordinator::Selector,
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
) -> Result<Vec<f32>> {
    if h < kh || wd < kw {
        bail!("feature map {}x{} smaller than filter {}x{}", h, wd, kh, kw);
    }
    let (oh, ow) = (h - kh + 1, wd - kw + 1);
    let (m, kdim) = (n * oh * ow, kh * kw * cin);
    // im2col patch matrix: row (b, oy, ox) -> taps in (i, j, c) order,
    // matching the filter reshaped as (kh*kw*cin, cout) row-major.
    let mut patches = vec![0f32; m * kdim];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = ((b * oh + oy) * ow + ox) * kdim;
                for i in 0..kh {
                    // one contiguous (kw * cin)-wide slab per filter row
                    let src = ((b * h + oy + i) * wd + ox) * cin;
                    let dst = row + i * kw * cin;
                    patches[dst..dst + kw * cin]
                        .copy_from_slice(&x[src..src + kw * cin]);
                }
            }
        }
    }
    // Select through the SAME op-aware selector as every other op: the
    // conv program's IterSpace goes straight in, and the selector
    // resolves it against a conv library or the implicit-GEMM fallback
    // (no conv-specific selection side path here).
    let program = crate::ir::TensorProgram::Conv2d {
        n,
        h,
        w: wd,
        cin,
        cout,
        kh,
        kw,
        dtype: DType::F32,
    };
    let space = program.space();
    debug_assert_eq!(space.dims.to3(), [m, cout, kdim]);
    let sel = selector
        .select(space, crate::coordinator::HwMode::Adaptive)
        .ok_or_else(|| anyhow!("no kernel for conv space {:?}", space))?;
    let kern = selector.kernel(&sel);
    engine.gemm_dynamic(&patches, w, (m, cout, kdim), kern.l1.to3(), DType::F32)
}

/// Reference row-major triple-loop GEMM for verification in tests.
pub fn gemm_host_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            let row = l * n;
            let out = i * n;
            for j in 0..n {
                c[out + j] += av * b[row + j];
            }
        }
    }
    c
}

/// Reference direct NHWC valid convolution (for verification).
pub fn conv2d_host_ref(
    x: &[f32],
    w: &[f32],
    (n, h, wd, cin): (usize, usize, usize, usize),
    (kh, kw, cout): (usize, usize, usize),
) -> Vec<f32> {
    let (oh, ow) = (h - kh + 1, wd - kw + 1);
    let mut out = vec![0f32; n * oh * ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((b * oh + oy) * ow + ox) * cout;
                for i in 0..kh {
                    for j in 0..kw {
                        let src = ((b * h + oy + i) * wd + ox + j) * cin;
                        for ci in 0..cin {
                            let xv = x[src + ci];
                            let wrow = ((i * kw + j) * cin + ci) * cout;
                            for co in 0..cout {
                                out[dst + co] += xv * w[wrow + co];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_ref_gemm_known_values() {
        // [[1,2],[3,4]] @ I = same matrix
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(gemm_host_ref(&a, &b, 2, 2, 2), a);
    }

    #[test]
    fn manifest_parse_rejects_garbage() {
        let dir = std::env::temp_dir().join("vortex_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"entries\": [{}]}").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
