//! Analytical cost model (paper §5.2, Eqs. 2–4) and the hybrid
//! analytical–empirical analyzer.
//!
//! A *strategy* is a chain of tiles over one operator's iteration space
//! ([`crate::ir::OpSpec`]), one tile per hierarchy level, innermost
//! first: `[t0, t1, tN]` where `tN` is the (padded) problem shape. The
//! model recurses bottom-up:
//!
//! ```text
//! T_temporal(L) = T_load + (|TemporalLoop|-1) * max(T_load, Cost_{L-1})
//!                 + Cost_{L-1} + T_store                       (Eq. 2)
//! F_parallel(L) = ceil(|ParallelLoop| / |HardwareUnit(L)|)     (Eq. 3)
//! Cost(L)       = F_parallel(L) * T_temporal(L)                (Eq. 4)
//! ```
//!
//! Loop extents and per-step traffic come from the op: batch + spatial
//! axes feed the parallel loop (Eq. 3), the reduction axis feeds the
//! temporal loop (Eq. 2), and the op's operand formulas give the
//! load/store bytes. At level 0 the recursion bottoms out in the ISA
//! instruction stream (MMA / FMA / pallas dot), costed from the
//! backend's per-unit peak. The double-buffered pipeline shape of Eq. 2
//! (next load overlapping current compute) is exactly what the `max()`
//! expresses.

pub mod hybrid;

use crate::hw::{Backend, HwSpec};
use crate::ir::{ceil_div, DType, OpKind, Tile};

/// A full strategy chain: `tiles[l]` is the op-axes tile at level l;
/// `tiles[last]` is the padded problem shape. All levels use `backend`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Strategy {
    pub op: OpKind,
    pub tiles: Vec<Tile>,
    pub backend: usize,
}

impl Strategy {
    /// Contraction-view (GEMM) convenience constructor — the historical
    /// `[m, n, k]` chain shape used by the baselines and benches.
    pub fn new(tiles: Vec<[usize; 3]>, backend: usize) -> Strategy {
        Strategy::for_op(
            OpKind::Gemm,
            tiles.into_iter().map(Tile::from3).collect(),
            backend,
        )
    }

    pub fn for_op(op: OpKind, tiles: Vec<Tile>, backend: usize) -> Strategy {
        debug_assert!(tiles.iter().all(|t| t.rank() == op.spec().rank()));
        Strategy { op, tiles, backend }
    }

    /// Integer-multiple nesting sanity check (levels need not divide the
    /// top problem shape — the constructor pads there — but offline
    /// levels must nest exactly).
    pub fn is_nested(&self) -> bool {
        self.tiles.windows(2).all(|w| w[1].is_multiple_of(w[0]))
    }
}

/// Cost model output, seconds. `per_level_secs[l]` is Cost(L) of the
/// recursion truncated at level l (used by Fig. 14's breakdown).
#[derive(Debug, Clone)]
pub struct CostReport {
    pub total_secs: f64,
    pub per_level_secs: Vec<f64>,
}

/// Level-0 compute cost: the tile's FLOPs at the backend's per-L0-unit
/// peak, padded up to the op-lifted ISA granularity (MMA-shape padding,
/// §6.2; batch axes have granularity 1). The FLOP count comes from the
/// op — a fused chain ([`crate::ir::FusedAttention`]) counts every
/// constituent kernel's contraction.
pub fn l0_compute_secs(
    hw: &HwSpec,
    backend: &Backend,
    op: OpKind,
    tile: Tile,
) -> f64 {
    let spec = op.spec();
    let isa = spec.isa_tile(backend.isa);
    let mut padded = tile;
    for i in 0..tile.rank() {
        padded[i] = ceil_div(tile[i].max(1), isa[i]) * isa[i];
    }
    spec.flops(padded) / (backend.peak_per_l0_unit(hw) * 1e9)
}

/// Evaluate Eqs. 2–4 for a strategy on a hardware target.
///
/// `l0_override`: measured level-0 cost from the empirical profiler —
/// the hybrid analyzer passes `Some(secs)` for chains whose innermost
/// tile has been profiled, replacing the analytical bottom (§5.2).
pub fn cost(
    hw: &HwSpec,
    dtype: DType,
    strat: &Strategy,
    l0_override: Option<f64>,
) -> CostReport {
    debug_assert!(strat.is_nested(), "strategy tiles must nest: {:?}", strat);
    let backend = &hw.backends[strat.backend];
    let spec = strat.op.spec();
    let mut per_level = Vec::with_capacity(strat.tiles.len());

    // Level 0: instruction stream, fragment loads pipelined with issue.
    let cost_below = match l0_override {
        Some(secs) => secs,
        None => {
            let t0 = strat.tiles[0];
            // Operand fragments of one full L0 traversal.
            let frag_bytes = spec.load_bytes_per_step(t0, t0, dtype);
            let t_load = frag_bytes / (hw.level(0).load_bw_gbps * 1e9);
            let compute = l0_compute_secs(hw, backend, strat.op, t0);
            compute.max(t_load)
        }
    };
    per_level.push(cost_below);
    let report = cost_from(hw, dtype, strat, 1, cost_below);
    per_level.extend(report.per_level_secs);
    CostReport { total_secs: report.total_secs.max(cost_below), per_level_secs: per_level }
}

/// Continue the Eq. 2–4 recursion from `start_level`, given the cost of
/// the fully-nested subchain below it (`cost_below`). Used by the hybrid
/// analyzer to splice empirically-measured subchain costs into the
/// analytical upper levels (§5.2).
pub fn cost_from(
    hw: &HwSpec,
    dtype: DType,
    strat: &Strategy,
    start_level: usize,
    mut cost_below: f64,
) -> CostReport {
    let spec = strat.op.spec();
    let mut per_level = Vec::with_capacity(strat.tiles.len() - start_level);
    for l in start_level..strat.tiles.len() {
        let parent = strat.tiles[l];
        let child = strat.tiles[l - 1];
        // Batch + spatial child iterations are parallel over this
        // level's child units; reduction iterations are temporal.
        let spatial_iters = spec.spatial_iters(parent, child);
        let reduce_iters = spec.reduce_iters(parent, child);
        let units = hw.level(l - 1).unit_count as usize;

        let bw = hw.level(l).load_bw_gbps * 1e9;
        let t_load = spec.load_bytes_per_step(parent, child, dtype) / bw;
        let t_store = spec.store_bytes(parent) / bw;

        // Eq. 3: parallel amplification (batch/spatial tiles over units).
        let f_parallel = ceil_div(spatial_iters, units) as f64;

        // Eq. 2 over the reduction (temporal) loop.
        let n_t = reduce_iters.max(1) as f64;
        let t_temporal =
            t_load + (n_t - 1.0) * t_load.max(cost_below) + cost_below + t_store;

        // Eq. 4.
        cost_below = f_parallel * t_temporal;
        per_level.push(cost_below);
    }
    CostReport { total_secs: cost_below, per_level_secs: per_level }
}

/// Simple whole-problem roofline: max(compute-bound, memory-bound),
/// with FLOPs and minimum DRAM traffic supplied by the op.
pub fn roofline_secs(
    hw: &HwSpec,
    backend: &Backend,
    space: impl Into<crate::ir::IterSpace>,
) -> f64 {
    let space = space.into();
    let compute = space.flops() / (backend.peak_gflops * 1e9);
    let top = hw.levels.last().unwrap();
    let memory = space.min_bytes() / (top.load_bw_gbps * 1e9);
    compute.max(memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::Contraction;

    fn a100_tc_strategy(problem: [usize; 3]) -> (HwSpec, Strategy) {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        (hw, Strategy::new(vec![[16, 8, 16], [64, 64, 32], problem], bi))
    }

    fn batched_strategy(hw: &HwSpec, b: usize, problem: [usize; 3]) -> Strategy {
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        Strategy::for_op(
            OpKind::BatchedGemm,
            vec![
                Tile::new(&[1, 16, 8, 16]),
                Tile::new(&[1, 64, 64, 32]),
                Tile::new(&[b, problem[0], problem[1], problem[2]]),
            ],
            bi,
        )
    }

    #[test]
    fn nesting_check() {
        let (_, s) = a100_tc_strategy([1024, 1024, 1024]);
        assert!(s.is_nested());
        let bad = Strategy::new(vec![[16, 8, 16], [60, 64, 32]], 0);
        assert!(!bad.is_nested());
    }

    #[test]
    fn cost_is_positive_and_monotonic_in_problem_size() {
        let (hw, s1) = a100_tc_strategy([512, 512, 512]);
        let (_, s2) = a100_tc_strategy([2048, 2048, 2048]);
        let c1 = cost(&hw, DType::F16, &s1, None).total_secs;
        let c2 = cost(&hw, DType::F16, &s2, None).total_secs;
        assert!(c1 > 0.0);
        assert!(c2 > 8.0 * c1, "64x flops should be >8x cost: {} vs {}", c1, c2);
    }

    #[test]
    fn cost_never_beats_roofline_badly() {
        // The model includes load/store overheads, so it must be at
        // least ~half the roofline for a balanced large GEMM.
        let (hw, s) = a100_tc_strategy([4096, 4096, 4096]);
        let backend = &hw.backends[s.backend];
        let rl = roofline_secs(
            &hw,
            backend,
            Contraction { m: 4096, n: 4096, k: 4096, dtype: DType::F16 },
        );
        let c = cost(&hw, DType::F16, &s, None).total_secs;
        assert!(c >= rl * 0.5, "model {} vs roofline {}", c, rl);
    }

    #[test]
    fn l0_override_replaces_bottom() {
        let (hw, s) = a100_tc_strategy([512, 512, 512]);
        let base = cost(&hw, DType::F16, &s, None);
        let forced = cost(&hw, DType::F16, &s, Some(base.per_level_secs[0] * 10.0));
        assert!(forced.total_secs > base.total_secs);
        assert_eq!(forced.per_level_secs[0], base.per_level_secs[0] * 10.0);
    }

    #[test]
    fn parallel_amplification_quantizes() {
        // 109 rows of CTA tiles on 108 SMs must cost ~2x of 108 (Eq. 3).
        let hw = presets::a100();
        let bi = hw.backend_idx("cuda_core_f32").unwrap();
        let mk_strat = |grid_m: usize| {
            Strategy::new(vec![[8, 8, 8], [64, 64, 64], [64 * grid_m, 64, 64]], bi)
        };
        let c108 = cost(&hw, DType::F32, &mk_strat(108), None).total_secs;
        let c109 = cost(&hw, DType::F32, &mk_strat(109), None).total_secs;
        assert!(c109 > 1.8 * c108, "{} vs {}", c108, c109);
    }

    #[test]
    fn isa_padding_penalizes_misaligned_l0() {
        let hw = presets::a100();
        let tc = hw.backend("tensor_core_f16").unwrap();
        let aligned =
            l0_compute_secs(&hw, tc, OpKind::Gemm, Tile::from3([16, 8, 16]));
        let misaligned =
            l0_compute_secs(&hw, tc, OpKind::Gemm, Tile::from3([17, 9, 17]));
        assert!(misaligned > 4.0 * aligned);
    }

    #[test]
    fn per_level_costs_accumulate() {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let s = Strategy::new(vec![[16, 8, 16], [128, 128, 32], [1024, 1024, 4096]], bi);
        let c = cost(&hw, DType::F16, &s, None);
        assert_eq!(c.per_level_secs.len(), 3);
        assert!(c.per_level_secs[2] >= c.per_level_secs[1]);
        assert_eq!(c.per_level_secs[2], c.total_secs);
    }

    #[test]
    fn attention_chain_beats_two_dispatches_and_pays_for_both_kernels() {
        // The fusion claim, as cost-model assertions: the fused chain
        // prices BELOW its two contraction dispatches run separately
        // (the score tile never round-trips through the L1 store), yet
        // in a compute-bound (deep-reduction) regime it prices ABOVE a
        // single batched GEMM — both kernels' flops are really there.
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let tiles = vec![
            Tile::new(&[1, 16, 8, 16]),
            Tile::new(&[1, 64, 64, 32]),
            Tile::new(&[12, 512, 512, 64]),
        ];
        // The context contraction is the (b, m, k, n) transpose.
        let swap = |t: &Tile| Tile::new(&[t[0], t[1], t[3], t[2]]);
        let tiles_t: Vec<Tile> = tiles.iter().map(swap).collect();
        let at = Strategy::for_op(OpKind::FusedAttention, tiles.clone(), bi);
        let score = Strategy::for_op(OpKind::BatchedGemm, tiles, bi);
        let ctx = Strategy::for_op(OpKind::BatchedGemm, tiles_t, bi);
        let c_at = cost(&hw, DType::F16, &at, None).total_secs;
        let c_score = cost(&hw, DType::F16, &score, None).total_secs;
        let c_ctx = cost(&hw, DType::F16, &ctx, None).total_secs;
        assert!(c_at > 0.0 && c_at.is_finite());
        assert!(
            c_at < c_score + c_ctx,
            "fused {} !< separate {} + {}",
            c_at,
            c_score,
            c_ctx
        );
        // Deep reduction: compute dominates, so the chain's doubled
        // flops must show up as a higher cost than one batched GEMM.
        let deep = vec![
            Tile::new(&[1, 16, 8, 16]),
            Tile::new(&[1, 64, 64, 64]),
            Tile::new(&[12, 512, 512, 512]),
        ];
        let at_deep = Strategy::for_op(OpKind::FusedAttention, deep.clone(), bi);
        let bg_deep = Strategy::for_op(OpKind::BatchedGemm, deep, bi);
        let ca = cost(&hw, DType::F16, &at_deep, None).total_secs;
        let cb = cost(&hw, DType::F16, &bg_deep, None).total_secs;
        assert!(ca > cb, "deep-k fused {} !> single gemm {}", ca, cb);
        // ISA padding at L0 counts both kernels too.
        let tc = hw.backend("tensor_core_f16").unwrap();
        let t0 = Tile::new(&[1, 16, 8, 16]);
        let l0_at = l0_compute_secs(&hw, tc, OpKind::FusedAttention, t0);
        let l0_bg = l0_compute_secs(&hw, tc, OpKind::BatchedGemm, t0);
        assert_eq!(l0_at, 2.0 * l0_bg);
    }

    #[test]
    fn batched_gemm_costs_like_batch_of_gemms() {
        // A batch-1 batched strategy must price identically to the same
        // GEMM chain (the op abstraction adds no phantom cost), and a
        // batch-B problem over a batch-1 tile must cost more than one
        // batch (Eq. 3 amplification over the batch axis).
        let hw = presets::a100();
        let s1 = batched_strategy(&hw, 1, [1024, 1024, 512]);
        let bi = s1.backend;
        let g = Strategy::new(vec![[16, 8, 16], [64, 64, 32], [1024, 1024, 512]], bi);
        let c_b1 = cost(&hw, DType::F16, &s1, None).total_secs;
        let c_g = cost(&hw, DType::F16, &g, None).total_secs;
        assert!((c_b1 - c_g).abs() < 1e-12 * c_g, "{} vs {}", c_b1, c_g);
        let c_b8 = cost(&hw, DType::F16, &batched_strategy(&hw, 8, [1024, 1024, 512]), None)
            .total_secs;
        assert!(c_b8 > 4.0 * c_b1, "{} !> 4x {}", c_b8, c_b1);
    }
}
