//! Analytical cost model (paper §5.2, Eqs. 2–4) and the hybrid
//! analytical–empirical analyzer.
//!
//! A *strategy* is a chain of tiles, one per hierarchy level, innermost
//! first: `[t0, t1, tN]` where `tN` is the (padded) problem shape. The
//! model recurses bottom-up:
//!
//! ```text
//! T_temporal(L) = T_load + (|TemporalLoop|-1) * max(T_load, Cost_{L-1})
//!                 + Cost_{L-1} + T_store                       (Eq. 2)
//! F_parallel(L) = ceil(|ParallelLoop| / |HardwareUnit(L)|)     (Eq. 3)
//! Cost(L)       = F_parallel(L) * T_temporal(L)                (Eq. 4)
//! ```
//!
//! At level 0 the recursion bottoms out in the ISA instruction stream
//! (MMA / FMA / pallas dot), costed from the backend's per-unit peak.
//! The double-buffered pipeline shape of Eq. 2 (next load overlapping
//! current compute) is exactly what the `max()` expresses.

pub mod hybrid;

use crate::hw::{Backend, HwSpec};
use crate::ir::{ceil_div, DType};

/// A full strategy chain: `tiles[l]` is the (m, n, k) tile at level l;
/// `tiles[last]` is the padded problem shape. All levels use `backend`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Strategy {
    pub tiles: Vec<[usize; 3]>,
    pub backend: usize,
}

impl Strategy {
    pub fn new(tiles: Vec<[usize; 3]>, backend: usize) -> Strategy {
        Strategy { tiles, backend }
    }

    /// Integer-multiple nesting sanity check (levels need not divide the
    /// top problem shape — the constructor pads there — but offline
    /// levels must nest exactly).
    pub fn is_nested(&self) -> bool {
        self.tiles.windows(2).all(|w| {
            w[0].iter().zip(w[1].iter()).all(|(&c, &p)| c > 0 && p % c == 0)
        })
    }
}

/// Cost model output, seconds. `per_level_secs[l]` is Cost(L) of the
/// recursion truncated at level l (used by Fig. 14's breakdown).
#[derive(Debug, Clone)]
pub struct CostReport {
    pub total_secs: f64,
    pub per_level_secs: Vec<f64>,
}

/// Level-0 compute cost: the tile's FLOPs at the backend's per-L0-unit
/// peak, padded up to ISA granularity (MMA-shape padding, §6.2).
pub fn l0_compute_secs(hw: &HwSpec, backend: &Backend, tile: [usize; 3]) -> f64 {
    let padded: f64 = tile
        .iter()
        .zip(backend.isa.iter())
        .map(|(&t, &g)| (ceil_div(t.max(1), g) * g) as f64)
        .product();
    let flops = 2.0 * padded;
    flops / (backend.peak_per_l0_unit(hw) * 1e9)
}

/// Bytes loaded per reduction step at a level: the A and B slabs of the
/// child-k extent across the parent's spatial extent.
fn load_bytes_per_step(parent: [usize; 3], child_k: usize, dtype: DType) -> f64 {
    let [m, n, _] = parent;
    ((m * child_k + child_k * n) * dtype.bytes()) as f64
}

/// Store bytes at a level: the C tile written back once (f32 acc).
fn store_bytes(parent: [usize; 3]) -> f64 {
    (parent[0] * parent[1] * 4) as f64
}

/// Evaluate Eqs. 2–4 for a strategy on a hardware target.
///
/// `l0_override`: measured level-0 cost from the empirical profiler —
/// the hybrid analyzer passes `Some(secs)` for chains whose innermost
/// tile has been profiled, replacing the analytical bottom (§5.2).
pub fn cost(
    hw: &HwSpec,
    dtype: DType,
    strat: &Strategy,
    l0_override: Option<f64>,
) -> CostReport {
    debug_assert!(strat.is_nested(), "strategy tiles must nest: {:?}", strat);
    let backend = &hw.backends[strat.backend];
    let mut per_level = Vec::with_capacity(strat.tiles.len());

    // Level 0: instruction stream, fragment loads pipelined with issue.
    let cost_below = match l0_override {
        Some(secs) => secs,
        None => {
            let t0 = strat.tiles[0];
            let frag_bytes =
                ((t0[0] * t0[2] + t0[2] * t0[1]) * dtype.bytes()) as f64;
            let t_load = frag_bytes / (hw.level(0).load_bw_gbps * 1e9);
            let compute = l0_compute_secs(hw, backend, t0);
            compute.max(t_load)
        }
    };
    per_level.push(cost_below);
    let report = cost_from(hw, dtype, strat, 1, cost_below);
    per_level.extend(report.per_level_secs);
    CostReport { total_secs: report.total_secs.max(cost_below), per_level_secs: per_level }
}

/// Continue the Eq. 2–4 recursion from `start_level`, given the cost of
/// the fully-nested subchain below it (`cost_below`). Used by the hybrid
/// analyzer to splice empirically-measured subchain costs into the
/// analytical upper levels (§5.2).
pub fn cost_from(
    hw: &HwSpec,
    dtype: DType,
    strat: &Strategy,
    start_level: usize,
    mut cost_below: f64,
) -> CostReport {
    let mut per_level = Vec::with_capacity(strat.tiles.len() - start_level);
    for l in start_level..strat.tiles.len() {
        let parent = strat.tiles[l];
        let child = strat.tiles[l - 1];
        // Contraction view: spatial child iterations are parallel over
        // this level's child units; reduction iterations are temporal.
        let spatial_iters =
            ceil_div(parent[0], child[0]) * ceil_div(parent[1], child[1]);
        let reduce_iters = ceil_div(parent[2], child[2]);
        let units = hw.level(l - 1).unit_count as usize;

        let bw = hw.level(l).load_bw_gbps * 1e9;
        let t_load = load_bytes_per_step(parent, child[2], dtype) / bw;
        let t_store = store_bytes(parent) / bw;

        // Eq. 3: parallel amplification (spatial tiles over units).
        let f_parallel = ceil_div(spatial_iters, units) as f64;

        // Eq. 2 over the reduction (temporal) loop.
        let n_t = reduce_iters.max(1) as f64;
        let t_temporal =
            t_load + (n_t - 1.0) * t_load.max(cost_below) + cost_below + t_store;

        // Eq. 4.
        cost_below = f_parallel * t_temporal;
        per_level.push(cost_below);
    }
    CostReport { total_secs: cost_below, per_level_secs: per_level }
}

/// Simple whole-problem roofline: max(compute-bound, memory-bound).
pub fn roofline_secs(hw: &HwSpec, backend: &Backend, c: crate::ir::Contraction) -> f64 {
    let compute = c.flops() / (backend.peak_gflops * 1e9);
    let top = hw.levels.last().unwrap();
    let memory = c.min_bytes() / (top.load_bw_gbps * 1e9);
    compute.max(memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::ir::Contraction;

    fn a100_tc_strategy(problem: [usize; 3]) -> (HwSpec, Strategy) {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        (hw, Strategy::new(vec![[16, 8, 16], [64, 64, 32], problem], bi))
    }

    #[test]
    fn nesting_check() {
        let (_, s) = a100_tc_strategy([1024, 1024, 1024]);
        assert!(s.is_nested());
        let bad = Strategy::new(vec![[16, 8, 16], [60, 64, 32]], 0);
        assert!(!bad.is_nested());
    }

    #[test]
    fn cost_is_positive_and_monotonic_in_problem_size() {
        let (hw, s1) = a100_tc_strategy([512, 512, 512]);
        let (_, s2) = a100_tc_strategy([2048, 2048, 2048]);
        let c1 = cost(&hw, DType::F16, &s1, None).total_secs;
        let c2 = cost(&hw, DType::F16, &s2, None).total_secs;
        assert!(c1 > 0.0);
        assert!(c2 > 8.0 * c1, "64x flops should be >8x cost: {} vs {}", c1, c2);
    }

    #[test]
    fn cost_never_beats_roofline_badly() {
        // The model includes load/store overheads, so it must be at
        // least ~half the roofline for a balanced large GEMM.
        let (hw, s) = a100_tc_strategy([4096, 4096, 4096]);
        let backend = &hw.backends[s.backend];
        let rl = roofline_secs(
            &hw,
            backend,
            Contraction { m: 4096, n: 4096, k: 4096, dtype: DType::F16 },
        );
        let c = cost(&hw, DType::F16, &s, None).total_secs;
        assert!(c >= rl * 0.5, "model {} vs roofline {}", c, rl);
    }

    #[test]
    fn l0_override_replaces_bottom() {
        let (hw, s) = a100_tc_strategy([512, 512, 512]);
        let base = cost(&hw, DType::F16, &s, None);
        let forced = cost(&hw, DType::F16, &s, Some(base.per_level_secs[0] * 10.0));
        assert!(forced.total_secs > base.total_secs);
        assert_eq!(forced.per_level_secs[0], base.per_level_secs[0] * 10.0);
    }

    #[test]
    fn parallel_amplification_quantizes() {
        // 109 rows of CTA tiles on 108 SMs must cost ~2x of 108 (Eq. 3).
        let hw = presets::a100();
        let bi = hw.backend_idx("cuda_core_f32").unwrap();
        let mk_strat = |grid_m: usize| {
            Strategy::new(vec![[8, 8, 8], [64, 64, 64], [64 * grid_m, 64, 64]], bi)
        };
        let c108 = cost(&hw, DType::F32, &mk_strat(108), None).total_secs;
        let c109 = cost(&hw, DType::F32, &mk_strat(109), None).total_secs;
        assert!(c109 > 1.8 * c108, "{} vs {}", c108, c109);
    }

    #[test]
    fn isa_padding_penalizes_misaligned_l0() {
        let hw = presets::a100();
        let tc = hw.backend("tensor_core_f16").unwrap();
        let aligned = l0_compute_secs(&hw, tc, [16, 8, 16]);
        let misaligned = l0_compute_secs(&hw, tc, [17, 9, 17]);
        assert!(misaligned > 4.0 * aligned);
    }

    #[test]
    fn per_level_costs_accumulate() {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let s = Strategy::new(vec![[16, 8, 16], [128, 128, 32], [1024, 1024, 4096]], bi);
        let c = cost(&hw, DType::F16, &s, None);
        assert_eq!(c.per_level_secs.len(), 3);
        assert!(c.per_level_secs[2] >= c.per_level_secs[1]);
        assert_eq!(c.per_level_secs[2], c.total_secs);
    }
}
