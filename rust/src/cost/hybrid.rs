//! Hybrid analytical–empirical analyzer (paper §5.2).
//!
//! Empirical profiling is applied at the configured low levels (default:
//! L0 on CPU, L0+L1 on GPU — Table 7's "Default" rows) and the Eq. 2–4
//! analytical recursion continues above the measured subchain. All
//! *runtime* queries hit the offline-built measurement cache plus the
//! analytical top — "all runtime analyses are conducted using the
//! analytical model" — so selection latency stays microseconds.

use crate::cost::{self, Strategy};
use crate::hw::HwSpec;
use crate::ir::{AnalyzeType, DType};
use crate::profiler::Profiler;

/// Which levels use empirical measurement. Must be a contiguous prefix
/// {0..=e}; the paper only ever profiles the bottom of the chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AnalyzerConfig {
    /// Highest empirically-profiled level, or None for fully analytical.
    pub empirical_up_to: Option<usize>,
}

impl AnalyzerConfig {
    /// Paper defaults (Table 7): CPU profiles L0; GPU profiles L0+L1.
    pub fn default_for(hw: &HwSpec) -> AnalyzerConfig {
        match hw.name {
            "a100" => AnalyzerConfig { empirical_up_to: Some(1) },
            "xeon_8255c" => AnalyzerConfig { empirical_up_to: Some(0) },
            // Real testbed: the AOT micro-kernel (the L1 block) is what
            // we can wall-clock, so profile through L1.
            _ => AnalyzerConfig { empirical_up_to: Some(1) },
        }
    }

    pub fn analytical_only() -> AnalyzerConfig {
        AnalyzerConfig { empirical_up_to: None }
    }

    pub fn empirical(levels: usize) -> AnalyzerConfig {
        AnalyzerConfig { empirical_up_to: Some(levels) }
    }

    pub fn analyze_type(&self, level: usize) -> AnalyzeType {
        match self.empirical_up_to {
            Some(e) if level <= e => AnalyzeType::Empirical,
            _ => AnalyzeType::Analytical,
        }
    }

    /// Short display form matching Table 7 ("E: L0", "E: L0, L1", "-").
    pub fn label(&self) -> String {
        match self.empirical_up_to {
            None => "-".to_string(),
            Some(e) => {
                let lv: Vec<String> = (0..=e).map(|l| format!("L{}", l)).collect();
                format!("E: {}", lv.join(", "))
            }
        }
    }

    /// Strict inverse of [`label`](Self::label). Empirical levels must
    /// be the contiguous prefix "L0, L1, ..."; anything else is `None`
    /// (the library loader refuses to guess at unknown analyzers).
    pub fn parse_label(s: &str) -> Option<AnalyzerConfig> {
        if s == "-" {
            return Some(AnalyzerConfig::analytical_only());
        }
        let rest = s.strip_prefix("E: ")?;
        let mut expect = 0usize;
        for part in rest.split(", ") {
            let n: usize = part.strip_prefix('L')?.parse().ok()?;
            if n != expect {
                return None;
            }
            expect += 1;
        }
        if expect == 0 {
            None
        } else {
            Some(AnalyzerConfig::empirical(expect - 1))
        }
    }

    /// Filesystem-safe form for library-cache file names.
    pub fn slug(&self) -> String {
        match self.empirical_up_to {
            None => "analytical".to_string(),
            Some(e) => format!("e{}", e),
        }
    }
}

/// Estimate the cost of a full strategy chain under the hybrid scheme.
///
/// The profiler is consulted for the subchain up to
/// `cfg.empirical_up_to`; Eq. 2–4 run analytically above it.
pub fn hybrid_cost(
    hw: &HwSpec,
    dtype: DType,
    strat: &Strategy,
    cfg: &AnalyzerConfig,
    profiler: &mut dyn Profiler,
) -> f64 {
    match cfg.empirical_up_to {
        None => cost::cost(hw, dtype, strat, None).total_secs,
        Some(e) => {
            let e = e.min(strat.tiles.len() - 1).min(1);
            let base = profiler.measure_subchain(dtype, strat, e);
            cost::cost_from(hw, dtype, strat, e + 1, base).total_secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;

    fn setup() -> (HwSpec, SimProfiler, Strategy) {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let strat =
            Strategy::new(vec![[16, 8, 16], [64, 64, 32], [1024, 1024, 1024]], bi);
        let prof = SimProfiler::new(Simulator::new(hw.clone(), 11));
        (hw, prof, strat)
    }

    #[test]
    fn defaults_match_table7() {
        assert_eq!(AnalyzerConfig::default_for(&presets::a100()).label(), "E: L0, L1");
        assert_eq!(
            AnalyzerConfig::default_for(&presets::xeon_8255c()).label(),
            "E: L0"
        );
        assert_eq!(AnalyzerConfig::analytical_only().label(), "-");
    }

    #[test]
    fn label_parse_round_trip_and_strictness() {
        for cfg in [
            AnalyzerConfig::analytical_only(),
            AnalyzerConfig::empirical(0),
            AnalyzerConfig::empirical(1),
            AnalyzerConfig::empirical(2),
        ] {
            assert_eq!(AnalyzerConfig::parse_label(&cfg.label()), Some(cfg));
        }
        for bad in ["", "E: ", "E: L1", "E: L0, L2", "E: L0,L1", "empirical", "E: X0"] {
            assert_eq!(AnalyzerConfig::parse_label(bad), None, "{:?}", bad);
        }
        assert_eq!(AnalyzerConfig::empirical(1).slug(), "e1");
        assert_eq!(AnalyzerConfig::analytical_only().slug(), "analytical");
    }

    #[test]
    fn analyze_type_prefix() {
        let cfg = AnalyzerConfig::empirical(1);
        assert_eq!(cfg.analyze_type(0), AnalyzeType::Empirical);
        assert_eq!(cfg.analyze_type(1), AnalyzeType::Empirical);
        assert_eq!(cfg.analyze_type(2), AnalyzeType::Analytical);
    }

    #[test]
    fn hybrid_tracks_simulator_better_than_analytical() {
        // Across many chains, |hybrid - true| must beat |analytic - true|
        // on average — this is the entire point of §5.2.
        let (hw, mut prof, _) = setup();
        let sim = Simulator::new(hw.clone(), 11);
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let cfg = AnalyzerConfig::empirical(1);
        let (mut err_h, mut err_a, mut n) = (0.0, 0.0, 0);
        for &l1 in &[[32usize, 32, 32], [64, 64, 32], [128, 64, 32], [64, 128, 16]] {
            let s = Strategy::new(vec![[16, 8, 16], l1, [1024, 1024, 512]], bi);
            let truth = sim.execute(DType::F16, &s);
            let h = hybrid_cost(&hw, DType::F16, &s, &cfg, &mut prof);
            let a = cost::cost(&hw, DType::F16, &s, None).total_secs;
            err_h += ((h - truth) / truth).abs();
            err_a += ((a - truth) / truth).abs();
            n += 1;
        }
        assert!(
            err_h / n as f64 <= err_a / n as f64,
            "hybrid {} !<= analytic {}",
            err_h,
            err_a
        );
    }

    #[test]
    fn analytical_only_never_profiles() {
        let (hw, mut prof, strat) = setup();
        let cfg = AnalyzerConfig::analytical_only();
        hybrid_cost(&hw, DType::F16, &strat, &cfg, &mut prof);
        assert_eq!(prof.queries(), 0);
    }

    #[test]
    fn empirical_issues_queries_once() {
        let (hw, mut prof, strat) = setup();
        let cfg = AnalyzerConfig::empirical(0);
        hybrid_cost(&hw, DType::F16, &strat, &cfg, &mut prof);
        hybrid_cost(&hw, DType::F16, &strat, &cfg, &mut prof);
        assert_eq!(prof.queries(), 1, "cache must absorb the second call");
    }
}
