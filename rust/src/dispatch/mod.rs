//! Offline shape-space partitioning: compile-time dispatch tables that
//! replace per-request selection scans (the sample-free endgame of the
//! paper's runtime stage).
//!
//! Vortex's headline claim is that the shape→kernel decision is a pure
//! function of hardware structure — nothing about it needs to be
//! *discovered* at serve time. The serving layer's plan cache
//! ([`crate::serve::PlanCache`]) already proved the key fact: the
//! selection argmin depends on the runtime shape ONLY through the
//! per-axis launch grids `ceil(dim / extent)` under the serving op's
//! distinct L1 extents. This module turns that observation from a
//! memoization key into an *enumeration*: at compile time, each axis
//! is partitioned into intervals whose boundaries are the L1-extent
//! multiples up to a configurable horizon, the winning `(lib, kernel)`
//! is recorded per cell of the resulting lattice, and adjacent
//! intervals whose winner hyperplanes coincide are merged back into
//! regions. The shipped [`DispatchTable`] then answers any in-horizon
//! runtime shape in `O(axes · log intervals)` — zero warm-up, no cold
//! misses, and **provably identical plans to fresh selection**.
//!
//! ## Soundness
//!
//! Within one cell, every candidate kernel sees the same launch grid
//! (the cell boundaries include every multiple of every distinct L1
//! extent on the axis, so no kernel's `ceil(dim / l1)` can change
//! inside it), hence the same padded problem, traffic terms, launch
//! count and estimate — the argmin is constant, and it is computed
//! with the *same* [`FastKernel` arithmetic and tie-break
//! order](crate::coordinator::Selector::select_plan) the online scan
//! uses, including the alias-chain scaling (`chain_kernels()`), so a
//! table answer is bit-identical to a fresh scan. Alias-served ops
//! (Conv2d → Gemm, GroupedConv2d / FusedAttention → BatchedGemm) route
//! through the same [`Selector::serving_op`] fixpoint: there is no
//! side path. Region merging only coalesces intervals whose recorded
//! winner slices are equal, and a lookup reconstructs the `Selection`
//! from `(kernel, actual dims)` — never from a representative — so
//! padded shape, grid and estimate stay exact after merging.
//!
//! ## Horizon fallback
//!
//! Shapes with any dim beyond the effective horizon return `None` from
//! [`DispatchTable::select`]; the serving layer demotes the PR 4 plan
//! cache to exactly this beyond-horizon tail (tri-state accounting:
//! table / cache / fresh). A cell budget ([`DispatchConfig::max_cells`])
//! bounds table construction: when the requested horizons would exceed
//! it, the widest axis is halved until the lattice fits (recorded as
//! `clamped` in [`BuildStats`]), trading coverage — never correctness.

use std::collections::HashMap;
use std::time::Instant;

use crate::analysis::Diagnostic;
use crate::coordinator::select::{HwMode, Selection, Selector};
use crate::ir::{ceil_div, AxisRole, IterSpace, OpKind, Tile};
use crate::util::json::Json;
use crate::util::rng::hash_key;

/// Offline partitioning configuration: how far out each axis is
/// enumerated before the live-selection fallback takes over.
#[derive(Debug, Clone)]
pub struct DispatchConfig {
    /// Default per-axis horizon for spatial / reduction axes.
    pub horizon: usize,
    /// Default horizon for batch-role axes (batched GEMM batch, conv
    /// groups, attention head groups) — typically far smaller than the
    /// spatial extents.
    pub batch_horizon: usize,
    /// Per-op horizon overrides (full per-axis vectors, rank-matched):
    /// the deployment's advertised shape envelope.
    pub per_op: Vec<(OpKind, Vec<usize>)>,
    /// Requested ops to enumerate tables for; empty means every op in
    /// [`OpKind::ALL`].
    pub ops: Vec<OpKind>,
    /// Backend modes to enumerate tables for.
    pub modes: Vec<HwMode>,
    /// Per-table cell budget: horizons are halved (widest axis first)
    /// until the lattice fits. Bounds offline build time and table
    /// size, never correctness.
    pub max_cells: usize,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        DispatchConfig {
            horizon: 256,
            batch_horizon: 32,
            per_op: Vec::new(),
            ops: Vec::new(),
            modes: vec![HwMode::Adaptive],
            max_cells: 1 << 20,
        }
    }
}

impl DispatchConfig {
    /// The configured horizon vector for one op (override or
    /// role-derived defaults). Panics on a rank-mismatched override —
    /// a config bug must fail loudly, not truncate axes.
    pub fn horizons_for(&self, op: OpKind) -> Vec<usize> {
        if let Some((_, h)) = self.per_op.iter().find(|(o, _)| *o == op) {
            assert_eq!(
                h.len(),
                op.spec().rank(),
                "horizon override for {} must have one entry per axis",
                op
            );
            return h.clone();
        }
        op.spec()
            .axes()
            .iter()
            .map(|a| {
                if a.role == AxisRole::Batch {
                    self.batch_horizon
                } else {
                    self.horizon
                }
            })
            .collect()
    }

    /// Builder-style per-op horizon override. Panics unless `horizons`
    /// has exactly one entry per axis of `op`'s iteration space.
    pub fn with_op_horizons(mut self, op: OpKind, horizons: &[usize]) -> Self {
        assert_eq!(
            horizons.len(),
            op.spec().rank(),
            "horizon override for {} must have one entry per axis",
            op
        );
        self.per_op.retain(|(o, _)| *o != op);
        self.per_op.push((op, horizons.to_vec()));
        self
    }
}

/// One (requested op, mode) table: per-axis interval upper edges and
/// the row-major winner lattice (indices into the selector's fast
/// path, so reconstruction shares the scan's exact arithmetic).
/// `pub(crate)` so the plan auditor ([`crate::analysis`]) can re-prove
/// every cell's argmin (and its seeded-corruption tests can tamper
/// with edges and winners in place).
#[derive(Debug, Clone)]
pub(crate) struct OpTable {
    pub(crate) op: OpKind,
    pub(crate) mode: HwMode,
    /// Per-axis strictly-increasing interval upper edges (inclusive);
    /// `edges[a].last()` is the effective horizon of axis `a`.
    pub(crate) edges: Vec<Vec<usize>>,
    /// Row-major winners (axis 0 outermost): index into
    /// `Selector::fast`.
    pub(crate) winners: Vec<u32>,
    pub(crate) clamped: bool,
}

/// Offline build statistics.
#[derive(Debug, Clone, Default)]
pub struct BuildStats {
    /// (op, mode) tables built (ops with no servable kernels skipped).
    pub tables: usize,
    /// Lattice cells enumerated before region merging.
    pub cells_enumerated: usize,
    /// Cells stored after region merging.
    pub cells: usize,
    /// Wall-clock of the whole build.
    pub build_secs: f64,
    /// True when any table's horizons were halved to fit `max_cells`.
    pub clamped: bool,
    /// Per-(op, mode) build telemetry, in build order (the
    /// "dispatch_table" spans of [`crate::obs::compile_trace`]).
    pub per_table: Vec<TableBuildStat>,
}

/// Build telemetry for ONE (op, mode) table: how many lattice cells
/// were enumerated, how many survived region merging, and the
/// wall-clock of that table's build.
#[derive(Debug, Clone)]
pub struct TableBuildStat {
    pub op: OpKind,
    /// Mode label ("adaptive" or the pinned backend's name).
    pub mode: String,
    pub cells_enumerated: usize,
    /// Cells merged away (`cells_enumerated - cells_stored`).
    pub cells_merged: usize,
    pub build_secs: f64,
}

/// The compile-time dispatch table: one [`OpTable`] per (requested op,
/// mode) with at least one servable kernel. Like
/// [`crate::serve::PlanCache`], a table is built FOR one selector —
/// [`DispatchTable::fingerprint`] records that selector's identity and
/// [`DispatchTable::from_data`] refuses to adopt serialized tables
/// built for a different one.
#[derive(Debug, Clone)]
pub struct DispatchTable {
    pub(crate) tables: Vec<OpTable>,
    fingerprint: u64,
    pub stats: BuildStats,
}

/// Fingerprint of everything a table answer depends on: the hardware
/// spec contents (including the per-launch overhead) and every loaded
/// library's identity — op, dtype, kernel tiles, backends and base
/// costs, in load order (the scan's tie-break order).
pub fn selector_fingerprint(selector: &Selector) -> u64 {
    let hw = &selector.hw;
    let mut parts: Vec<u64> = vec![hw.launch_overhead_secs.to_bits()];
    for l in &hw.levels {
        parts.push(l.capacity_bytes);
        parts.push(l.load_bw_gbps.to_bits());
        parts.push(l.unit_count as u64);
    }
    for b in &hw.backends {
        parts.push(b.peak_gflops.to_bits());
        parts.extend(b.isa.iter().map(|&x| x as u64));
        parts.push(b.dtype_bytes as u64);
        parts.push(b.launch_factor.to_bits());
    }
    parts.push(hw.is_real_testbed() as u64);
    for lib in &selector.libraries {
        parts.push(lib.op as u64);
        parts.push(lib.dtype as u64);
        for k in &lib.kernels {
            parts.extend(k.l0.dims().iter().map(|&d| d as u64));
            parts.extend(k.l1.dims().iter().map(|&d| d as u64));
            parts.push(k.backend as u64);
            parts.push(k.base_cost.to_bits());
        }
    }
    hash_key(&parts)
}

/// Interval upper edges of one axis: every multiple of every distinct
/// L1 extent below the horizon, plus the horizon itself. Between two
/// consecutive edges no kernel's `ceil(dim / extent)` can change, so
/// the selection argmin is constant per interval (see module docs).
pub(crate) fn axis_edges(extents: &[usize], horizon: usize) -> Vec<usize> {
    let mut edges: Vec<usize> = Vec::new();
    for &e in extents {
        let mut m = e;
        while m < horizon {
            edges.push(m);
            m += e;
        }
    }
    edges.push(horizon.max(1));
    edges.sort_unstable();
    edges.dedup();
    edges
}

/// Per-kernel evaluation grid: the kernel's chain-scaled estimates at
/// every distinct launch grid the table lattice can produce, plus the
/// per-axis map from table interval to estimate index contribution.
struct KernelGrid {
    /// Index into `Selector::fast` (the tie-break identity).
    fast_idx: u32,
    /// `contrib[a][i]` = (kernel-grid position of table interval `i`
    /// on axis `a`) × (kernel-lattice stride of axis `a`).
    contrib: Vec<Vec<usize>>,
    /// Row-major chain-scaled estimates over the kernel's own lattice.
    est: Vec<f64>,
}

fn build_kernel_grid(
    selector: &Selector,
    fast_idx: usize,
    edges: &[Vec<usize>],
    chain: f64,
) -> KernelGrid {
    let fk = &selector.fast[fast_idx];
    let rank = edges.len();
    // Distinct kernel-grid coordinates per axis (non-decreasing over
    // the sorted edges) and each interval's position among them.
    let mut gvals: Vec<Vec<usize>> = Vec::with_capacity(rank);
    let mut pos: Vec<Vec<usize>> = Vec::with_capacity(rank);
    for a in 0..rank {
        let mut g: Vec<usize> = Vec::new();
        let mut p = Vec::with_capacity(edges[a].len());
        for &d in &edges[a] {
            let gv = ceil_div(d, fk.l1[a]);
            if g.last() != Some(&gv) {
                g.push(gv);
            }
            p.push(g.len() - 1);
        }
        gvals.push(g);
        pos.push(p);
    }
    let mut kstride = vec![1usize; rank];
    for a in (0..rank - 1).rev() {
        kstride[a] = kstride[a + 1] * gvals[a + 1].len();
    }
    let kcells: usize = gvals.iter().map(Vec::len).product();
    let mut est = vec![0f64; kcells];
    let mut digits = vec![0usize; rank];
    for e in est.iter_mut() {
        let mut dims = Tile::ones(rank);
        for a in 0..rank {
            // A representative shape with exactly this launch grid:
            // the padded problem itself.
            dims[a] = gvals[a][digits[a]] * fk.l1[a];
        }
        *e = fk.estimate(dims).0 * chain;
        for a in (0..rank).rev() {
            digits[a] += 1;
            if digits[a] < gvals[a].len() {
                break;
            }
            digits[a] = 0;
        }
    }
    let contrib: Vec<Vec<usize>> = (0..rank)
        .map(|a| pos[a].iter().map(|&p| p * kstride[a]).collect())
        .collect();
    KernelGrid { fast_idx: fast_idx as u32, contrib, est }
}

/// Below this lattice size one kernel's whole cell pass is cheaper
/// than spawning a thread scope for it.
const PARALLEL_CELL_THRESHOLD: usize = 1 << 14;

/// Stream one kernel over a contiguous range of table cells starting
/// at flat index `start`: decode the start into per-axis digits, then
/// advance an odometer, updating the running argmin (`best`/`winners`)
/// with a strict `<` so the first kernel keeps ties. Shared by the
/// sequential and per-chunk-threaded build paths.
fn cell_pass(
    kg: &KernelGrid,
    edges: &[Vec<usize>],
    stride: &[usize],
    start: usize,
    best: &mut [f64],
    winners: &mut [u32],
) {
    let rank = edges.len();
    let mut digits = vec![0usize; rank];
    let mut rem = start;
    for a in 0..rank {
        digits[a] = rem / stride[a];
        rem %= stride[a];
    }
    let mut kidx: usize = (0..rank).map(|a| kg.contrib[a][digits[a]]).sum();
    for (b, w) in best.iter_mut().zip(winners.iter_mut()) {
        let secs = kg.est[kidx];
        if secs < *b {
            *b = secs;
            *w = kg.fast_idx;
        }
        for a in (0..rank).rev() {
            let old = kg.contrib[a][digits[a]];
            digits[a] += 1;
            if digits[a] < edges[a].len() {
                kidx = kidx - old + kg.contrib[a][digits[a]];
                break;
            }
            digits[a] = 0;
            kidx = kidx - old + kg.contrib[a][0];
        }
    }
}

/// Enumerate the winner lattice for one (op, mode): for every cell,
/// the first strict argmin over the eligible kernels in fast-path
/// order — the same comparison, order and chain scaling as
/// [`Selector::select_plan`].
fn build_op_table(
    selector: &Selector,
    op: OpKind,
    mode: HwMode,
    cfg: &DispatchConfig,
) -> Option<(OpTable, usize)> {
    let serving = selector.serving_op(op);
    let chain = selector.chain_factor(op);
    let eligible = selector.eligible_fast(serving, mode);
    if eligible.is_empty() {
        return None;
    }
    let rank = op.spec().rank();
    let mut horizons = cfg.horizons_for(op);
    debug_assert_eq!(horizons.len(), rank);
    let mut extents: Vec<Vec<usize>> = vec![Vec::new(); rank];
    for &i in &eligible {
        let l1 = selector.fast[i].l1;
        for (a, ex) in extents.iter_mut().enumerate() {
            if !ex.contains(&l1[a]) {
                ex.push(l1[a]);
            }
        }
    }
    let mut edges: Vec<Vec<usize>> = extents
        .iter()
        .zip(&horizons)
        .map(|(ex, &h)| axis_edges(ex, h))
        .collect();
    // Cell budget: halve the widest axis until the lattice fits.
    let mut clamped = false;
    loop {
        let cells: usize = edges.iter().map(Vec::len).product();
        if cells <= cfg.max_cells.max(1) {
            break;
        }
        let widest = (0..rank).max_by_key(|&a| edges[a].len()).unwrap();
        if horizons[widest] <= 1 {
            break; // every axis already minimal
        }
        horizons[widest] = (horizons[widest] / 2).max(1);
        edges[widest] = axis_edges(&extents[widest], horizons[widest]);
        clamped = true;
    }
    let n_cells: usize = edges.iter().map(Vec::len).product();
    let mut stride = vec![1usize; rank];
    for a in (0..rank - 1).rev() {
        stride[a] = stride[a + 1] * edges[a + 1].len();
    }

    let mut best = vec![f64::INFINITY; n_cells];
    let mut winners = vec![0u32; n_cells];
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 16);
    let chunk = n_cells.div_ceil(threads).max(1);
    // Kernel-outer streaming: ONE kernel's evaluation grid is alive at
    // a time (its lattice is at most the table lattice, so peak memory
    // is O(n_cells), never O(n_cells × kernels)), and each kernel's
    // cell pass fans out across threads over disjoint winner chunks —
    // but only when the lattice is big enough to amortize the spawns
    // (small tables would otherwise pay a scope per kernel for ns of
    // compare work). Kernels run in fast-path order with a strict `<`
    // update, so the per-cell result is the first strict argmin —
    // exactly `select_plan`'s tie-break.
    let parallel = threads > 1 && n_cells >= PARALLEL_CELL_THRESHOLD;
    for &fi in &eligible {
        let kg = build_kernel_grid(selector, fi, &edges, chain);
        if !parallel {
            cell_pass(&kg, &edges, &stride, 0, &mut best, &mut winners);
            continue;
        }
        std::thread::scope(|s| {
            let kg = &kg;
            let edges = &edges;
            let stride = &stride;
            let handles: Vec<_> = best
                .chunks_mut(chunk)
                .zip(winners.chunks_mut(chunk))
                .enumerate()
                .map(|(ci, (bc, wc))| {
                    s.spawn(move || cell_pass(kg, edges, stride, ci * chunk, bc, wc))
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    let mut table = OpTable { op, mode, edges, winners, clamped };
    merge_regions(&mut table);
    Some((table, n_cells))
}

/// Region merging: collapse adjacent intervals whose winner
/// hyperplanes are identical, per axis, to a fixpoint. Lookups are
/// unchanged — a merged interval's winner is the winner of every cell
/// it covers — while storage shrinks to the argmin's actual region
/// structure.
fn merge_regions(t: &mut OpTable) {
    loop {
        let mut changed = false;
        for axis in 0..t.edges.len() {
            changed |= merge_axis(t, axis);
        }
        if !changed {
            break;
        }
    }
}

fn merge_axis(t: &mut OpTable, axis: usize) -> bool {
    let dims: Vec<usize> = t.edges.iter().map(Vec::len).collect();
    let n = dims[axis];
    if n <= 1 {
        return false;
    }
    // Row-major: `block` cells per interval of `axis` within one outer
    // block; `super_stride` cells per full sweep of the axis.
    let block: usize = dims[axis + 1..].iter().product();
    let super_stride = block * n;
    let outers = t.winners.len() / super_stride;
    let same = |i: usize, j: usize| -> bool {
        (0..outers).all(|o| {
            let bi = o * super_stride + i * block;
            let bj = o * super_stride + j * block;
            t.winners[bi..bi + block] == t.winners[bj..bj + block]
        })
    };
    // Runs of identical consecutive slices become one region keeping
    // the run's LAST upper edge.
    let mut reps: Vec<usize> = Vec::new();
    let mut new_edges: Vec<usize> = Vec::new();
    let mut i = 0;
    while i < n {
        let rep = i;
        let mut j = i + 1;
        while j < n && same(j, rep) {
            j += 1;
        }
        reps.push(rep);
        new_edges.push(t.edges[axis][j - 1]);
        i = j;
    }
    if reps.len() == n {
        return false;
    }
    let mut new_winners = Vec::with_capacity(outers * reps.len() * block);
    for o in 0..outers {
        for &r in &reps {
            let b = o * super_stride + r * block;
            new_winners.extend_from_slice(&t.winners[b..b + block]);
        }
    }
    t.winners = new_winners;
    t.edges[axis] = new_edges;
    true
}

impl DispatchTable {
    /// Build the full dispatch table for one selector: every op in
    /// [`OpKind::ALL`] × every configured mode with at least one
    /// servable kernel (through the measurement-alias fixpoint).
    pub fn for_selector(selector: &Selector, cfg: &DispatchConfig) -> DispatchTable {
        let t0 = Instant::now();
        let mut tables = Vec::new();
        let mut stats = BuildStats::default();
        let ops: Vec<OpKind> = if cfg.ops.is_empty() {
            OpKind::ALL.to_vec()
        } else {
            cfg.ops.clone()
        };
        for op in ops {
            for &mode in &cfg.modes {
                let t_op = Instant::now();
                if let Some((t, enumerated)) = build_op_table(selector, op, mode, cfg) {
                    stats.tables += 1;
                    stats.cells_enumerated += enumerated;
                    stats.cells += t.winners.len();
                    stats.clamped |= t.clamped;
                    stats.per_table.push(TableBuildStat {
                        op,
                        mode: mode_name(mode),
                        cells_enumerated: enumerated,
                        cells_merged: enumerated - t.winners.len(),
                        build_secs: t_op.elapsed().as_secs_f64(),
                    });
                    tables.push(t);
                }
            }
        }
        stats.build_secs = t0.elapsed().as_secs_f64();
        DispatchTable { tables, fingerprint: selector_fingerprint(selector), stats }
    }

    /// The selector identity this table was built for.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// True when this table was built for (a selector identical to)
    /// `selector` — the precondition of [`DispatchTable::select`].
    pub fn matches(&self, selector: &Selector) -> bool {
        self.fingerprint == selector_fingerprint(selector)
    }

    fn table_for(&self, op: OpKind, mode: HwMode) -> Option<&OpTable> {
        self.tables.iter().find(|t| t.op == op && t.mode == mode)
    }

    /// True when the table answers this (space, mode): a table exists
    /// for the op and every dim is within the effective horizon.
    pub fn covers(&self, space: IterSpace, mode: HwMode) -> bool {
        match self.table_for(space.op, mode) {
            None => false,
            Some(t) => space
                .dims
                .dims()
                .iter()
                .zip(&t.edges)
                .all(|(&d, e)| d <= *e.last().unwrap()),
        }
    }

    /// Effective per-axis horizons of one (op, mode) table, if built.
    pub fn horizons(&self, op: OpKind, mode: HwMode) -> Option<Vec<usize>> {
        self.table_for(op, mode)
            .map(|t| t.edges.iter().map(|e| *e.last().unwrap()).collect())
    }

    /// Compile-time dispatch: `O(axes · log intervals)` interval
    /// lookup plus ONE kernel evaluation at the actual dims — returns
    /// a plan identical to `selector.select(space, mode)` in every
    /// field except `select_secs` (which reports the lookup
    /// wall-clock). `None` when the space is beyond the horizon or no
    /// table serves the (op, mode) — the caller falls back to live
    /// selection.
    pub fn select(
        &self,
        selector: &Selector,
        space: IterSpace,
        mode: HwMode,
    ) -> Option<Selection> {
        let t0 = Instant::now();
        let t = self.table_for(space.op, mode)?;
        debug_assert_eq!(t.edges.len(), space.dims.rank());
        let mut flat = 0usize;
        for (&d, e) in space.dims.dims().iter().zip(&t.edges) {
            let idx = e.partition_point(|&edge| edge < d);
            if idx == e.len() {
                return None; // beyond the horizon: live-selection fallback
            }
            flat = flat * e.len() + idx;
        }
        let chain = selector.chain_factor(space.op);
        let mut sel = selector.selection_from(t.winners[flat] as usize, space.dims, chain);
        sel.select_secs = t0.elapsed().as_secs_f64();
        Some(sel)
    }

    /// Serialize every table to the schema-v3 payload, keyed by the
    /// build selector's fingerprint.
    pub fn to_data(&self, selector: &Selector) -> Vec<TableData> {
        self.tables
            .iter()
            .map(|t| {
                let mut runs: Vec<(usize, usize, usize)> = Vec::new();
                for &w in &t.winners {
                    let fk = &selector.fast[w as usize];
                    match runs.last_mut() {
                        Some((n, lib, kernel)) if *lib == fk.lib && *kernel == fk.kernel => {
                            *n += 1
                        }
                        _ => runs.push((1, fk.lib, fk.kernel)),
                    }
                }
                let mode = mode_name(t.mode);
                let digest = table_digest(t.op, &mode, &t.edges, &runs, t.clamped);
                TableData {
                    op: t.op,
                    mode,
                    edges: t.edges.clone(),
                    runs,
                    clamped: t.clamped,
                    fingerprint: self.fingerprint,
                    digest,
                }
            })
            .collect()
    }

    /// Adopt serialized tables for `selector`. Returns `None` when the
    /// fingerprint does not match the selector (tables built for a
    /// different hardware spec or library set), when a mode names an
    /// unknown backend, or when any lattice is malformed — never a
    /// silently-wrong table. Thin wrapper over
    /// [`DispatchTable::from_data_checked`] for callers that only need
    /// the yes/no answer.
    pub fn from_data(selector: &Selector, data: &[TableData]) -> Option<DispatchTable> {
        DispatchTable::from_data_checked(selector, data).ok()
    }

    /// Strict adoption with a context-rich refusal: every rejection is
    /// a structured [`Diagnostic`] naming the payload index and, once
    /// parsed, the (op, mode) — the same diagnostic currency as the
    /// plan auditor, so CLI and serving surfaces print one vocabulary.
    pub fn from_data_checked(
        selector: &Selector,
        data: &[TableData],
    ) -> Result<DispatchTable, Diagnostic> {
        let fingerprint = selector_fingerprint(selector);
        // (lib, kernel) → fast index.
        let by_pair: HashMap<(usize, usize), u32> = selector
            .fast
            .iter()
            .enumerate()
            .map(|(i, fk)| ((fk.lib, fk.kernel), i as u32))
            .collect();
        let mut tables = Vec::with_capacity(data.len());
        let mut stats = BuildStats::default();
        for (di, d) in data.iter().enumerate() {
            let reject = |code: &'static str, msg: String| {
                Err(Diagnostic::error(code, msg)
                    .with_op(d.op)
                    .with_mode(&d.mode)
                    .with_entry(format!("table #{di}")))
            };
            if d.fingerprint != fingerprint {
                return reject(
                    "load.fingerprint_mismatch",
                    format!(
                        "payload fingerprint {:#018x} was built for a different \
                         selector than {fingerprint:#018x}",
                        d.fingerprint
                    ),
                );
            }
            // Content integrity: any corruption of edges / runs /
            // clamped since `to_data` is refused, never served.
            if d.digest != table_digest(d.op, &d.mode, &d.edges, &d.runs, d.clamped) {
                return reject(
                    "load.digest_mismatch",
                    "content digest does not match the stored edges/runs".to_string(),
                );
            }
            let Some(mode) = parse_mode(&d.mode, selector) else {
                return reject(
                    "load.unknown_mode",
                    format!("mode {:?} names no backend of this hardware spec", d.mode),
                );
            };
            if d.edges.len() != d.op.spec().rank() {
                return reject(
                    "load.rank_mismatch",
                    format!(
                        "{} edge axes for a rank-{} op",
                        d.edges.len(),
                        d.op.spec().rank()
                    ),
                );
            }
            for (a, e) in d.edges.iter().enumerate() {
                if e.is_empty() || e.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(Diagnostic::error(
                        "load.bad_edges",
                        "empty or non-increasing edge vector".to_string(),
                    )
                    .with_op(d.op)
                    .with_mode(&d.mode)
                    .with_axis(a)
                    .with_entry(format!("table #{di}")));
                }
            }
            // Checked product: adversarial edge arrays must not
            // overflow (or allocate) their way past the strict loader.
            let Some(n_cells) = d
                .edges
                .iter()
                .try_fold(1usize, |acc, e| acc.checked_mul(e.len()))
            else {
                return reject(
                    "load.cell_overflow",
                    "per-axis interval counts overflow the cell lattice".to_string(),
                );
            };
            let serving = selector.serving_op(d.op);
            let mut winners = Vec::with_capacity(n_cells);
            for (ri, &(n, lib, kernel)) in d.runs.iter().enumerate() {
                let Some(&fi) = by_pair.get(&(lib, kernel)) else {
                    return Err(Diagnostic::error(
                        "load.unknown_kernel",
                        format!("run #{ri} names (lib {lib}, kernel {kernel}), not loaded"),
                    )
                    .with_op(d.op)
                    .with_mode(&d.mode)
                    .with_kernel(lib, kernel)
                    .with_entry(format!("table #{di}")));
                };
                // Every winner must be a kernel the online scan could
                // have picked for this (op, mode): right serving op
                // (also pins the tile rank) and an admitted backend.
                // The fingerprint pins the selector; this pins the
                // payload — a tampered file is refused, never served.
                let fk = &selector.fast[fi as usize];
                if fk.op != serving || !selector.mode_admits(fk, mode) {
                    return Err(Diagnostic::error(
                        "load.ineligible_winner",
                        format!(
                            "run #{ri} winner (lib {lib}, kernel {kernel}) cannot \
                             serve {} in this mode",
                            d.op
                        ),
                    )
                    .with_op(d.op)
                    .with_mode(&d.mode)
                    .with_kernel(lib, kernel)
                    .with_entry(format!("table #{di}")));
                }
                // Bound each run BEFORE materializing it: a corrupt
                // run length must fail, not OOM (subtraction order
                // keeps the check overflow-proof for huge `n`).
                if n == 0 || n > n_cells - winners.len() {
                    return reject(
                        "load.bad_run_length",
                        format!(
                            "run #{ri} length {n} with {} of {n_cells} cells filled",
                            winners.len()
                        ),
                    );
                }
                winners.extend(std::iter::repeat_n(fi, n));
            }
            if winners.len() != n_cells {
                return reject(
                    "load.cell_count_mismatch",
                    format!("runs fill {} of {n_cells} cells", winners.len()),
                );
            }
            stats.tables += 1;
            stats.cells += n_cells;
            stats.clamped |= d.clamped;
            tables.push(OpTable {
                op: d.op,
                mode,
                edges: d.edges.clone(),
                winners,
                clamped: d.clamped,
            });
        }
        Ok(DispatchTable { tables, fingerprint, stats })
    }
}

pub(crate) fn mode_name(mode: HwMode) -> String {
    match mode {
        HwMode::Adaptive => "adaptive".to_string(),
        HwMode::Only(name) => format!("only:{name}"),
    }
}

/// Inverse of [`mode_name`], resolving backend names against the
/// selector's hardware spec (whose names are `'static`).
fn parse_mode(s: &str, selector: &Selector) -> Option<HwMode> {
    if s == "adaptive" {
        return Some(HwMode::Adaptive);
    }
    let name = s.strip_prefix("only:")?;
    selector
        .hw
        .backends
        .iter()
        .find(|b| b.name == name)
        .map(|b| HwMode::Only(b.name))
}

/// Pure serialized form of one (op, mode) table — the `"dispatch"`
/// payload of the schema-v3 library JSON
/// ([`crate::compiler::LIBRARY_SCHEMA_VERSION`]). Winners are stored
/// as run-length-encoded `(count, lib, kernel)` triples over the
/// row-major lattice; the fingerprint pins the selector the table was
/// built for, and the digest pins THIS payload's contents (edges,
/// winners, clamped flag) so a corrupted or hand-edited file is
/// refused at adoption instead of silently serving shifted intervals.
/// (An integrity check against accidents, not a cryptographic
/// signature.)
#[derive(Debug, Clone, PartialEq)]
pub struct TableData {
    pub op: OpKind,
    /// `"adaptive"` or `"only:<backend name>"`.
    pub mode: String,
    pub edges: Vec<Vec<usize>>,
    pub runs: Vec<(usize, usize, usize)>,
    /// True when the build clamped horizons to fit the cell budget —
    /// carried through adoption so "unclamped ⟹ full envelope
    /// coverage" reasoning survives serialization.
    pub clamped: bool,
    pub fingerprint: u64,
    /// [`table_digest`] of (op, mode, edges, runs, clamped).
    pub digest: u64,
}

/// Content digest of one serialized table (see [`TableData::digest`]).
/// `pub(crate)` so corruption tests can forge digest-consistent
/// payloads that exercise the auditor rather than the loader.
pub(crate) fn table_digest(
    op: OpKind,
    mode: &str,
    edges: &[Vec<usize>],
    runs: &[(usize, usize, usize)],
    clamped: bool,
) -> u64 {
    let mut parts: Vec<u64> = vec![op as u64, clamped as u64];
    parts.extend(mode.bytes().map(|b| b as u64));
    for e in edges {
        parts.push(u64::MAX); // axis separator
        parts.extend(e.iter().map(|&x| x as u64));
    }
    for &(n, lib, kernel) in runs {
        parts.push(n as u64);
        parts.push(lib as u64);
        parts.push(kernel as u64);
    }
    hash_key(&parts)
}

impl TableData {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("op", Json::str(self.op.name())),
            ("mode", Json::str(self.mode.clone())),
            ("clamped", Json::Bool(self.clamped)),
            ("fingerprint", Json::str(format!("{:016x}", self.fingerprint))),
            ("digest", Json::str(format!("{:016x}", self.digest))),
            (
                "edges",
                Json::arr(
                    self.edges
                        .iter()
                        .map(|e| {
                            Json::arr(e.iter().map(|&x| Json::num(x as f64)).collect())
                        })
                        .collect(),
                ),
            ),
            (
                "runs",
                Json::arr(
                    self.runs
                        .iter()
                        .map(|&(n, lib, kernel)| {
                            Json::arr(vec![
                                Json::num(n as f64),
                                Json::num(lib as f64),
                                Json::num(kernel as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Strict parse; `None` on any malformed field.
    pub fn from_json(v: &Json) -> Option<TableData> {
        let op = OpKind::parse(v.get("op")?.as_str()?)?;
        let mode = v.get("mode")?.as_str()?.to_string();
        let clamped = v.get("clamped")?.as_bool()?;
        let fingerprint = u64::from_str_radix(v.get("fingerprint")?.as_str()?, 16).ok()?;
        let digest = u64::from_str_radix(v.get("digest")?.as_str()?, 16).ok()?;
        let edges = v
            .get("edges")?
            .as_arr()?
            .iter()
            .map(|e| {
                e.as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Option<Vec<usize>>>()
            })
            .collect::<Option<Vec<Vec<usize>>>>()?;
        let runs = v
            .get("runs")?
            .as_arr()?
            .iter()
            .map(|r| {
                let a = r.as_arr()?;
                if a.len() != 3 {
                    return None;
                }
                Some((a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(TableData { op, mode, edges, runs, clamped, fingerprint, digest })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOpts};
    use crate::cost::hybrid::AnalyzerConfig;
    use crate::hw::presets;
    use crate::ir::DType;
    use crate::profiler::SimProfiler;
    use crate::sim::Simulator;
    use crate::util::prop::{forall, prop_assert};

    fn selector(seed: u64) -> Selector {
        let hw = presets::a100();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
        let libs = vec![
            compile(&hw, OpKind::Gemm, DType::F32, &cfg, &mut prof, &CompileOpts::default())
                .library,
            compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut prof, &CompileOpts::default())
                .library,
            compile(
                &hw,
                OpKind::BatchedGemm,
                DType::F16,
                &cfg,
                &mut prof,
                &CompileOpts::default(),
            )
            .library,
        ];
        Selector::new(hw, libs)
    }

    fn test_config() -> DispatchConfig {
        DispatchConfig {
            horizon: 96,
            batch_horizon: 8,
            modes: vec![
                HwMode::Adaptive,
                HwMode::Only("cuda_core_f32"),
                HwMode::Only("tensor_core_f16"),
            ],
            max_cells: 1 << 17,
            ..DispatchConfig::default()
        }
    }

    #[test]
    fn builds_tables_for_served_ops_only() {
        let s = selector(5);
        let t = DispatchTable::for_selector(&s, &test_config());
        assert!(t.stats.tables > 0);
        assert!(t.stats.cells > 0);
        assert!(t.stats.cells <= t.stats.cells_enumerated);
        // Gemm and its conv alias are servable under every mode;
        // batched/grouped/attention only where the f16 batched library
        // has backends.
        assert!(t.horizons(OpKind::Gemm, HwMode::Adaptive).is_some());
        assert!(t.horizons(OpKind::Conv2d, HwMode::Adaptive).is_some());
        assert!(t.horizons(OpKind::FusedAttention, HwMode::Adaptive).is_some());
        // The batched library is tensor-core f16: a cuda-core-only mode
        // has no eligible kernels, so no table is built — and lookups
        // fall through to fresh selection, which returns None too.
        let batched = IterSpace::batched_gemm(2, 64, 64, 32, DType::F16);
        if t.horizons(OpKind::BatchedGemm, HwMode::Only("cuda_core_f32")).is_none() {
            assert!(t
                .select(&s, batched, HwMode::Only("cuda_core_f32"))
                .is_none());
        }
        assert!(t.matches(&s));
    }

    #[test]
    fn prop_table_answers_equal_fresh_selection() {
        // The acceptance property: across random shapes (within AND
        // beyond the horizon), every op kind, both dtypes and all
        // modes, a table answer is same_plan-identical to fresh
        // Selector::select — and a table non-answer is exactly the
        // beyond-horizon / unservable case.
        let s = selector(5);
        let cfg = test_config();
        let table = DispatchTable::for_selector(&s, &cfg);
        let modes = [
            HwMode::Adaptive,
            HwMode::Only("cuda_core_f32"),
            HwMode::Only("tensor_core_f16"),
        ];
        let mut answered = 0usize;
        let mut fallback = 0usize;
        forall(
            "dispatch-table-equals-fresh",
            160,
            0xD15B,
            |r, size| {
                let op = OpKind::ALL[r.usize(0, OpKind::ALL.len() - 1)];
                let rank = op.spec().rank();
                let mut dims = vec![0usize; rank];
                for (i, d) in dims.iter_mut().enumerate() {
                    // Half the draws stay near the horizon, half go
                    // well beyond it.
                    let cap = if rank == 4 && i == 0 { 24 } else { 80 * size.max(1) };
                    *d = r.usize(1, cap.max(2));
                }
                let dtype = if r.usize(0, 1) == 0 { DType::F16 } else { DType::F32 };
                let mode = modes[r.usize(0, modes.len() - 1)];
                (op, dims, dtype, mode)
            },
            |(op, dims, dtype, mode)| {
                let space = IterSpace { op: *op, dims: Tile::new(dims), dtype: *dtype };
                let fresh = s.select(space, *mode);
                match table.select(&s, space, *mode) {
                    Some(t) => {
                        answered += 1;
                        match &fresh {
                            Some(f) => prop_assert(
                                f.same_plan(&t),
                                format!("table diverged for {:?}: {:?} vs {:?}", space, f, t),
                            ),
                            None => Err(format!("table answered unservable {:?}", space)),
                        }
                    }
                    None => {
                        fallback += 1;
                        prop_assert(
                            !table.covers(space, *mode) || fresh.is_none(),
                            format!("covered space {:?} got no table answer", space),
                        )
                    }
                }
            },
        );
        assert!(answered > 0, "property never exercised a table answer");
        assert!(fallback > 0, "property never exercised the horizon fallback");
    }

    #[test]
    fn exhaustive_equality_on_a_small_lattice() {
        // Brute force every shape of a small envelope (plus the first
        // beyond-horizon row) against fresh selection — no sampling
        // gaps at interval boundaries.
        let s = selector(7);
        let cfg = DispatchConfig {
            per_op: vec![(OpKind::Gemm, vec![48, 48, 48])],
            ops: vec![OpKind::Gemm],
            ..DispatchConfig::default()
        };
        let table = DispatchTable::for_selector(&s, &cfg);
        for m in 1..=50usize {
            for n in (1..=50usize).step_by(7) {
                for k in (1..=50usize).step_by(11) {
                    let space = IterSpace::gemm(m, n, k, DType::F32);
                    let fresh = s.select(space, HwMode::Adaptive).unwrap();
                    match table.select(&s, space, HwMode::Adaptive) {
                        Some(t) => assert!(
                            fresh.same_plan(&t),
                            "diverged at {:?}: {:?} vs {:?}",
                            (m, n, k),
                            fresh,
                            t
                        ),
                        None => assert!(
                            m > 48 || n > 48 || k > 48,
                            "in-horizon {:?} unanswered",
                            (m, n, k)
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn region_merging_compresses_without_changing_answers() {
        // A single-kernel library wins every cell, so the whole
        // lattice provably merges to ONE region per table — while the
        // merged table still reconstructs the exact per-shape plan
        // (padded, grid, estimate) from the actual dims.
        let hw = presets::a100();
        let acfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), 9));
        let mut lib = compile(
            &hw,
            OpKind::Gemm,
            DType::F32,
            &acfg,
            &mut prof,
            &CompileOpts::default(),
        )
        .library;
        lib.kernels.truncate(1);
        let s = Selector::new(hw, vec![lib]);
        let cfg = DispatchConfig {
            per_op: vec![(OpKind::Gemm, vec![96, 96, 96]), (OpKind::Conv2d, vec![96, 96, 96])],
            ..DispatchConfig::default()
        };
        let table = DispatchTable::for_selector(&s, &cfg);
        // One cell per table after merging (Gemm + its Conv2d alias).
        assert_eq!(table.stats.tables, 2);
        assert_eq!(table.stats.cells, 2, "uniform winners must fully merge");
        assert!(table.stats.cells_enumerated > 2);
        for m in [1usize, 7, 16, 33, 48, 96] {
            for n in [1usize, 24, 96] {
                let space = IterSpace::gemm(m, n, 64, DType::F32);
                let fresh = s.select(space, HwMode::Adaptive).unwrap();
                let t = table.select(&s, space, HwMode::Adaptive).unwrap();
                assert!(fresh.same_plan(&t), "merged table diverged at {:?}", (m, n));
            }
        }
        // Distinct shapes still get distinct plans out of one region.
        let a = table.select(&s, IterSpace::gemm(5, 40, 40, DType::F32), HwMode::Adaptive);
        let b = table.select(&s, IterSpace::gemm(90, 40, 40, DType::F32), HwMode::Adaptive);
        assert_ne!(a.unwrap().padded, b.unwrap().padded);
    }

    #[test]
    fn cell_budget_clamps_horizons_soundly() {
        let s = selector(5);
        let cfg = DispatchConfig {
            per_op: vec![(OpKind::Gemm, vec![4096, 4096, 4096])],
            ops: vec![OpKind::Gemm],
            max_cells: 512,
            ..DispatchConfig::default()
        };
        let table = DispatchTable::for_selector(&s, &cfg);
        assert!(table.stats.clamped, "huge horizons must clamp at 512 cells");
        let h = table.horizons(OpKind::Gemm, HwMode::Adaptive).unwrap();
        assert!(h.iter().any(|&x| x < 4096));
        // Clamping trades coverage, never correctness.
        for m in [1usize, 3, 9, 31] {
            let space = IterSpace::gemm(m, 32, 32, DType::F32);
            if let Some(t) = table.select(&s, space, HwMode::Adaptive) {
                let fresh = s.select(space, HwMode::Adaptive).unwrap();
                assert!(fresh.same_plan(&t));
            }
        }
    }

    #[test]
    fn serialization_round_trips_and_rejects_foreign_selectors() {
        let s = selector(5);
        let cfg = test_config();
        let table = DispatchTable::for_selector(&s, &cfg);
        let data = table.to_data(&s);
        assert_eq!(data.len(), table.stats.tables);
        // JSON round trip of every payload.
        let parsed: Vec<TableData> = data
            .iter()
            .map(|d| TableData::from_json(&Json::parse(&d.to_json().dump()).unwrap()).unwrap())
            .collect();
        assert_eq!(parsed, data);
        // Adoption by the SAME selector reproduces identical answers.
        let adopted = DispatchTable::from_data(&s, &parsed).expect("adoption");
        for (m, n, k) in [(1usize, 64usize, 64usize), (33, 100, 150), (160, 160, 160)] {
            let space = IterSpace::gemm(m, n, k, DType::F16);
            let a = adopted.select(&s, space, HwMode::Adaptive);
            let b = table.select(&s, space, HwMode::Adaptive);
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => assert!(x.same_plan(&y)),
                other => panic!("adoption diverged: {:?}", other),
            }
        }
        // A selector with different base costs (different profiler
        // seed) must refuse the tables.
        let other = selector(6);
        assert!(
            DispatchTable::from_data(&other, &parsed).is_none(),
            "foreign selector adopted a stale table"
        );
        // Tampering with an interval edge (fingerprint untouched) is
        // caught by the content digest — never a silently-shifted
        // lookup.
        let mut tampered = parsed.clone();
        tampered[0].edges[0][0] += 1;
        assert!(
            DispatchTable::from_data(&s, &tampered).is_none(),
            "edge-tampered table adopted"
        );
    }
}
