//! Hardware performance simulator substrate (DESIGN.md §3, substitution
//! for the paper's A100 / Xeon testbeds).
//!
//! The simulator is the shared "ground truth" all engines (Vortex,
//! DietCode, vendor-library analogs) are measured against on the
//! simulated testbeds. It executes the same Eq. 2–4 pipeline model as
//! the analytical cost model, then layers on the effects the analytical
//! model cannot see — which is precisely what makes the paper's hybrid
//! analyzer (§5.2) and Fig. 5's utilization cliff reproducible:
//!
//! * **Per-level utilization efficiency curve** (Fig. 5): working sets
//!   that under- or over-shoot a level's capacity lose efficiency, with
//!   a hard cliff past 100% (spill).
//! * **Hidden micro-architectural factors**: deterministic per-tile
//!   multipliers (hash-derived) standing in for out-of-order execution,
//!   bank conflicts and issue-slot luck — visible to empirical
//!   profiling, invisible to the analytical model (paper: "hardware
//!   optimizations ... can lead to substantial inaccuracies" [24]).
//! * **Kernel launch overhead** and deterministic measurement noise.

use crate::cost::{self, Strategy};
use crate::hw::HwSpec;
use crate::ir::{DType, Tile};
use crate::util::rng::hash_key;

#[derive(Debug, Clone)]
pub struct Simulator {
    pub hw: HwSpec,
    pub seed: u64,
    /// Per-kernel-launch fixed overhead, seconds.
    pub launch_overhead: f64,
}

/// Map a hash to a factor in [1-spread, 1+spread].
fn factor(h: u64, spread: f64) -> f64 {
    let u = (h >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
    1.0 + spread * (2.0 * u - 1.0)
}

impl Simulator {
    pub fn new(hw: HwSpec, seed: u64) -> Simulator {
        // Owned by the preset (like `is_real_testbed`): no name
        // string-matching here.
        let launch_overhead = hw.launch_overhead_secs;
        Simulator { hw, seed, launch_overhead }
    }

    fn tile_hash(&self, salt: u64, backend: usize, tile: Tile) -> u64 {
        let mut parts = vec![self.seed, salt, backend as u64];
        parts.extend(tile.iter().map(|&x| x as u64));
        hash_key(&parts)
    }

    /// Hidden L0 micro-architectural factor: out-of-order/issue effects
    /// the analytical model cannot predict. Empirical profiling sees it.
    pub fn hidden_l0_factor(&self, backend: usize, tile: Tile) -> f64 {
        factor(self.tile_hash(0x10, backend, tile), 0.30)
    }

    /// Hidden L1 factor (bank conflicts, cache way contention) — smaller.
    pub fn hidden_l1_factor(&self, backend: usize, tile: Tile) -> f64 {
        factor(self.tile_hash(0x11, backend, tile), 0.12)
    }

    /// Fig. 5 utilization-efficiency curve for one level: multiplier on
    /// time (>= 1). `util` = working set / capacity.
    pub fn util_penalty(util: f64, min_util: f64) -> f64 {
        if util > 1.0 {
            // Spill cliff: sharply worse past capacity.
            1.0 + 6.0 * (util - 1.0) + 2.0 * (util - 1.0) * (util - 1.0)
        } else if util < min_util {
            // Severe under-utilization wastes the level's parallel/reuse
            // capability (left side of Fig. 5).
            1.0 + 0.8 * (min_util - util) / min_util.max(1e-9)
        } else {
            1.0
        }
    }

    /// Deterministic "measurement" noise, ±3%.
    fn noise(&self, strat: &Strategy) -> f64 {
        let mut parts = vec![self.seed, 0x707];
        for t in &strat.tiles {
            parts.extend(t.iter().map(|&x| x as u64));
        }
        factor(hash_key(&parts), 0.03)
    }

    /// The simulated true execution time of a full strategy chain
    /// (`tiles[last]` = padded problem shape).
    ///
    /// Hidden factors scale the tiers they belong to: the L0 factor the
    /// instruction stream, the L1 factor the on-chip subchain. They do
    /// NOT scale the top-level DRAM traffic — bank conflicts do not slow
    /// HBM — which keeps the measured-subchain + analytical-top
    /// composition of the hybrid analyzer structurally faithful.
    pub fn execute(&self, dtype: DType, strat: &Strategy) -> f64 {
        let t = if strat.tiles.len() >= 3 {
            let c1 = self.true_subchain_secs(dtype, strat);
            cost::cost_from(&self.hw, dtype, strat, 2, c1).total_secs
        } else if strat.tiles.len() == 2 {
            self.true_subchain_secs(dtype, strat)
        } else {
            self.true_l0_secs(dtype, strat)
        };
        let lf = self.hw.backends[strat.backend].launch_factor;
        (t + self.launch_overhead * lf) * self.noise(strat)
    }

    /// Fig. 5 utilization penalty of the tile at `level`.
    fn tile_penalty(&self, strat: &Strategy, level: usize) -> f64 {
        let ws = strat.op.spec().working_set(
            strat.tiles[level],
            self.hw.backends[strat.backend].dtype_bytes,
        );
        let util = ws as f64 / self.hw.level(level).capacity_bytes as f64;
        Self::util_penalty(util, self.hw.min_util)
    }

    /// True level-0 cost (what empirical L0 profiling measures): the
    /// analytical bottom, scaled by the hidden micro-architectural
    /// factor AND the Fig. 5 utilization penalty of the register tile —
    /// both are properties of the tile that real profiling observes.
    pub fn true_l0_secs(&self, dtype: DType, strat: &Strategy) -> f64 {
        let analytic = cost::cost(&self.hw, dtype, strat, None).per_level_secs[0];
        analytic
            * self.hidden_l0_factor(strat.backend, strat.tiles[0])
            * self.tile_penalty(strat, 0)
    }

    /// True cost of the 2-level subchain [t0, t1] (what empirical L1
    /// profiling measures): includes the hidden L1 factor.
    pub fn true_subchain_secs(&self, dtype: DType, strat: &Strategy) -> f64 {
        debug_assert!(strat.tiles.len() >= 2);
        let sub = Strategy::for_op(strat.op, strat.tiles[..2].to_vec(), strat.backend);
        let l0 = self.true_l0_secs(dtype, &sub);
        let up = cost::cost_from(&self.hw, dtype, &sub, 1, l0);
        up.total_secs
            * self.hidden_l1_factor(strat.backend, strat.tiles[1])
            * self.tile_penalty(&sub, 1)
    }

    /// Streaming row-softmax micro-measurement: the true cost of one
    /// fused softmax pass over a (rows x cols) f32 score tile — one
    /// online max/rescaled-sum sweep plus one normalization sweep,
    /// `ops_per_elem` scalar ops per element on the widest f32 backend
    /// — scaled by a hidden throughput factor (exp-unit pressure,
    /// lane predication) only empirical profiling can see. This is the
    /// attention epilogue's analog of `true_l0_secs`.
    pub fn softmax_secs(&self, ops_per_elem: f64, rows: usize, cols: usize) -> f64 {
        let peak = self
            .hw
            .backends
            .iter()
            .filter(|b| b.dtype_bytes == 4)
            .map(|b| b.peak_gflops)
            .fold(0.0, f64::max)
            .max(1.0);
        let base = (rows * cols) as f64 * ops_per_elem / (peak * 1e9);
        let h = hash_key(&[self.seed, 0x50F7, rows as u64, cols as u64]);
        base * factor(h, 0.20)
    }

    /// Achieved FLOP/s for a chain on a given *unpadded* problem (used
    /// by Fig. 5 / Fig. 12 style reporting: real flops over true time).
    pub fn achieved_gflops(
        &self,
        dtype: DType,
        strat: &Strategy,
        real_flops: f64,
    ) -> f64 {
        real_flops / self.execute(dtype, strat) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    fn sim() -> Simulator {
        Simulator::new(presets::a100(), 7)
    }

    fn strat(hw: &HwSpec, tiles: Vec<[usize; 3]>, backend: &str) -> Strategy {
        Strategy::new(tiles, hw.backend_idx(backend).unwrap())
    }

    #[test]
    fn deterministic() {
        let s = sim();
        let st = strat(&s.hw, vec![[16, 8, 16], [64, 64, 32], [512, 512, 512]], "tensor_core_f16");
        assert_eq!(s.execute(DType::F16, &st), s.execute(DType::F16, &st));
    }

    #[test]
    fn seeds_change_hidden_factors_not_scale() {
        let a = Simulator::new(presets::a100(), 1);
        let b = Simulator::new(presets::a100(), 2);
        let st = strat(&a.hw, vec![[16, 8, 16], [64, 64, 32], [512, 512, 512]], "tensor_core_f16");
        let (ta, tb) = (a.execute(DType::F16, &st), b.execute(DType::F16, &st));
        assert_ne!(ta, tb);
        assert!(ta / tb < 2.0 && tb / ta < 2.0);
    }

    #[test]
    fn util_cliff_shape() {
        // Fig. 5: flat in the window, cliff past 1.0, mild penalty low.
        assert_eq!(Simulator::util_penalty(0.5, 0.25), 1.0);
        assert!(Simulator::util_penalty(1.5, 0.25) > 3.0);
        assert!(Simulator::util_penalty(0.05, 0.25) > 1.2);
        assert!(
            Simulator::util_penalty(2.0, 0.25) > Simulator::util_penalty(1.2, 0.25)
        );
    }

    #[test]
    fn oversized_tile_is_slower_despite_fewer_iterations() {
        // A CTA tile that spills shared memory must lose to one that fits.
        let s = sim();
        let fits = strat(&s.hw, vec![[16, 8, 16], [64, 64, 32], [2048, 2048, 512]], "tensor_core_f16");
        let ws_fits = HwSpec::gemm_working_set([64, 64, 32], 2);
        assert!(ws_fits <= s.hw.level(1).capacity_bytes);
        let spills = strat(&s.hw, vec![[16, 8, 16], [256, 256, 64], [2048, 2048, 512]], "tensor_core_f16");
        let ws_spill = HwSpec::gemm_working_set([256, 256, 64], 2);
        assert!(ws_spill > s.hw.level(1).capacity_bytes);
        assert!(
            s.execute(DType::F16, &spills) > s.execute(DType::F16, &fits),
            "spilling tile should be slower"
        );
    }

    #[test]
    fn empirical_l0_sees_hidden_factor() {
        let s = sim();
        let st = strat(&s.hw, vec![[16, 8, 16], [64, 64, 32], [512, 512, 512]], "tensor_core_f16");
        let analytic = cost::cost(&s.hw, DType::F16, &st, None).per_level_secs[0];
        let measured = s.true_l0_secs(DType::F16, &st);
        let f = measured / analytic;
        // hidden factor (±30%) x possible small-tile utilization penalty
        assert!((0.69..=2.4).contains(&f), "hidden factor out of range: {}", f);
    }

    #[test]
    fn softmax_measurement_is_deterministic_and_scales_with_tile() {
        let s = sim();
        let a = s.softmax_secs(8.0, 64, 64);
        assert_eq!(a, s.softmax_secs(8.0, 64, 64));
        assert!(a > 0.0);
        // More elements cost more (hidden factor is bounded to ±20%,
        // a 4x tile always dominates it).
        assert!(s.softmax_secs(8.0, 256, 64) > a);
        // The per-element op count is a measurement input: doubling it
        // doubles the base term under the same hidden factor.
        assert_eq!(s.softmax_secs(16.0, 64, 64), 2.0 * a);
    }

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let s = sim();
        let tiny = strat(&s.hw, vec![[16, 8, 16], [16, 8, 16], [16, 8, 16]], "tensor_core_f16");
        let t = s.execute(DType::F16, &tiny);
        assert!(t >= s.launch_overhead);
    }
}
