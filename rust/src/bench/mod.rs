//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§7) — see DESIGN.md §5 for the experiment index.
//!
//! Entry point: [`run`] (used by `vortex bench <exp>` and the criterion-
//! style bench binaries). Each experiment prints aligned tables and
//! writes CSVs under `results/`.

pub mod exp_ablation;
pub mod exp_analysis;
pub mod exp_decode;
pub mod exp_model;
pub mod exp_operator;
pub mod exp_serve;
pub mod harness;
pub mod workloads;

use std::path::Path;

use crate::util::table::Table;

/// All experiment names, in paper order.
pub const EXPERIMENTS: &[&str] = &[
    "fig3", "fig5", "table5", "table6", "fig13", "offline", "fig14", "fig15",
    "table7", "fig16", "ablation", "ops", "serve", "decode",
];

/// Run one experiment (or "all"). `fast` subsamples the big suites so a
/// full pass stays minutes, not hours; paper-scale runs use fast=false.
pub fn run(name: &str, out_dir: &Path, seed: u64, fast: bool) -> Vec<Table> {
    std::fs::create_dir_all(out_dir).ok();
    let frac = if fast { 8 } else { 1 };
    match name {
        "fig3" => exp_operator::fig3(out_dir, seed),
        "fig5" => exp_operator::fig5(out_dir, seed),
        "table5" => exp_operator::table5(out_dir, seed, frac),
        "table6" => exp_operator::table6(out_dir, seed),
        "fig13" => exp_model::fig13(out_dir, seed, if fast { 4 } else { 1 }),
        "offline" => exp_analysis::offline(out_dir, seed, if fast { 30 } else { 150 }),
        "fig14" => exp_analysis::fig14(out_dir, seed),
        "fig15" => exp_analysis::fig15(out_dir, seed, frac),
        "table7" => exp_analysis::table7(out_dir, seed, frac),
        "fig16" => exp_analysis::fig16(out_dir, seed),
        "ablation" => exp_ablation::ablation(out_dir, seed, frac),
        "ops" => exp_operator::ops(out_dir, seed, frac),
        "serve" => exp_serve::serve(out_dir, seed, frac),
        "decode" => exp_decode::decode(out_dir, seed, frac),
        "all" => {
            let mut all = Vec::new();
            for e in EXPERIMENTS {
                eprintln!("== running {e} ==");
                all.extend(run(e, out_dir, seed, fast));
            }
            all
        }
        other => panic!("unknown experiment '{other}' (try one of {EXPERIMENTS:?})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("vortex_bench_tests");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fig3_dietcode_out_of_sample_is_slower() {
        let tables = run("fig3", &tmp(), 7, true);
        let t = &tables[0];
        // Average DietCode/cuBLAS speedup over in-sample rows must beat
        // out-of-sample rows (the paper's motivating observation).
        let mut in_s = vec![];
        let mut out_s = vec![];
        for row in &t.rows {
            let v: f64 = row[5].trim_end_matches('x').parse().unwrap();
            if row[2] == "I" {
                in_s.push(v);
            } else {
                out_s.push(v);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            mean(&in_s) > mean(&out_s),
            "in-sample {:?} !> out-of-sample {:?}",
            mean(&in_s),
            mean(&out_s)
        );
    }

    #[test]
    fn fig5_shows_the_cliff() {
        let tables = run("fig5", &tmp(), 7, true);
        for t in &tables {
            let g: Vec<f64> =
                t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
            let peak = g.iter().cloned().fold(0.0, f64::max);
            // Performance at the extremes (tiny tile / oversized tile)
            // must fall well below the peak (Fig. 5's shape).
            assert!(g[0] < 0.7 * peak, "{}: no low-util penalty", t.title);
            assert!(
                *g.last().unwrap() < 0.7 * peak,
                "{}: no capacity cliff",
                t.title
            );
        }
    }

    #[test]
    fn fig16_adaptive_tracks_best_backend() {
        let tables = run("fig16", &tmp(), 7, true);
        let mut beat_cc = false;
        let mut beat_tc = false;
        for row in &tables[0].rows {
            let tc: f64 = row[3].parse().unwrap();
            let ad: f64 = row[4].parse().unwrap();
            // estimate-driven choice: never catastrophically worse...
            assert!(ad <= tc.min(1.0) * 1.3, "adaptive lost badly: {:?}", row);
            // ...and clearly better than each fixed mode somewhere.
            beat_cc |= ad < 0.95;
            beat_tc |= ad < tc * 0.95;
        }
        assert!(beat_cc, "adaptive never beat CUDA-only");
        assert!(beat_tc, "adaptive never beat tensor-only");
    }

    #[test]
    fn fig14_scheduling_overhead_shrinks_with_size() {
        let tables = run("fig14", &tmp(), 7, true);
        // Selection cost is wall-clock: under `cargo test` (debug build)
        // it is ~10x the release number, so the absolute bound here is
        // loose; the release-mode bound is asserted by the
        // runtime_select bench and EXPERIMENTS.md §Perf.
        let pcts: Vec<f64> = tables[0]
            .rows
            .iter()
            .map(|r| r[3].trim_end_matches('%').parse().unwrap())
            .collect();
        // Monotone trend: large kernels amortize scheduling.
        assert!(pcts.last().unwrap() < &pcts[0]);
        // At the largest size scheduling must be a sliver even in debug.
        assert!(pcts.last().unwrap() < &10.0, "{:?}", pcts);
    }
}
