//! §7.4 analyses: offline overhead, runtime overhead breakdown
//! (Fig. 14), hierarchical-construction ablation (Fig. 15), hybrid
//! analyzer study (Table 7), dynamic hardware adaptation (Fig. 16).

use std::path::Path;
use std::time::Instant;

use crate::baselines::dietcode::DietCode;
use crate::bench::harness::{vortex_engine, Engine, Testbed};
use crate::bench::workloads;
use crate::compiler::{compile, CompileOpts, MicroKernelLibrary};
use crate::coordinator::{HwMode, Selector};
use crate::cost::hybrid::AnalyzerConfig;
use crate::ir::{Contraction, DType, OpKind};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;
use crate::util::table::{fmt_secs, fmt_x, Table};

/// §7.4 Offline-overhead analysis: Vortex candidate counts + compile
/// time per mode vs DietCode's sample-driven tuning time.
pub fn offline(out_dir: &Path, seed: u64, dietcode_trials: usize) -> Vec<Table> {
    let mut t = Table::new(
        "§7.4 — offline compilation overhead",
        &["Engine", "Mode", "Candidates", "Profile queries", "Offline time (modeled)", "Wall here"],
    );
    for tb in [Testbed::Cpu, Testbed::GpuTensorCore, Testbed::GpuCudaCore] {
        let hw = tb.hw();
        let cfg = AnalyzerConfig::default_for(&hw);
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
        let r = compile(&hw, OpKind::Gemm, tb.dtype(), &cfg, &mut prof, &CompileOpts::default());
        t.row(vec![
            "vortex".into(),
            tb.label().into(),
            r.candidates_total.to_string(),
            r.profile_queries.to_string(),
            fmt_secs(r.offline_secs),
            fmt_secs(r.wall_secs),
        ]);
    }
    // DietCode: GPU CUDA-core mode, the full Table-3 suite as its
    // sample set (paper §7.4: "using configurations in Table 3 as the
    // sample set" -> 25 hours of tuning). The trial budget is sized so
    // the modeled tuning time lands in the paper's tens-of-hours class;
    // more trials only make the sample-driven approach look worse.
    let hw = Testbed::GpuCudaCore.hw();
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let wall0 = Instant::now();
    let samples: Vec<[usize; 3]> = workloads::gemm_suite(DType::F32, seed)
        .iter()
        .map(|c| {
            let ct = c.program.contraction();
            [ct.m, ct.n, ct.k]
        })
        .collect();
    let dc = DietCode::tune(
        &hw,
        "cuda_core_f32",
        &samples,
        dietcode_trials,
        &mut prof,
        seed,
    );
    t.row(vec![
        "dietcode".into(),
        Testbed::GpuCudaCore.label().into(),
        format!("{} samples x {} trials", samples.len(), dietcode_trials),
        dc.trials_total.to_string(),
        fmt_secs(dc.tuning_secs),
        fmt_secs(wall0.elapsed().as_secs_f64()),
    ]);
    let _ = t.write_csv(&out_dir.join("offline.csv"));
    vec![t]
}

/// Fig. 14: runtime overhead breakdown — scheduling (cost-model
/// selection) vs kernel execution across GEMM sizes.
pub fn fig14(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let sim = Simulator::new(tb.hw(), seed);
    let engine = vortex_engine(tb, seed);
    let Engine::Vortex { selector, mode } = &engine else { unreachable!() };
    let mut t = Table::new(
        "Fig. 14 — runtime overhead breakdown (GPU, GEMM M=N=K)",
        &["M/N/K", "scheduling (us)", "execution (us)", "scheduling %"],
    );
    for &d in &[64usize, 128, 256, 512, 1024, 2048, 4096] {
        let c = Contraction { m: d, n: d, k: d, dtype: DType::F16 };
        let sel = selector.select(c, *mode).unwrap();
        let lib = &selector.libraries[sel.lib];
        let exec = sim.execute(lib.dtype, &selector.chain(&sel));
        t.row(vec![
            d.to_string(),
            format!("{:.1}", sel.select_secs * 1e6),
            format!("{:.1}", exec * 1e6),
            format!("{:.2}%", 100.0 * sel.select_secs / (sel.select_secs + exec)),
        ]);
    }
    let _ = t.write_csv(&out_dir.join("fig14.csv"));
    vec![t]
}

/// Fig. 15: hierarchical kernel construction ablation on the Table 3
/// GEMM suite (GPU Tensor Core): Vortex vs Oracle / Static1 / Static2.
pub fn fig15(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let lib = compile(&hw, OpKind::Gemm, DType::F16, &cfg, &mut prof, &CompileOpts::default())
        .library;
    let selector = Selector::new(hw.clone(), vec![lib.clone()]);

    let cases: Vec<Contraction> = workloads::gemm_suite(DType::F16, seed)
        .into_iter()
        .step_by(fraction.max(1))
        // Oracle scans the full library per case; bound M to keep the
        // padded-chain costs meaningful on TC tiles.
        .map(|c| c.program.contraction())
        .collect();

    // True (simulator) time of a library kernel on a case.
    let truth = |k: &crate::compiler::MicroKernel, c: Contraction| -> f64 {
        let padded = crate::ir::Tile::from3([
            crate::ir::round_up(c.m, k.l1[0]),
            crate::ir::round_up(c.n, k.l1[1]),
            crate::ir::round_up(c.k, k.l1[2]),
        ]);
        sim.execute(DType::F16, &k.chain(OpKind::Gemm, padded))
    };

    // Oracle: per-case best-true kernel (profiling-based static compile).
    let mut oracle_times = Vec::with_capacity(cases.len());
    let mut oracle_choice = Vec::with_capacity(cases.len());
    for &c in &cases {
        let (bi, bt) = lib
            .kernels
            .iter()
            .enumerate()
            .map(|(i, k)| (i, truth(k, c)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        oracle_times.push(bt);
        oracle_choice.push(bi);
    }

    // Vortex default: analytical selection (hybrid-informed base costs).
    let vortex_times: Vec<f64> = cases
        .iter()
        .map(|&c| {
            let sel = selector.select(c, HwMode::Only("tensor_core_f16")).unwrap();
            truth(selector.kernel(&sel), c)
        })
        .collect();

    // Static1: dynamic L1 selection, single fixed L0 (most frequently
    // optimal across the suite).
    let most_freq = |choices: &[usize]| -> usize {
        let mut counts = std::collections::HashMap::new();
        for &c in choices {
            *counts.entry(c).or_insert(0usize) += 1;
        }
        counts.into_iter().max_by_key(|&(_, n)| n).unwrap().0
    };
    let fixed_l0 = lib.kernels[most_freq(&oracle_choice)].l0;
    let static1_lib = MicroKernelLibrary {
        kernels: lib
            .kernels
            .iter()
            .filter(|k| {
                k.l1.iter().zip(fixed_l0.iter()).all(|(&p, &c0)| p % c0 == 0)
            })
            .map(|k| crate::compiler::MicroKernel { l0: fixed_l0, ..k.clone() })
            .collect(),
        ..lib.clone()
    };
    let static1_sel = Selector::new(hw.clone(), vec![static1_lib]);
    let static1_times: Vec<f64> = cases
        .iter()
        .map(|&c| {
            let sel = static1_sel.select(c, HwMode::Only("tensor_core_f16")).unwrap();
            truth(static1_sel.kernel(&sel), c)
        })
        .collect();

    // Static2: one fixed (L0, L1) kernel for every case.
    let fixed_kernel = &lib.kernels[most_freq(&oracle_choice)];
    let static2_times: Vec<f64> =
        cases.iter().map(|&c| truth(fixed_kernel, c)).collect();

    let norm = |times: &[f64]| -> f64 {
        // Average of per-case (oracle / variant) — "fraction of oracle
        // performance" like the paper's normalization.
        let s: f64 = times
            .iter()
            .zip(oracle_times.iter())
            .map(|(t, o)| o / t)
            .sum();
        100.0 * s / times.len() as f64
    };

    let mut t = Table::new(
        "Fig. 15 — hierarchical construction ablation (GPU Tensor Core, % of Vortex-Oracle)",
        &["Variant", "% of Oracle perf"],
    );
    t.row(vec!["Vortex-Oracle".into(), "100.0%".into()]);
    t.row(vec!["Vortex".into(), format!("{:.1}%", norm(&vortex_times))]);
    t.row(vec!["Vortex-Static1".into(), format!("{:.1}%", norm(&static1_times))]);
    t.row(vec!["Vortex-Static2".into(), format!("{:.1}%", norm(&static2_times))]);
    let _ = t.write_csv(&out_dir.join("fig15.csv"));
    vec![t]
}

/// Table 7: hybrid analyzer configurations — offline overhead vs
/// resulting execution performance.
pub fn table7(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Table 7 — hybrid analyzer configuration study",
        &["HW", "Analyzer config", "Offline overhead", "Execution perf (vs default)"],
    );
    for (tb, default_cfg, changed_cfg, changed_all_pairs) in [
        // CPU: default E:L0; changed E:L0,L1 (profile every pair -> hours).
        (Testbed::Cpu, AnalyzerConfig::empirical(0), AnalyzerConfig::empirical(1), true),
        // GPU TC: default E:L0,L1; changed E:L0 only.
        (Testbed::GpuTensorCore, AnalyzerConfig::empirical(1), AnalyzerConfig::empirical(0), false),
        // GPU CC: default E:L0,L1; changed E:L0 only.
        (Testbed::GpuCudaCore, AnalyzerConfig::empirical(1), AnalyzerConfig::empirical(0), false),
    ] {
        let hw = tb.hw();
        let sim = Simulator::new(hw.clone(), seed);
        let cases: Vec<Contraction> = workloads::gemm_suite(tb.dtype(), seed)
            .into_iter()
            .step_by(fraction.max(1))
            .map(|c| c.program.contraction())
            .collect();
        let eval = |cfg: &AnalyzerConfig, all_pairs: bool| -> (f64, f64) {
            let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
            let r = compile(
                &hw,
                OpKind::Gemm,
                tb.dtype(),
                cfg,
                &mut prof,
                &CompileOpts { profile_all_pairs: all_pairs, ..CompileOpts::default() },
            );
            let sel = Selector::new(hw.clone(), vec![r.library]);
            let total: f64 = cases
                .iter()
                .map(|&c| {
                    let s = sel.select(c, HwMode::Only(tb.backend_name())).unwrap();
                    sim.execute(tb.dtype(), &sel.chain(&s))
                })
                .sum();
            (r.offline_secs, total)
        };
        let (off_d, perf_d) = eval(&default_cfg, false);
        let (off_c, perf_c) = eval(&changed_cfg, changed_all_pairs);
        t.row(vec![
            tb.label().into(),
            format!("Default ({})", default_cfg.label()),
            fmt_secs(off_d),
            "1x".into(),
        ]);
        t.row(vec![
            tb.label().into(),
            format!("Changed ({})", changed_cfg.label()),
            fmt_secs(off_c),
            fmt_x(perf_d / perf_c), // >1 means changed is faster
        ]);
    }
    let _ = t.write_csv(&out_dir.join("table7.csv"));
    vec![t]
}

/// Fig. 16: CUDA-core-only vs Tensor-core-only vs Adaptive for small-M
/// FP16 GEMMs (N in {1024, 2048, 4096}, K = 1024, M in 1..=16).
pub fn fig16(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let sim = Simulator::new(tb.hw(), seed);
    let engine = vortex_engine(tb, seed);
    let Engine::Vortex { selector, .. } = &engine else { unreachable!() };
    let mut t = Table::new(
        "Fig. 16 — dynamic hardware adaptation (normalized to CUDA-core-only)",
        &["N", "M", "cuda_only", "tensor_only", "adaptive", "adaptive picks"],
    );
    let run = |c: Contraction, mode: HwMode| -> (f64, &'static str) {
        let sel = selector.select(c, mode).unwrap();
        let k = selector.kernel(&sel);
        let lib = &selector.libraries[sel.lib];
        (
            sim.execute(lib.dtype, &selector.chain(&sel)),
            selector.hw.backends[k.backend].name,
        )
    };
    for &n in &[1024usize, 2048, 4096] {
        for m in [1usize, 2, 4, 8, 12, 16] {
            let c = Contraction { m, n, k: 1024, dtype: DType::F16 };
            let (cc, _) = run(c, HwMode::Only("cuda_core_f32"));
            let (tc, _) = run(c, HwMode::Only("tensor_core_f16"));
            let (ad, picked) = run(c, HwMode::Adaptive);
            t.row(vec![
                n.to_string(),
                m.to_string(),
                "1.00".into(),
                format!("{:.2}", tc / cc),
                format!("{:.2}", ad / cc),
                picked.into(),
            ]);
        }
    }
    let _ = t.write_csv(&out_dir.join("fig16.csv"));
    vec![t]
}
