//! Model-level experiment: Fig. 13 — end-to-end speedups on language
//! models (dynamic sequence length) and CNNs (dynamic batch size).

use std::path::Path;

use crate::bench::harness::{baseline_engines, vortex_engine, SpeedupAgg, Testbed};
use crate::ir::TensorProgram;
use crate::models::{dynamic_range, trace, Model};
use crate::sim::Simulator;
use crate::util::table::{fmt_x, Table};

/// Fig. 13: end-to-end model speedups. `stride` subsamples the dynamic
/// range (1 = the paper's full grid).
pub fn fig13(out_dir: &Path, seed: u64, stride: usize) -> Vec<Table> {
    let mut detail = Table::new(
        "Fig. 13 — per-point end-to-end times (CSV for plotting)",
        &["model", "dynamic", "testbed", "baseline", "baseline_ms", "vortex_ms", "speedup"],
    );
    let mut summary = Table::new(
        "Fig. 13 — average end-to-end speedup per model",
        &["model", "testbed", "baseline", "avg speedup (geomean)"],
    );

    for model in Model::all() {
        for tb in Testbed::all() {
            // The paper runs LLMs and CNNs on both platforms; Tensor-Core
            // mode applies to fp16-able models (all, here).
            let sim = Simulator::new(tb.hw(), seed);
            let vortex = vortex_engine(tb, seed);
            let is_conv_model = !model.is_language_model();
            let baselines = baseline_engines(tb, is_conv_model, seed);
            let mut aggs: Vec<SpeedupAgg> =
                baselines.iter().map(|_| SpeedupAgg::default()).collect();
            for &dynv in dynamic_range(model).iter().step_by(stride.max(1)) {
                let ops: Vec<TensorProgram> = trace(model, dynv, tb.dtype());
                let tv: f64 = ops.iter().map(|p| vortex.time_program(&sim, p)).sum();
                for (bi, b) in baselines.iter().enumerate() {
                    let tbl: f64 = ops.iter().map(|p| b.time_program(&sim, p)).sum();
                    aggs[bi].push(tbl, tv);
                    detail.row(vec![
                        model.name().into(),
                        dynv.to_string(),
                        tb.label().into(),
                        b.name().into(),
                        format!("{:.4}", tbl * 1e3),
                        format!("{:.4}", tv * 1e3),
                        format!("{:.3}", tbl / tv),
                    ]);
                }
            }
            for (b, agg) in baselines.iter().zip(aggs.iter()) {
                summary.row(vec![
                    model.name().into(),
                    tb.label().into(),
                    b.name().into(),
                    fmt_x(agg.geomean()),
                ]);
            }
        }
    }
    let _ = detail.write_csv(&out_dir.join("fig13.csv"));
    let _ = summary.write_csv(&out_dir.join("fig13_summary.csv"));
    vec![summary]
}
