//! Extension ablation (DESIGN.md §7 / paper future-work): which of
//! Algorithm 2's pruning ingredients actually matter?
//!
//! We re-run the offline+runtime pipeline with individual constraints
//! disabled and measure (a) candidate-space blowup and (b) achieved
//! performance on the transformer GEMM suite, against the same
//! simulator truth:
//!
//! * **no-util-window** — drop the §2.3 min-utilization filter.
//! * **no-multiple-sieve** — L1 tiles need not be integer multiples of
//!   their L0 child (FilterByMultiples off; children snap to the
//!   largest dividing tile, padding inside the block like Fig. 8's
//!   wasteful case).
//! * **full (Vortex)** — everything on.
//!
//! The point the paper argues: pruning barely loses performance while
//! collapsing the space (and therefore the offline cost).

use std::path::Path;

use crate::bench::harness::Testbed;
use crate::bench::workloads;
use crate::candgen;
use crate::compiler::{compile, CompileOpts};
use crate::coordinator::{HwMode, Selector};
use crate::cost::hybrid::AnalyzerConfig;
use crate::hw::HwSpec;
use crate::ir::{DType, OpKind};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;
use crate::util::table::{fmt_secs, Table};

/// Candidate-space sizes with individual filters disabled. The variants
/// re-implement the Algorithm-2 loop minus one rule, so the counts are
/// directly comparable.
fn space_without_util_window(hw: &HwSpec, dtype: DType) -> usize {
    let mut relaxed = hw.clone();
    relaxed.min_util = 0.0;
    candgen::generate(&relaxed, OpKind::Gemm, dtype).total()
}

fn space_without_isa_filter(hw: &HwSpec, dtype: DType) -> usize {
    // ISA granularity 1x1x1: every integer tile is "aligned".
    let mut relaxed = hw.clone();
    for b in &mut relaxed.backends {
        b.isa = [1, 1, 1];
    }
    candgen::generate(&relaxed, OpKind::Gemm, dtype).total()
}

pub fn ablation(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let hw = tb.hw();
    let dtype = DType::F16;
    let sim = Simulator::new(hw.clone(), seed);

    // --- candidate-space ablation ---------------------------------------
    let full = candgen::generate(&hw, OpKind::Gemm, dtype).total();
    let no_util = space_without_util_window(&hw, dtype);
    let no_isa = space_without_isa_filter(&hw, dtype);
    let mut t1 = Table::new(
        "Ablation A — Algorithm 2 candidate space (GPU Tensor Core)",
        &["Variant", "Candidates", "vs full"],
    );
    t1.row(vec!["full (Vortex)".into(), full.to_string(), "1.0x".into()]);
    t1.row(vec![
        "no util window".into(),
        no_util.to_string(),
        format!("{:.1}x", no_util as f64 / full as f64),
    ]);
    t1.row(vec![
        "no ISA filter".into(),
        no_isa.to_string(),
        format!("{:.1}x", no_isa as f64 / full as f64),
    ]);

    // --- performance + offline-cost ablation -----------------------------
    let cases: Vec<crate::ir::Contraction> = workloads::gemm_suite(dtype, seed)
        .into_iter()
        .filter(|c| c.category == "transformer")
        .step_by(fraction.max(1))
        .map(|c| c.program.contraction())
        .collect();
    let mut t2 = Table::new(
        "Ablation B — pruning vs achieved performance (transformer suite)",
        &["Variant", "Library kernels", "Offline (modeled)", "Total exec time vs full"],
    );
    let mut eval = |label: &str, hw_variant: &HwSpec| -> f64 {
        let mut prof = SimProfiler::new(Simulator::new(hw_variant.clone(), seed));
        let r = compile(
            hw_variant,
            OpKind::Gemm,
            dtype,
            &AnalyzerConfig::default_for(hw_variant),
            &mut prof,
            &CompileOpts::default(),
        );
        let sel = Selector::new(hw_variant.clone(), vec![r.library.clone()]);
        let total: f64 = cases
            .iter()
            .map(|&c| {
                let s = sel.select(c, HwMode::Adaptive).unwrap();
                // truth always on the REAL hardware model
                sim.execute(dtype, &sel.chain(&s))
            })
            .sum();
        t2.row(vec![
            label.into(),
            r.library.kernels.len().to_string(),
            fmt_secs(r.offline_secs),
            String::new(), // filled below
        ]);
        total
    };
    let full_time = eval("full (Vortex)", &hw);
    let mut no_util_hw = hw.clone();
    no_util_hw.min_util = 0.0;
    let no_util_time = eval("no util window", &no_util_hw);
    let ratios = [1.0, no_util_time / full_time];
    for (i, r) in ratios.iter().enumerate() {
        t2.rows[i][3] = format!("{:.2}x", r);
    }

    let _ = t1.write_csv(&out_dir.join("ablation_space.csv"));
    let _ = t2.write_csv(&out_dir.join("ablation_perf.csv"));
    vec![t1, t2]
}
