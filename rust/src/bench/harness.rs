//! Shared evaluation harness: every engine (Vortex and the baselines)
//! plans a strategy per shape; the same simulator times the plan. The
//! harness also builds the per-testbed engine roster used by Table 5 /
//! Fig. 12 / Fig. 13.

use std::collections::HashMap;

use crate::baselines::cutlass::Cutlass;
use crate::baselines::dietcode::DietCode;
use crate::baselines::vendor::VendorLib;
use crate::baselines::PlanEngine;
use crate::compiler::{compile, CompileOpts};
use crate::coordinator::{HwMode, Selector};
use crate::cost::hybrid::AnalyzerConfig;
use crate::hw::{presets, HwSpec};
use crate::ir::{Contraction, DType, IterSpace, OpKind, TensorProgram};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;

/// A hardware configuration under evaluation (Table 5 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Testbed {
    Cpu,
    GpuTensorCore,
    GpuCudaCore,
}

impl Testbed {
    pub fn all() -> [Testbed; 3] {
        [Testbed::Cpu, Testbed::GpuTensorCore, Testbed::GpuCudaCore]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Testbed::Cpu => "CPU",
            Testbed::GpuTensorCore => "GPU (Tensor Core Enabled)",
            Testbed::GpuCudaCore => "GPU (Cuda Core Only)",
        }
    }

    pub fn hw(&self) -> HwSpec {
        match self {
            Testbed::Cpu => presets::xeon_8255c(),
            _ => presets::a100(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Testbed::GpuTensorCore => DType::F16,
            _ => DType::F32,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Testbed::Cpu => "avx512_f32",
            Testbed::GpuTensorCore => "tensor_core_f16",
            Testbed::GpuCudaCore => "cuda_core_f32",
        }
    }
}

/// A ready-to-time engine: shape -> (strategy, scheduling overhead secs).
pub enum Engine {
    Vortex { selector: Selector, mode: HwMode },
    Baseline(Box<dyn PlanEngine>),
}

impl Engine {
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Vortex { .. } => "vortex",
            Engine::Baseline(b) => b.name(),
        }
    }

    /// Simulated end-to-end time for one iteration space (execution +
    /// scheduling).
    ///
    /// Scheduling overhead is *modeled*
    /// ([`crate::serve::SCHED_OVERHEAD_SECS`] — the paper's Fig. 14
    /// scale on the A100 host, shared with the serving layer's event
    /// clock), not the wall-clock of `select()` on this machine:
    /// mixing this box's wall time into simulated A100 microseconds
    /// would double-count hardware differences. The real wall-clock
    /// selection cost is reported separately by Fig. 14 and the
    /// runtime_select bench.
    pub fn time_space(&self, sim: &Simulator, space: IterSpace) -> f64 {
        const VORTEX_SCHED_OVERHEAD: f64 = crate::serve::SCHED_OVERHEAD_SECS;
        // A fused chain dispatched through a single-kernel lens (an
        // alias library, the folded contraction view, or a baseline
        // planner) executes one dispatch per constituent kernel.
        let kernels = space.op.spec().chain_kernels() as f64;
        match self {
            Engine::Vortex { selector, mode } => {
                // An op with no native library is served through its
                // folded contraction view (batch → M) by the GEMM
                // libraries — coverage is never lost, precision is.
                match selector.select(space, *mode) {
                    Some(sel) => {
                        let lib = &selector.libraries[sel.lib];
                        // Native library: the chain is one simulated
                        // strategy. Alias library: one block strategy
                        // per constituent kernel.
                        let mult = if lib.op == space.op { 1.0 } else { kernels };
                        sim.execute(lib.dtype, &selector.chain(&sel)) * mult
                            + VORTEX_SCHED_OVERHEAD
                    }
                    None => {
                        let sel = selector
                            .select(space.contraction(), *mode)
                            .expect("vortex select");
                        let lib = &selector.libraries[sel.lib];
                        sim.execute(lib.dtype, &selector.chain(&sel)) * kernels
                            + VORTEX_SCHED_OVERHEAD
                    }
                }
            }
            Engine::Baseline(b) => {
                let chain = b.plan(space.contraction());
                let dtype = if sim.hw.backends[chain.backend].dtype_bytes == 2 {
                    DType::F16
                } else {
                    DType::F32
                };
                (sim.execute(dtype, &chain) + b.dispatch_overhead()) * kernels
            }
        }
    }

    pub fn time(&self, sim: &Simulator, c: Contraction) -> f64 {
        self.time_space(sim, IterSpace::from(c))
    }

    pub fn time_program(&self, sim: &Simulator, p: &TensorProgram) -> f64 {
        self.time_space(sim, p.space())
    }
}

/// Build the Vortex engine for a testbed (offline compile, §5), one
/// library per (op x dtype) the testbed serves.
pub fn vortex_engine_ops(tb: Testbed, seed: u64, ops: &[OpKind]) -> Engine {
    let hw = tb.hw();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let mut libs = Vec::new();
    for &op in ops {
        match tb {
            Testbed::GpuTensorCore => {
                // Adaptive across tensor + cuda cores (paper §6.2).
                libs.push(
                    compile(&hw, op, DType::F16, &cfg, &mut prof, &CompileOpts::default())
                        .library,
                );
                libs.push(
                    compile(&hw, op, DType::F32, &cfg, &mut prof, &CompileOpts::default())
                        .library,
                );
            }
            _ => libs.push(
                compile(&hw, op, tb.dtype(), &cfg, &mut prof, &CompileOpts::default())
                    .library,
            ),
        }
    }
    let mode = match tb {
        // "Cuda Core Only" comparisons restrict Vortex too (Table 5).
        Testbed::GpuCudaCore => HwMode::Only("cuda_core_f32"),
        _ => HwMode::Adaptive,
    };
    Engine::Vortex { selector: Selector::new(hw, libs), mode }
}

/// Build the default (GEMM-space) Vortex engine for a testbed. Conv
/// selects through these libraries via the implicit-GEMM fallback;
/// workloads needing native batched/conv libraries use
/// [`vortex_engine_ops`].
pub fn vortex_engine(tb: Testbed, seed: u64) -> Engine {
    vortex_engine_ops(tb, seed, &[OpKind::Gemm])
}

/// Baselines applicable to a testbed + operator kind (Table 5 rows).
pub fn baseline_engines(tb: Testbed, is_conv: bool, seed: u64) -> Vec<Engine> {
    let hw = tb.hw();
    match tb {
        Testbed::Cpu => vec![
            Engine::Baseline(Box::new(VendorLib::onednn(&hw))),
            Engine::Baseline(Box::new(VendorLib::onnxruntime(&hw))),
        ],
        Testbed::GpuTensorCore => {
            let b = tb.backend_name();
            vec![
                Engine::Baseline(Box::new(if is_conv {
                    VendorLib::cudnn(&hw, b)
                } else {
                    VendorLib::cublas(&hw, b)
                })),
                Engine::Baseline(Box::new(Cutlass::new(&hw, b))),
            ]
        }
        Testbed::GpuCudaCore => {
            let b = tb.backend_name();
            let mut v = vec![
                Engine::Baseline(Box::new(if is_conv {
                    VendorLib::cudnn(&hw, b)
                } else {
                    VendorLib::cublas(&hw, b)
                })),
                Engine::Baseline(Box::new(Cutlass::new(&hw, b))),
            ];
            // DietCode is GPU-CUDA-core only (paper §7.2), tuned on the
            // suite's shape categories used as its sample list.
            let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
            let samples = dietcode_default_samples(is_conv);
            // 400 trials/sample ~ DietCode's evolutionary-search budget;
            // its tuned in-sample kernels are then genuinely strong.
            v.push(Engine::Baseline(Box::new(DietCode::tune(
                &hw,
                b,
                &samples,
                400,
                &mut prof,
                seed,
            ))));
            v
        }
    }
}

/// DietCode's sample list: representative shapes from the suite ranges
/// (the paper uses Tables 3/4 parameters as its sample set).
pub fn dietcode_default_samples(is_conv: bool) -> Vec<[usize; 3]> {
    if is_conv {
        // implicit-GEMM views of common conv shapes
        vec![
            [12544, 64, 147],
            [3136, 128, 576],
            [784, 256, 1152],
            [196, 512, 2304],
            [50176, 32, 27],
        ]
    } else {
        vec![
            [16, 768, 2304],
            [64, 768, 2304],
            [128, 768, 2304],
            [256, 768, 2304],
            [384, 3072, 768],
            [1024, 1024, 1024],
            [4096, 4096, 4096],
            [35, 2560, 2560],
            [5124, 700, 2048],
            [100000, 32, 64],
        ]
    }
}

/// Aggregate speedups (Table 5 columns): % cases faster, average.
#[derive(Debug, Clone, Default)]
pub struct SpeedupAgg {
    pub speedups: Vec<f64>,
}

impl SpeedupAgg {
    pub fn push(&mut self, baseline_secs: f64, ours_secs: f64) {
        self.speedups.push(baseline_secs / ours_secs);
    }

    pub fn pct_faster(&self) -> f64 {
        if self.speedups.is_empty() {
            return 0.0;
        }
        100.0 * self.speedups.iter().filter(|&&s| s > 1.0).count() as f64
            / self.speedups.len() as f64
    }

    /// Geometric mean (robust to outliers; the paper reports averages —
    /// we report both in the tables).
    pub fn geomean(&self) -> f64 {
        if self.speedups.is_empty() {
            return 0.0;
        }
        (self.speedups.iter().map(|s| s.ln()).sum::<f64>()
            / self.speedups.len() as f64)
            .exp()
    }

    pub fn mean(&self) -> f64 {
        if self.speedups.is_empty() {
            return 0.0;
        }
        self.speedups.iter().sum::<f64>() / self.speedups.len() as f64
    }
}

/// Cache of compiled Vortex engines, keyed by testbed.
pub struct EngineCache {
    engines: HashMap<&'static str, Engine>,
    pub seed: u64,
}

impl EngineCache {
    pub fn new(seed: u64) -> EngineCache {
        EngineCache { engines: HashMap::new(), seed }
    }

    pub fn vortex(&mut self, tb: Testbed) -> &Engine {
        self.engines.entry(tb.label()).or_insert_with(|| vortex_engine(tb, self.seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vortex_beats_cutlass_on_skinny_gemm() {
        // The canonical dynamic-shape win: tiny M on a big template.
        let tb = Testbed::GpuCudaCore;
        let sim = Simulator::new(tb.hw(), 9);
        let vortex = vortex_engine(tb, 9);
        let ct = Engine::Baseline(Box::new(Cutlass::new(&tb.hw(), "cuda_core_f32")));
        let c = Contraction { m: 3, n: 2048, k: 768, dtype: DType::F32 };
        let tv = vortex.time(&sim, c);
        let tc = ct.time(&sim, c);
        assert!(tv < tc, "vortex {} !< cutlass {}", tv, tc);
    }

    #[test]
    fn engines_report_positive_times() {
        let tb = Testbed::Cpu;
        let sim = Simulator::new(tb.hw(), 9);
        let vortex = vortex_engine(tb, 9);
        for e in baseline_engines(tb, false, 9) {
            let c = Contraction { m: 128, n: 768, k: 768, dtype: DType::F32 };
            assert!(e.time(&sim, c) > 0.0, "{}", e.name());
            assert!(vortex.time(&sim, c) > 0.0);
        }
    }

    #[test]
    fn aggregate_math() {
        let mut agg = SpeedupAgg::default();
        agg.push(2.0, 1.0); // 2x
        agg.push(1.0, 2.0); // 0.5x
        assert!((agg.geomean() - 1.0).abs() < 1e-12);
        assert!((agg.pct_faster() - 50.0).abs() < 1e-12);
        assert!((agg.mean() - 1.25).abs() < 1e-12);
    }
}
