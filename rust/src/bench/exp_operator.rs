//! Operator-level experiments: Fig. 3, Fig. 5, Table 5 / Fig. 12,
//! Table 6.

use std::path::Path;

use crate::baselines::dietcode::DietCode;
use crate::baselines::vendor::VendorLib;
use crate::baselines::PlanEngine;
use crate::bench::harness::{
    baseline_engines, vortex_engine, vortex_engine_ops, SpeedupAgg, Testbed,
};
use crate::bench::workloads;
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::{ceil_div, Contraction, DType, OpKind};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;
use crate::util::json::Json;
use crate::util::table::{fmt_x, Table};

/// Fig. 3: DietCode in-sample vs out-of-sample vs cuBLAS on the BERT
/// GEMM-1 (M = 16 x seq, N = 768, K = 2304), A100 CUDA cores.
pub fn fig3(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);
    // DietCode's default sample configuration: a seq-length grid; the
    // test sweep (5..=128 step 19) mostly falls BETWEEN samples.
    let sample_seqs = [32usize, 64, 96, 128];
    let samples: Vec<[usize; 3]> =
        sample_seqs.iter().map(|&s| [16 * s, 768, 2304]).collect();
    let mut prof = SimProfiler::new(sim.clone());
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 80, &mut prof, seed);
    let cublas = VendorLib::cublas(&hw, "cuda_core_f32");

    let mut t = Table::new(
        "Fig. 3 — DietCode vs cuBLAS over sequence length (BERT GEMM-1, A100 CUDA cores)",
        &["seq", "M", "in_sample", "cuBLAS (ms)", "DietCode (ms)", "DietCode/cuBLAS speedup"],
    );
    let mut seq = 5usize;
    while seq <= 128 {
        let c = Contraction { m: 16 * seq, n: 768, k: 2304, dtype: DType::F32 };
        let t_cb = sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead();
        let t_dc = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        t.row(vec![
            seq.to_string(),
            c.m.to_string(),
            if dc.in_sample(c) { "I".into() } else { "O".into() },
            format!("{:.4}", t_cb * 1e3),
            format!("{:.4}", t_dc * 1e3),
            fmt_x(t_cb / t_dc),
        ]);
        seq += 19;
    }
    // Also the exact sample points (the 'DietCode-I' series).
    for &s in &sample_seqs {
        let c = Contraction { m: 16 * s, n: 768, k: 2304, dtype: DType::F32 };
        let t_cb = sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead();
        let t_dc = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        t.row(vec![
            s.to_string(),
            c.m.to_string(),
            "I".into(),
            format!("{:.4}", t_cb * 1e3),
            format!("{:.4}", t_dc * 1e3),
            fmt_x(t_cb / t_dc),
        ]);
    }
    let _ = t.write_csv(&out_dir.join("fig3.csv"));
    vec![t]
}

/// Fig. 5: achieved GFLOPS vs per-level resource usage — the cliff that
/// justifies hardware-limit pruning (§2.3).
pub fn fig5(out_dir: &Path, seed: u64) -> Vec<Table> {
    let mut tables = Vec::new();
    for (hw, backend_name, dtype, problem) in [
        (crate::hw::presets::xeon_8255c(), "avx512_f32", DType::F32, [960usize, 960, 960]),
        (crate::hw::presets::a100(), "cuda_core_f32", DType::F32, [4096, 4096, 4096]),
    ] {
        let sim = Simulator::new(hw.clone(), seed);
        let bi = hw.backend_idx(backend_name).unwrap();
        let mut t = Table::new(
            &format!("Fig. 5 — GEMM GFLOPS vs L1 resource usage ({})", hw.name),
            &["l1_tile", "l1_util_%", "GFLOPS"],
        );
        // Sweep L1 tiles from deep under-utilization past the capacity
        // cliff (Ansor-config-sweep analog).
        for &scale in &[1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96] {
            let l1 = [4 * scale, 4 * scale, 8 * scale];
            let l0 = [4, 4, 8];
            let padded = [
                crate::ir::round_up(problem[0], l1[0]),
                crate::ir::round_up(problem[1], l1[1]),
                crate::ir::round_up(problem[2], l1[2]),
            ];
            let strat = Strategy::new(vec![l0, l1, padded], bi);
            let ws = HwSpec::gemm_working_set(l1, 4);
            let util = 100.0 * ws as f64 / hw.level(1).capacity_bytes as f64;
            let flops = 2.0 * problem.iter().map(|&d| d as f64).product::<f64>();
            let gflops = sim.achieved_gflops(dtype, &strat, flops);
            t.row(vec![
                format!("{}x{}x{}", l1[0], l1[1], l1[2]),
                format!("{:.1}", util),
                format!("{:.1}", gflops),
            ]);
        }
        let _ = t.write_csv(&out_dir.join(format!("fig5_{}.csv", hw.name)));
        tables.push(t);
    }
    tables
}

/// Table 5 + Fig. 12: operator-level speedups over every baseline, all
/// three testbeds, GEMM + Conv suites. `fraction` subsamples the suites
/// (1 = full paper-scale run).
pub fn table5(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let mut summary = Table::new(
        "Table 5 — operator-level speedups of Vortex vs baselines",
        &["Hardware Config", "Operator", "Baseline", "Cases speedup>1 (%)", "Avg (geomean)", "Avg (mean)"],
    );
    let mut fig12 = Table::new(
        "Fig. 12 — per-case speedups (CSV for plotting)",
        &["testbed", "op", "baseline", "category", "case", "gflop", "baseline_secs", "vortex_secs", "speedup"],
    );

    for tb in Testbed::all() {
        let sim = Simulator::new(tb.hw(), seed);
        let vortex = vortex_engine(tb, seed);
        for (op_name, cases) in [
            ("GEMM", workloads::gemm_suite(tb.dtype(), seed)),
            ("Conv.", workloads::conv_suite(tb.dtype(), seed)),
        ] {
            let cases: Vec<_> = cases
                .into_iter()
                .step_by(fraction.max(1))
                .collect();
            let baselines = baseline_engines(tb, op_name == "Conv.", seed);
            let mut aggs: Vec<SpeedupAgg> =
                baselines.iter().map(|_| SpeedupAgg::default()).collect();
            for case in &cases {
                let tv = vortex.time_program(&sim, &case.program);
                for (bi, b) in baselines.iter().enumerate() {
                    let tbse = b.time_program(&sim, &case.program);
                    aggs[bi].push(tbse, tv);
                    fig12.row(vec![
                        tb.label().into(),
                        op_name.into(),
                        b.name().into(),
                        case.category.into(),
                        case.program.id(),
                        format!("{:.3}", case.program.flops() / 1e9),
                        format!("{:.6e}", tbse),
                        format!("{:.6e}", tv),
                        format!("{:.3}", tbse / tv),
                    ]);
                }
            }
            for (b, agg) in baselines.iter().zip(aggs.iter()) {
                summary.row(vec![
                    tb.label().into(),
                    op_name.into(),
                    b.name().into(),
                    format!("{:.1}%", agg.pct_faster()),
                    fmt_x(agg.geomean()),
                    fmt_x(agg.mean()),
                ]);
            }
        }
    }
    let _ = fig12.write_csv(&out_dir.join("fig12.csv"));
    let _ = summary.write_csv(&out_dir.join("table5.csv"));
    vec![summary]
}

/// One case of the launch-composition study: a batched/grouped/fused op
/// executed (a) as the pre-batching host loop — one `gemm_acc` launch
/// chain per group plus host-materialized operands — and (b) as the
/// native `bgemm_acc` path that folds `bb` groups into every launch and
/// gathers operand blocks on demand (`runtime::OperandSource`).
struct CompCase {
    op: &'static str,
    case: &'static str,
    /// Conv groups / batch·heads / batch — the host loop's trip count.
    groups: usize,
    /// Per-group GEMM problem (m, n, k).
    mnk: [usize; 3],
    /// GEMM stages chained per group (attention: score + context).
    kernels: usize,
    /// Per-group f32 elements the host path materializes (im2col patch
    /// matrix `m·kh·kw·cg`, attention's `kt` transpose copy) that the
    /// block-provider path never builds.
    extra_elems: usize,
}

/// The L1 block both paths run, matching the checked-in
/// `bgemm_acc_4x8x128x128_f32` artifact (microkernels.json); the host
/// loop runs its rank-3 tail per group.
const COMP_BLOCK: [usize; 4] = [4, 8, 128, 128];

fn comp_cases() -> Vec<CompCase> {
    let c = |op, case, groups, mnk, kernels, extra_elems| CompCase {
        op,
        case,
        groups,
        mnk,
        kernels,
        extra_elems,
    };
    vec![
        // Plain batched GEMM: batch rides the leading grid axis.
        c("batched_gemm", "bmm_b8_128x256x256", 8, [128, 256, 256], 1, 0),
        c("batched_gemm", "bmm_b16_64x512x64", 16, [64, 512, 64], 1, 0),
        c("batched_gemm", "bmm_b32_448x64x128", 32, [448, 64, 128], 1, 0),
        // Grouped conv (implicit GEMM): m = n·oh·ow, n = cout/g,
        // k = kh·kw·cg; the host path materializes the m×k patch matrix
        // per group.
        c("grouped_conv", "resnext_3x3_g32_14x14", 32, [1568, 8, 72], 1, 1568 * 72),
        c("grouped_conv", "mobilenet_dw3x3_g96_28x28", 96, [3136, 1, 9], 1, 3136 * 9),
        c("grouped_conv", "shuffle_1x1_g8_28x28", 8, [3136, 30, 30], 1, 3136 * 30),
        // Attention: two chained GEMM stages per head group; the host
        // path copies kt (seq·hd) per group before stage 1.
        c("attention", "bert_base_s384_b8h12", 96, [384, 384, 64], 2, 384 * 64),
        c("attention", "gpt_s128_b4h16", 64, [128, 128, 64], 2, 128 * 64),
        c("attention", "long_s512_b2h8", 16, [512, 512, 64], 2, 512 * 64),
    ]
}

/// Operator-generality study + launch-composition model.
///
/// Part 1 (ops.csv): GEMM, batched GEMM, Conv2d, grouped / depthwise
/// conv and the attention-fused chain each compiled through the SAME
/// candgen → compile → select pipeline (one native library per op) and
/// executed in the simulator. Demonstrates the hierarchized strategy
/// space over every registered op — the extension point every new
/// workload plugs into. `fraction` subsamples these suites (CI smoke
/// passes 8).
///
/// Part 2 (BENCH_ops.json): before/after rows for the native-batching
/// runtime, from a deterministic analytic model priced with the
/// cpu_pjrt preset (the testbed `RealEngine` actually runs on). Both
/// paths share the identical padded-FLOP term; they differ only in the
/// terms the PR changed, each taken straight from the preset:
///
/// - launches: host = groups · cells · chain, native =
///   ceil(groups/bb) · cells · chain, each costing
///   `launch_overhead_secs × launch_factor` (the per-`execute_b`
///   dispatch the simulator also charges);
/// - materialization traffic: the host path writes + reads the
///   per-group im2col patch matrix / kt copy through DRAM
///   (`8 · groups · extra_elems` bytes at the preset's DRAM bandwidth);
///   the provider path never allocates it.
///
/// The model is intentionally closed-form — no RNG, no selector — so
/// the committed BENCH_ops.json is bit-reproducible on any machine and
/// CI can regenerate + diff it (`bench-smoke` step).
pub fn ops(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let sim = Simulator::new(tb.hw(), seed);
    let engine = vortex_engine_ops(tb, seed, &OpKind::ALL);
    let crate::bench::harness::Engine::Vortex { selector, .. } = &engine else {
        unreachable!()
    };
    let frac = fraction.max(1);
    let mut t = Table::new(
        "Operator generality — per-op libraries through one pipeline (GPU Tensor Core)",
        &["op", "libraries", "kernels", "cases", "geomean GFLOPS"],
    );
    for op in OpKind::ALL {
        let cases: Vec<workloads::Case> = match op {
            OpKind::Gemm => workloads::gemm_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(40 * frac)
                .collect(),
            OpKind::BatchedGemm => workloads::batched_gemm_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(16 * frac)
                .collect(),
            OpKind::Conv2d => workloads::conv_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(55 * frac)
                .collect(),
            // ResNet-strided cases optimize in the ungrouped conv space;
            // the grouped row takes the depthwise + grouped family.
            OpKind::GroupedConv2d => workloads::conv_family_suite(tb.dtype())
                .into_iter()
                .filter(|c| {
                    matches!(c.program, crate::ir::TensorProgram::Conv2d { groups, .. }
                        if groups > 1)
                })
                .step_by(frac)
                .collect(),
            // The fused chain: seq-swept attention head groups.
            OpKind::FusedAttention => workloads::attention_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(4 * frac)
                .collect(),
            // Decode attention is a serving-path property (per-token
            // dispatch over a growing seq_k), measured by its own
            // bench (`bench decode`); its aliased two-kernel chain
            // duplicates the batched-GEMM row at the op level, so it
            // adds no row here and the committed BENCH_ops.json is
            // unchanged.
            OpKind::CausalAttention => continue,
        };
        let libs = selector.libraries.iter().filter(|l| l.op == op).count();
        let kernels: usize = selector
            .libraries
            .iter()
            .filter(|l| l.op == op)
            .map(|l| l.kernels.len())
            .sum();
        let mut log_gflops = 0.0;
        for case in &cases {
            let secs = engine.time_program(&sim, &case.program);
            log_gflops += (case.program.flops() / secs / 1e9).ln();
        }
        t.row(vec![
            op.name().into(),
            libs.to_string(),
            kernels.to_string(),
            cases.len().to_string(),
            format!("{:.1}", (log_gflops / cases.len() as f64).exp()),
        ]);
    }
    let _ = t.write_csv(&out_dir.join("ops.csv"));

    // Part 2: the launch-composition model (see the doc comment).
    let hw = crate::hw::presets::cpu_pjrt();
    let bi = hw.backend_idx("mxu_f32").unwrap();
    let launch = hw.launch_overhead_secs * hw.backends[bi].launch_factor;
    let bw = hw.levels.last().unwrap().load_bw_gbps * 1e9;
    let peak = hw.backends[bi].peak_gflops * 1e9;
    let [bb, bm, bn, bk] = COMP_BLOCK;
    let mut comp = Table::new(
        "Launch composition — host-loop vs native batched runtime (cpu_pjrt model)",
        &["op", "case", "groups", "l_host", "l_native", "host (ms)", "native (ms)", "speedup"],
    );
    let mut rows = Vec::new();
    let mut logs: Vec<(&'static str, f64, usize)> = Vec::new();
    for c in comp_cases() {
        let [m, n, k] = c.mnk;
        let cells = ceil_div(m, bm) * ceil_div(n, bn);
        let chain = ceil_div(k, bk);
        let l_host = c.groups * cells * chain;
        let l_native = ceil_div(c.groups, bb) * cells * chain;
        let padded = c.groups * (cells * bm * bn) * (chain * bk);
        let compute = 2.0 * padded as f64 / peak;
        let extra = (8 * c.groups * c.extra_elems) as f64 / bw;
        let kf = c.kernels as f64;
        let sched = crate::serve::SCHED_OVERHEAD_SECS;
        let host = kf * (compute + l_host as f64 * launch) + extra + sched;
        let native = kf * (compute + l_native as f64 * launch) + sched;
        let speedup = host / native;
        comp.row(vec![
            c.op.into(),
            c.case.into(),
            c.groups.to_string(),
            l_host.to_string(),
            l_native.to_string(),
            format!("{:.3}", host * 1e3),
            format!("{:.3}", native * 1e3),
            fmt_x(speedup),
        ]);
        rows.push(Json::obj(vec![
            ("op", Json::str(c.op)),
            ("case", Json::str(c.case)),
            ("groups", Json::num(c.groups as f64)),
            ("m", Json::num(m as f64)),
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
            ("kernels", Json::num(c.kernels as f64)),
            ("extra_elems", Json::num(c.extra_elems as f64)),
            ("launches_host", Json::num(l_host as f64)),
            ("launches_native", Json::num(l_native as f64)),
            ("host_loop_secs", Json::num(host)),
            ("native_secs", Json::num(native)),
            ("speedup", Json::num(speedup)),
        ]));
        match logs.iter_mut().find(|(op, ..)| *op == c.op) {
            Some((_, s, cnt)) => {
                *s += speedup.ln();
                *cnt += 1;
            }
            None => logs.push((c.op, speedup.ln(), 1)),
        }
    }
    let mut geo: Vec<(&str, Json)> = Vec::new();
    let mut all = (0.0, 0usize);
    for &(op, s, cnt) in &logs {
        geo.push((op, Json::num((s / cnt as f64).exp())));
        all.0 += s;
        all.1 += cnt;
    }
    geo.push(("overall", Json::num((all.0 / all.1 as f64).exp())));
    let report = Json::obj(vec![
        ("schema", Json::str("vortex-bench-ops-v1")),
        ("testbed", Json::str(hw.name)),
        ("block", Json::arr(COMP_BLOCK.iter().map(|&v| Json::num(v as f64)).collect())),
        ("launch_overhead_secs", Json::num(launch)),
        ("sched_overhead_secs", Json::num(crate::serve::SCHED_OVERHEAD_SECS)),
        ("dram_gbps", Json::num(hw.levels.last().unwrap().load_bw_gbps)),
        ("peak_gflops", Json::num(hw.backends[bi].peak_gflops)),
        ("rows", Json::arr(rows)),
        ("geomean_speedup", Json::obj(geo)),
    ]);
    let _ = std::fs::write(out_dir.join("BENCH_ops.json"), report.dump() + "\n");
    vec![t, comp]
}

/// Table 6: Vortex vs DietCode across M ranges, with DietCode sampled
/// only inside [128, 256).
pub fn table6(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);
    let vortex = vortex_engine(tb, seed);
    // Sample/compile DietCode within [128, 256) only (paper setup).
    let samples: Vec<[usize; 3]> =
        [128usize, 160, 192, 224].iter().map(|&m| [m, 768, 2304]).collect();
    let mut prof = SimProfiler::new(sim.clone());
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 80, &mut prof, seed);

    let mut aggs = [SpeedupAgg::default(), SpeedupAgg::default(), SpeedupAgg::default()];
    let ranges = [(1usize, 127usize), (128, 255), (256, 384)];
    // 96 test cases spread over [1, 384] (paper: 96 cases).
    for i in 0..96 {
        let m = 1 + i * 383 / 95;
        let c = Contraction { m, n: 768, k: 2304, dtype: DType::F32 };
        let tv = vortex.time(&sim, c);
        let td = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        for (ri, (lo, hi)) in ranges.iter().enumerate() {
            if (*lo..=*hi).contains(&m) {
                aggs[ri].push(td, tv);
            }
        }
    }
    let mut t = Table::new(
        "Table 6 — Vortex speedup over DietCode by M range (sampled in [128,256))",
        &["Input range for M", "[0,128)", "[128,256)", "[256,384]"],
    );
    t.row(vec![
        "Avg. speedups".into(),
        fmt_x(aggs[0].geomean()),
        fmt_x(aggs[1].geomean()),
        fmt_x(aggs[2].geomean()),
    ]);
    let _ = t.write_csv(&out_dir.join("table6.csv"));
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_writes_composition_report_with_real_speedups() {
        let dir = std::env::temp_dir().join("vortex_bench_ops_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tables = ops(&dir, 7, 8);
        assert_eq!(tables.len(), 2, "generality + composition tables");
        let text = std::fs::read_to_string(dir.join("BENCH_ops.json")).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str().unwrap(), "vortex-bench-ops-v1");
        assert_eq!(v.get("testbed").unwrap().as_str().unwrap(), "cpu_pjrt");
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), comp_cases().len());
        for op in ["batched_gemm", "grouped_conv", "attention"] {
            assert!(
                rows.iter().any(|r| r.get("op").unwrap().as_str().unwrap() == op),
                "no {} row",
                op
            );
            let g = v.get("geomean_speedup").unwrap().get(op).unwrap().as_f64().unwrap();
            assert!(g > 1.0, "{} geomean {} not a speedup", op, g);
        }
        for r in rows {
            let host = r.get("host_loop_secs").unwrap().as_f64().unwrap();
            let native = r.get("native_secs").unwrap().as_f64().unwrap();
            let speedup = r.get("speedup").unwrap().as_f64().unwrap();
            assert!(host.is_finite() && native > 0.0);
            assert!(speedup > 1.0, "{:?}: native path not faster", r.get("case"));
            assert!((speedup - host / native).abs() < 1e-12);
            // The native path never launches more chains than the loop.
            let lh = r.get("launches_host").unwrap().as_usize().unwrap();
            let ln = r.get("launches_native").unwrap().as_usize().unwrap();
            assert!(ln < lh, "batching did not reduce launches");
        }
        let overall =
            v.get("geomean_speedup").unwrap().get("overall").unwrap().as_f64().unwrap();
        assert!(overall > 1.0, "overall geomean {}", overall);
        // Deterministic: independent of seed and fraction (the model has
        // no RNG), so CI can regenerate and diff the committed file.
        let dir2 = std::env::temp_dir().join("vortex_bench_ops_test2");
        std::fs::create_dir_all(&dir2).unwrap();
        ops(&dir2, 99, 16);
        let text2 = std::fs::read_to_string(dir2.join("BENCH_ops.json")).unwrap();
        assert_eq!(text, text2, "BENCH_ops.json must not depend on seed/fraction");
    }
}
