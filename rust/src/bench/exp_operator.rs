//! Operator-level experiments: Fig. 3, Fig. 5, Table 5 / Fig. 12,
//! Table 6.

use std::path::Path;

use crate::baselines::dietcode::DietCode;
use crate::baselines::vendor::VendorLib;
use crate::baselines::PlanEngine;
use crate::bench::harness::{
    baseline_engines, vortex_engine, vortex_engine_ops, SpeedupAgg, Testbed,
};
use crate::bench::workloads;
use crate::cost::Strategy;
use crate::hw::HwSpec;
use crate::ir::{Contraction, DType, OpKind};
use crate::profiler::SimProfiler;
use crate::sim::Simulator;
use crate::util::table::{fmt_x, Table};

/// Fig. 3: DietCode in-sample vs out-of-sample vs cuBLAS on the BERT
/// GEMM-1 (M = 16 x seq, N = 768, K = 2304), A100 CUDA cores.
pub fn fig3(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);
    // DietCode's default sample configuration: a seq-length grid; the
    // test sweep (5..=128 step 19) mostly falls BETWEEN samples.
    let sample_seqs = [32usize, 64, 96, 128];
    let samples: Vec<[usize; 3]> =
        sample_seqs.iter().map(|&s| [16 * s, 768, 2304]).collect();
    let mut prof = SimProfiler::new(sim.clone());
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 80, &mut prof, seed);
    let cublas = VendorLib::cublas(&hw, "cuda_core_f32");

    let mut t = Table::new(
        "Fig. 3 — DietCode vs cuBLAS over sequence length (BERT GEMM-1, A100 CUDA cores)",
        &["seq", "M", "in_sample", "cuBLAS (ms)", "DietCode (ms)", "DietCode/cuBLAS speedup"],
    );
    let mut seq = 5usize;
    while seq <= 128 {
        let c = Contraction { m: 16 * seq, n: 768, k: 2304, dtype: DType::F32 };
        let t_cb = sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead();
        let t_dc = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        t.row(vec![
            seq.to_string(),
            c.m.to_string(),
            if dc.in_sample(c) { "I".into() } else { "O".into() },
            format!("{:.4}", t_cb * 1e3),
            format!("{:.4}", t_dc * 1e3),
            fmt_x(t_cb / t_dc),
        ]);
        seq += 19;
    }
    // Also the exact sample points (the 'DietCode-I' series).
    for &s in &sample_seqs {
        let c = Contraction { m: 16 * s, n: 768, k: 2304, dtype: DType::F32 };
        let t_cb = sim.execute(DType::F32, &cublas.plan(c)) + cublas.dispatch_overhead();
        let t_dc = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        t.row(vec![
            s.to_string(),
            c.m.to_string(),
            "I".into(),
            format!("{:.4}", t_cb * 1e3),
            format!("{:.4}", t_dc * 1e3),
            fmt_x(t_cb / t_dc),
        ]);
    }
    let _ = t.write_csv(&out_dir.join("fig3.csv"));
    vec![t]
}

/// Fig. 5: achieved GFLOPS vs per-level resource usage — the cliff that
/// justifies hardware-limit pruning (§2.3).
pub fn fig5(out_dir: &Path, seed: u64) -> Vec<Table> {
    let mut tables = Vec::new();
    for (hw, backend_name, dtype, problem) in [
        (crate::hw::presets::xeon_8255c(), "avx512_f32", DType::F32, [960usize, 960, 960]),
        (crate::hw::presets::a100(), "cuda_core_f32", DType::F32, [4096, 4096, 4096]),
    ] {
        let sim = Simulator::new(hw.clone(), seed);
        let bi = hw.backend_idx(backend_name).unwrap();
        let mut t = Table::new(
            &format!("Fig. 5 — GEMM GFLOPS vs L1 resource usage ({})", hw.name),
            &["l1_tile", "l1_util_%", "GFLOPS"],
        );
        // Sweep L1 tiles from deep under-utilization past the capacity
        // cliff (Ansor-config-sweep analog).
        for &scale in &[1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96] {
            let l1 = [4 * scale, 4 * scale, 8 * scale];
            let l0 = [4, 4, 8];
            let padded = [
                crate::ir::round_up(problem[0], l1[0]),
                crate::ir::round_up(problem[1], l1[1]),
                crate::ir::round_up(problem[2], l1[2]),
            ];
            let strat = Strategy::new(vec![l0, l1, padded], bi);
            let ws = HwSpec::gemm_working_set(l1, 4);
            let util = 100.0 * ws as f64 / hw.level(1).capacity_bytes as f64;
            let flops = 2.0 * problem.iter().map(|&d| d as f64).product::<f64>();
            let gflops = sim.achieved_gflops(dtype, &strat, flops);
            t.row(vec![
                format!("{}x{}x{}", l1[0], l1[1], l1[2]),
                format!("{:.1}", util),
                format!("{:.1}", gflops),
            ]);
        }
        let _ = t.write_csv(&out_dir.join(format!("fig5_{}.csv", hw.name)));
        tables.push(t);
    }
    tables
}

/// Table 5 + Fig. 12: operator-level speedups over every baseline, all
/// three testbeds, GEMM + Conv suites. `fraction` subsamples the suites
/// (1 = full paper-scale run).
pub fn table5(out_dir: &Path, seed: u64, fraction: usize) -> Vec<Table> {
    let mut summary = Table::new(
        "Table 5 — operator-level speedups of Vortex vs baselines",
        &["Hardware Config", "Operator", "Baseline", "Cases speedup>1 (%)", "Avg (geomean)", "Avg (mean)"],
    );
    let mut fig12 = Table::new(
        "Fig. 12 — per-case speedups (CSV for plotting)",
        &["testbed", "op", "baseline", "category", "case", "gflop", "baseline_secs", "vortex_secs", "speedup"],
    );

    for tb in Testbed::all() {
        let sim = Simulator::new(tb.hw(), seed);
        let vortex = vortex_engine(tb, seed);
        for (op_name, cases) in [
            ("GEMM", workloads::gemm_suite(tb.dtype(), seed)),
            ("Conv.", workloads::conv_suite(tb.dtype(), seed)),
        ] {
            let cases: Vec<_> = cases
                .into_iter()
                .step_by(fraction.max(1))
                .collect();
            let baselines = baseline_engines(tb, op_name == "Conv.", seed);
            let mut aggs: Vec<SpeedupAgg> =
                baselines.iter().map(|_| SpeedupAgg::default()).collect();
            for case in &cases {
                let tv = vortex.time_program(&sim, &case.program);
                for (bi, b) in baselines.iter().enumerate() {
                    let tbse = b.time_program(&sim, &case.program);
                    aggs[bi].push(tbse, tv);
                    fig12.row(vec![
                        tb.label().into(),
                        op_name.into(),
                        b.name().into(),
                        case.category.into(),
                        case.program.id(),
                        format!("{:.3}", case.program.flops() / 1e9),
                        format!("{:.6e}", tbse),
                        format!("{:.6e}", tv),
                        format!("{:.3}", tbse / tv),
                    ]);
                }
            }
            for (b, agg) in baselines.iter().zip(aggs.iter()) {
                summary.row(vec![
                    tb.label().into(),
                    op_name.into(),
                    b.name().into(),
                    format!("{:.1}%", agg.pct_faster()),
                    fmt_x(agg.geomean()),
                    fmt_x(agg.mean()),
                ]);
            }
        }
    }
    let _ = fig12.write_csv(&out_dir.join("fig12.csv"));
    let _ = summary.write_csv(&out_dir.join("table5.csv"));
    vec![summary]
}

/// Operator-generality study: GEMM, batched GEMM, Conv2d, grouped /
/// depthwise conv and the attention-fused chain each compiled through
/// the SAME candgen → compile → select pipeline (one native library
/// per op) and executed in the simulator. Demonstrates the
/// hierarchized strategy space over every registered op — the
/// extension point every new workload plugs into.
pub fn ops(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuTensorCore;
    let sim = Simulator::new(tb.hw(), seed);
    let engine = vortex_engine_ops(tb, seed, &OpKind::ALL);
    let crate::bench::harness::Engine::Vortex { selector, .. } = &engine else {
        unreachable!()
    };
    let mut t = Table::new(
        "Operator generality — per-op libraries through one pipeline (GPU Tensor Core)",
        &["op", "libraries", "kernels", "cases", "geomean GFLOPS"],
    );
    for op in OpKind::ALL {
        let cases: Vec<workloads::Case> = match op {
            OpKind::Gemm => workloads::gemm_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(40)
                .collect(),
            OpKind::BatchedGemm => workloads::batched_gemm_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(16)
                .collect(),
            OpKind::Conv2d => workloads::conv_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(55)
                .collect(),
            // ResNet-strided cases optimize in the ungrouped conv space;
            // the grouped row takes the depthwise + grouped family.
            OpKind::GroupedConv2d => workloads::conv_family_suite(tb.dtype())
                .into_iter()
                .filter(|c| {
                    matches!(c.program, crate::ir::TensorProgram::Conv2d { groups, .. }
                        if groups > 1)
                })
                .collect(),
            // The fused chain: seq-swept attention head groups.
            OpKind::FusedAttention => workloads::attention_suite(tb.dtype(), seed)
                .into_iter()
                .step_by(4)
                .collect(),
        };
        let libs = selector.libraries.iter().filter(|l| l.op == op).count();
        let kernels: usize = selector
            .libraries
            .iter()
            .filter(|l| l.op == op)
            .map(|l| l.kernels.len())
            .sum();
        let mut log_gflops = 0.0;
        for case in &cases {
            let secs = engine.time_program(&sim, &case.program);
            log_gflops += (case.program.flops() / secs / 1e9).ln();
        }
        t.row(vec![
            op.name().into(),
            libs.to_string(),
            kernels.to_string(),
            cases.len().to_string(),
            format!("{:.1}", (log_gflops / cases.len() as f64).exp()),
        ]);
    }
    let _ = t.write_csv(&out_dir.join("ops.csv"));
    vec![t]
}

/// Table 6: Vortex vs DietCode across M ranges, with DietCode sampled
/// only inside [128, 256).
pub fn table6(out_dir: &Path, seed: u64) -> Vec<Table> {
    let tb = Testbed::GpuCudaCore;
    let hw = tb.hw();
    let sim = Simulator::new(hw.clone(), seed);
    let vortex = vortex_engine(tb, seed);
    // Sample/compile DietCode within [128, 256) only (paper setup).
    let samples: Vec<[usize; 3]> =
        [128usize, 160, 192, 224].iter().map(|&m| [m, 768, 2304]).collect();
    let mut prof = SimProfiler::new(sim.clone());
    let dc = DietCode::tune(&hw, "cuda_core_f32", &samples, 80, &mut prof, seed);

    let mut aggs = [SpeedupAgg::default(), SpeedupAgg::default(), SpeedupAgg::default()];
    let ranges = [(1usize, 127usize), (128, 255), (256, 384)];
    // 96 test cases spread over [1, 384] (paper: 96 cases).
    for i in 0..96 {
        let m = 1 + i * 383 / 95;
        let c = Contraction { m, n: 768, k: 2304, dtype: DType::F32 };
        let tv = vortex.time(&sim, c);
        let td = sim.execute(DType::F32, &dc.plan(c)) + dc.dispatch_overhead();
        for (ri, (lo, hi)) in ranges.iter().enumerate() {
            if (*lo..=*hi).contains(&m) {
                aggs[ri].push(td, tv);
            }
        }
    }
    let mut t = Table::new(
        "Table 6 — Vortex speedup over DietCode by M range (sampled in [128,256))",
        &["Input range for M", "[0,128)", "[128,256)", "[256,384]"],
    );
    t.row(vec![
        "Avg. speedups".into(),
        fmt_x(aggs[0].geomean()),
        fmt_x(aggs[1].geomean()),
        fmt_x(aggs[2].geomean()),
    ]);
    let _ = t.write_csv(&out_dir.join("table6.csv"));
    vec![t]
}
