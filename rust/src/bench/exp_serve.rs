//! Serving-layer benchmark: a mixed multi-op trace (BERT token traffic
//! interleaved with vision bursts) through the request lanes under
//! THREE dispatch configurations — compile-time dispatch table (plan
//! cache demoted to the beyond-horizon fallback), PR 4's reactive plan
//! cache alone, and fresh per-batch selection — span, tail latency,
//! scheduling fraction and tri-state hit accounting, written to
//! `serve.csv` and `BENCH_serve.json`.
//!
//! The fresh run is the correctness baseline: identical per-request
//! selections are REQUIRED under every configuration (the table's and
//! the cache's shared guarantee), and the event clock charges a
//! modeled scheduling overhead either way — so the only delta is the
//! MEASURED scheduling seconds (`Metrics`'s sched component). The
//! table's additional claim over the cache is *zero warm-up*: no cold
//! misses at all when the configured envelope covers the traffic
//! (`dispatch.fresh == 0`), versus the cache's one fresh scan per
//! bucket.
//!
//! Fleet rows ride along: the same trace sharded across 4 replicas
//! under hash routing, with the worker-pool run re-checked
//! bitwise-equivalent against the sequential replay on every bench run
//! (`fleet.executor_equivalent` — the determinism-oracle contract of
//! [`crate::serve::serve_fleet`]). CI schema-validates the emitted
//! report against `results/BENCH_serve.json`.

use std::path::Path;

use crate::hw::presets;
use crate::ir::DType;
use crate::serve::{
    scenario, serve_fleet, serve_mixed_trace, FleetConfig, FleetStats, MixedStats,
    RoutePolicy, SimLaneEngine,
};
use crate::sim::Simulator;
use crate::util::json::Json;
use crate::util::table::{fmt_secs, Table};

/// Fraction of warm outcomes (plan from the dispatch table OR a cache
/// hit — anything but a fresh scan) after the warmup prefix (first
/// half of the request stream) — the steady-state rate the acceptance
/// gate asserts on.
pub fn warm_hit_rate(stats: &MixedStats) -> f64 {
    let warm = &stats.outcomes[stats.outcomes.len() / 2..];
    if warm.is_empty() {
        return 0.0;
    }
    warm.iter().filter(|o| o.warm()).count() as f64 / warm.len() as f64
}

/// True when both runs picked the same plan for every request
/// (plan identity is [`crate::coordinator::Selection::same_plan`]).
pub fn identical_selections(a: &MixedStats, b: &MixedStats) -> bool {
    a.outcomes.len() == b.outcomes.len()
        && a.outcomes.iter().zip(&b.outcomes).all(|(x, y)| {
            x.id == y.id
                && x.lane == y.lane
                && x.batch_size == y.batch_size
                && x.selection.same_plan(&y.selection)
        })
}

/// True when two FLEET runs are bitwise indistinguishable: same
/// per-request plans, sources, replicas, launch/latency BITS and the
/// same drop log. This is the determinism-oracle contract the bench
/// re-checks on every run (worker pool vs sequential replay).
pub fn equivalent_fleet_runs(a: &FleetStats, b: &FleetStats) -> bool {
    a.outcomes.len() == b.outcomes.len()
        && a.drops.len() == b.drops.len()
        && a.outcomes.iter().zip(&b.outcomes).all(|(x, y)| {
            x.id == y.id
                && x.replica == y.replica
                && x.lane == y.lane
                && x.batch_size == y.batch_size
                && x.source == y.source
                && x.degraded == y.degraded
                && x.latency.to_bits() == y.latency.to_bits()
                && x.launch.to_bits() == y.launch.to_bits()
                && x.selection.same_plan(&y.selection)
        })
        && a.drops.iter().zip(&b.drops).all(|(x, y)| {
            x.id == y.id
                && x.replica == y.replica
                && x.decided_at.to_bits() == y.decided_at.to_bits()
                && x.miss_by.to_bits() == y.miss_by.to_bits()
        })
}

/// The per-lane results table — shared by this bench and the
/// `vortex serve --mixed` CLI so the two reports cannot drift.
pub fn lanes_table(title: &str, stats: &MixedStats) -> Table {
    let mut t = Table::new(
        title,
        &["lane", "requests", "batches", "units", "p50", "p99", "sched %"],
    );
    for l in &stats.lanes {
        let (p50, _, p99) = l.metrics.latency_percentiles();
        t.row(vec![
            l.class.name().into(),
            l.metrics.count().to_string(),
            l.batches.to_string(),
            l.total_units.to_string(),
            fmt_secs(p50),
            fmt_secs(p99),
            format!("{:.2}", 100.0 * l.metrics.sched_fraction()),
        ]);
    }
    t
}

pub fn serve(out_dir: &Path, seed: u64, frac: usize) -> Vec<Table> {
    let hw = presets::a100();
    let selector = scenario::demo_selector(seed);

    // The acceptance gate requires >= 200 requests even in fast mode.
    let n = (600 / frac.max(1)).max(240);
    let trace = scenario::mixed_trace(n, 4e-4, seed, DType::F32);
    let serve_cfg = scenario::serving_config();

    let run = |cfg: &crate::serve::ServeConfig| {
        let mut engine = SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
        serve_mixed_trace(&mut engine, &selector, cfg, &trace)
    };
    // The headline run records a span trace — zero-perturbation by
    // contract (the fleet oracle proves it), so the traced run IS the
    // benchmark run and the shipped trace matches the shipped numbers.
    let table = run(&serve_cfg.with_dispatch(scenario::dispatch_config()).traced());
    let cached = run(&serve_cfg);
    let baseline = run(&serve_cfg.without_cache());
    let identical = identical_selections(&cached, &baseline)
        && identical_selections(&table, &baseline);
    let warm_rate = warm_hit_rate(&cached);
    let table_warm = warm_hit_rate(&table);

    // Fleet rows: the same trace sharded across 4 replicas (hash
    // routing, dispatch tables cloned per replica), once on the
    // sequential discrete-event replay and once on the worker pool —
    // the two must be bitwise-equivalent (the determinism oracle).
    let make_engine = || SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
    let fleet_cfg = |workers: usize| FleetConfig {
        replicas: 4,
        workers,
        routing: RoutePolicy::HashKey,
        serve: serve_cfg.with_dispatch(scenario::dispatch_config()),
    };
    let fleet = serve_fleet(make_engine, &selector, &fleet_cfg(0), &trace);
    let fleet_pool = serve_fleet(make_engine, &selector, &fleet_cfg(2), &trace);
    let executor_equivalent = equivalent_fleet_runs(&fleet, &fleet_pool);

    let lanes = lanes_table("serving lanes (dispatch table ON, simulated A100)", &table);

    let mut cmp = Table::new(
        "dispatch table vs plan cache vs fresh",
        &["config", "span", "p99", "sched secs", "table/cache/fresh", "warm start"],
    );
    let row = |t: &mut Table, name: &str, s: &MixedStats| {
        let (_, _, p99) = s.latency_percentiles();
        t.row(vec![
            name.into(),
            fmt_secs(s.span_secs),
            fmt_secs(p99),
            fmt_secs(s.total_sched_secs()),
            format!("{}/{}/{}", s.dispatch.table, s.dispatch.cache, s.dispatch.fresh),
            format!("{:.3}", s.dispatch.warm_start_rate()),
        ]);
    };
    row(&mut cmp, "table", &table);
    row(&mut cmp, "cached", &cached);
    row(&mut cmp, "fresh", &baseline);
    {
        let (_, _, f99) = fleet.latency_percentiles();
        cmp.row(vec![
            format!("fleet x4 ({})", RoutePolicy::HashKey.name()),
            fmt_secs(fleet.span_secs),
            fmt_secs(f99),
            String::new(),
            format!(
                "{}/{}/{}",
                fleet.dispatch.table, fleet.dispatch.cache, fleet.dispatch.fresh
            ),
            format!("executor ok: {executor_equivalent}"),
        ]);
    }
    cmp.row(vec![
        "identical selections".into(),
        identical.to_string(),
        String::new(),
        format!(
            "{:.2}x less vs fresh",
            baseline.total_sched_secs() / table.total_sched_secs().max(1e-12)
        ),
        String::new(),
        String::new(),
    ]);

    let (c50, _, c99) = cached.latency_percentiles();
    let (t50, _, t99) = table.latency_percentiles();
    let (_, _, b99) = baseline.latency_percentiles();
    let build = table.dispatch_build.clone().unwrap_or_default();
    let (f50, _, f99) = fleet.latency_percentiles();
    let json = Json::obj(vec![
        ("schema", Json::str("vortex-bench-serve-v1")),
        ("requests", Json::num(trace.len() as f64)),
        ("lanes", Json::num(table.lanes.len() as f64)),
        ("span_secs", Json::num(table.span_secs)),
        ("p50_secs", Json::num(t50)),
        ("p99_secs", Json::num(t99)),
        ("sched_secs", Json::num(table.total_sched_secs())),
        ("sched_fraction", Json::num(table.sched_fraction())),
        (
            "dispatch",
            Json::obj(vec![
                ("table_hits", Json::num(table.dispatch.table as f64)),
                ("cache_hits", Json::num(table.dispatch.cache as f64)),
                ("fresh", Json::num(table.dispatch.fresh as f64)),
                ("warm_start_rate", Json::num(table.dispatch.warm_start_rate())),
                ("warm_start_rate_warm_half", Json::num(table_warm)),
                ("tables", Json::num(build.tables as f64)),
                ("cells", Json::num(build.cells as f64)),
                ("cells_enumerated", Json::num(build.cells_enumerated as f64)),
                ("build_secs", Json::num(build.build_secs)),
                ("clamped", Json::Bool(build.clamped)),
                (
                    "sched_vs_cache",
                    Json::num(
                        table.total_sched_secs() / cached.total_sched_secs().max(1e-12),
                    ),
                ),
            ]),
        ),
        (
            "plan_cache",
            Json::obj(vec![
                ("span_secs", Json::num(cached.span_secs)),
                ("p50_secs", Json::num(c50)),
                ("p99_secs", Json::num(c99)),
                ("sched_secs", Json::num(cached.total_sched_secs())),
                ("hits", Json::num(cached.cache.hits as f64)),
                ("misses", Json::num(cached.cache.misses as f64)),
                ("evictions", Json::num(cached.cache.evictions as f64)),
                ("hit_rate", Json::num(cached.cache.hit_rate())),
                ("hit_rate_warm", Json::num(warm_rate)),
            ]),
        ),
        (
            "baseline",
            Json::obj(vec![
                ("span_secs", Json::num(baseline.span_secs)),
                ("p99_secs", Json::num(b99)),
                ("sched_secs", Json::num(baseline.total_sched_secs())),
                ("sched_fraction", Json::num(baseline.sched_fraction())),
            ]),
        ),
        (
            "fleet",
            Json::obj(vec![
                ("replicas", Json::num(4.0)),
                ("workers", Json::num(2.0)),
                ("routing", Json::str(RoutePolicy::HashKey.name())),
                ("span_secs", Json::num(fleet.span_secs)),
                ("p50_secs", Json::num(f50)),
                ("p99_secs", Json::num(f99)),
                ("offered", Json::num(fleet.offered() as f64)),
                ("admitted", Json::num(fleet.admitted() as f64)),
                ("degraded", Json::num(fleet.degraded() as f64)),
                ("dropped", Json::num(fleet.drops.len() as f64)),
                ("table_hits", Json::num(fleet.dispatch.table as f64)),
                ("cache_hits", Json::num(fleet.dispatch.cache as f64)),
                ("fresh", Json::num(fleet.dispatch.fresh as f64)),
                (
                    "span_speedup_vs_single",
                    Json::num(table.span_secs / fleet.span_secs.max(1e-12)),
                ),
                ("executor_equivalent", Json::Bool(executor_equivalent)),
            ]),
        ),
        (
            "sched_speedup",
            Json::num(baseline.total_sched_secs() / table.total_sched_secs().max(1e-12)),
        ),
        ("identical_selections", Json::Bool(identical)),
    ]);
    let _ = std::fs::write(out_dir.join("BENCH_serve.json"), json.dump());
    if let Some(t) = &table.trace {
        let _ = std::fs::write(out_dir.join("serve_trace.json"), t.to_chrome_json());
    }
    let _ = lanes.write_csv(&out_dir.join("serve.csv"));
    vec![lanes, cmp]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_writes_report_with_identical_selections() {
        let dir = std::env::temp_dir().join("vortex_bench_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tables = serve(&dir, 7, 8);
        assert_eq!(tables.len(), 2);
        let text = std::fs::read_to_string(dir.join("BENCH_serve.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("vortex-bench-serve-v1"));
        assert!(j.get("requests").unwrap().as_f64().unwrap() >= 200.0);
        assert_eq!(j.get("identical_selections").unwrap().as_bool(), Some(true));
        // Fleet rows: every request accounted for, and the worker pool
        // reproduced the sequential replay bitwise.
        let f = j.get("fleet").unwrap();
        assert_eq!(f.get("executor_equivalent").unwrap().as_bool(), Some(true));
        assert_eq!(
            f.get("offered").unwrap().as_f64().unwrap(),
            j.get("requests").unwrap().as_f64().unwrap()
        );
        assert_eq!(
            f.get("admitted").unwrap().as_f64().unwrap()
                + f.get("degraded").unwrap().as_f64().unwrap()
                + f.get("dropped").unwrap().as_f64().unwrap(),
            f.get("offered").unwrap().as_f64().unwrap()
        );
        assert_eq!(f.get("dropped").unwrap().as_f64().unwrap(), 0.0);
        let d = j.get("dispatch").unwrap();
        let requests = j.get("requests").unwrap().as_f64().unwrap();
        let table_hits = d.get("table_hits").unwrap().as_f64().unwrap();
        let cache_hits = d.get("cache_hits").unwrap().as_f64().unwrap();
        let fresh = d.get("fresh").unwrap().as_f64().unwrap();
        // Tri-state accounting covers every request.
        assert_eq!(table_hits + cache_hits + fresh, requests);
        assert!(table_hits > 0.0, "dispatch table answered nothing");
        // Zero warm-up: when the envelope fit the cell budget (no
        // clamping), EVERY request is answered without a fresh scan —
        // a 100% warm-start rate from request 1.
        if d.get("clamped").unwrap().as_bool() == Some(false) {
            assert_eq!(fresh, 0.0, "cold miss despite full table coverage");
            assert_eq!(
                d.get("warm_start_rate").unwrap().as_f64().unwrap(),
                1.0
            );
        }
        // The PR 4 cache path still reports its own hits for the
        // beyond-horizon fallback comparison.
        assert!(
            j.get("plan_cache").unwrap().get("hits").unwrap().as_f64().unwrap() > 0.0
        );
        // The headline run also ships its Chrome trace: it parses back,
        // audits clean, and re-emits byte-identically (the round-trip
        // contract CI's trace-schema step leans on).
        let trace_text = std::fs::read_to_string(dir.join("serve_trace.json")).unwrap();
        let t = crate::obs::Trace::from_chrome_json(&trace_text).unwrap();
        assert!(!t.is_empty(), "benchmark trace recorded no spans");
        let report = crate::analysis::audit_trace(&t);
        assert!(
            report.is_clean(true),
            "trace-schema audit: {:?}",
            report.diagnostics.iter().map(|d| d.to_string()).collect::<Vec<_>>()
        );
        assert_eq!(t.to_chrome_json(), trace_text, "re-emission is not byte-identical");
    }
}
