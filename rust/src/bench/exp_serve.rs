//! Serving-layer benchmark: a mixed multi-op trace (BERT token traffic
//! interleaved with vision bursts) through the request lanes, plan
//! cache ON vs OFF — span, tail latency, scheduling fraction and cache
//! hit rate, written to `serve.csv` and `BENCH_serve.json`.
//!
//! The cache-disabled run is the correctness baseline: identical
//! per-request selections are REQUIRED (the plan cache's guarantee),
//! and the event clock charges a modeled scheduling overhead either
//! way — so the only delta is the MEASURED scheduling seconds
//! (`Metrics`'s sched component), which the cache collapses.

use std::path::Path;

use crate::hw::presets;
use crate::ir::DType;
use crate::serve::{scenario, serve_mixed_trace, MixedStats, SimLaneEngine};
use crate::sim::Simulator;
use crate::util::json::Json;
use crate::util::table::{fmt_secs, Table};

/// Fraction of cache_hit outcomes after the warmup prefix (first half
/// of the request stream) — the steady-state hit rate the acceptance
/// gate asserts on.
pub fn warm_hit_rate(stats: &MixedStats) -> f64 {
    let warm = &stats.outcomes[stats.outcomes.len() / 2..];
    if warm.is_empty() {
        return 0.0;
    }
    warm.iter().filter(|o| o.cache_hit).count() as f64 / warm.len() as f64
}

/// True when both runs picked the same plan for every request
/// (plan identity is [`crate::coordinator::Selection::same_plan`]).
pub fn identical_selections(a: &MixedStats, b: &MixedStats) -> bool {
    a.outcomes.len() == b.outcomes.len()
        && a.outcomes.iter().zip(&b.outcomes).all(|(x, y)| {
            x.id == y.id
                && x.lane == y.lane
                && x.batch_size == y.batch_size
                && x.selection.same_plan(&y.selection)
        })
}

/// The per-lane results table — shared by this bench and the
/// `vortex serve --mixed` CLI so the two reports cannot drift.
pub fn lanes_table(title: &str, stats: &MixedStats) -> Table {
    let mut t = Table::new(
        title,
        &["lane", "requests", "batches", "units", "p50", "p99", "sched %"],
    );
    for l in &stats.lanes {
        let (p50, _, p99) = l.metrics.latency_percentiles();
        t.row(vec![
            l.class.name().into(),
            l.metrics.count().to_string(),
            l.batches.to_string(),
            l.total_units.to_string(),
            fmt_secs(p50),
            fmt_secs(p99),
            format!("{:.2}", 100.0 * l.metrics.sched_fraction()),
        ]);
    }
    t
}

pub fn serve(out_dir: &Path, seed: u64, frac: usize) -> Vec<Table> {
    let hw = presets::a100();
    let selector = scenario::demo_selector(seed);

    // The acceptance gate requires >= 200 requests even in fast mode.
    let n = (600 / frac.max(1)).max(240);
    let trace = scenario::mixed_trace(n, 4e-4, seed, DType::F32);
    let serve_cfg = scenario::serving_config();

    let run = |cache: bool| {
        let mut engine = SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
        let cfg = if cache { serve_cfg.clone() } else { serve_cfg.without_cache() };
        serve_mixed_trace(&mut engine, &selector, &cfg, &trace)
    };
    let cached = run(true);
    let baseline = run(false);
    let identical = identical_selections(&cached, &baseline);
    let warm_rate = warm_hit_rate(&cached);

    let lanes = lanes_table("serving lanes (plan cache ON, simulated A100)", &cached);

    let mut cmp = Table::new(
        "plan cache ON vs OFF",
        &["config", "span", "p99", "sched secs", "hit rate", "warm hit rate"],
    );
    let row = |t: &mut Table, name: &str, s: &MixedStats, warm: f64| {
        let (_, _, p99) = s.latency_percentiles();
        t.row(vec![
            name.into(),
            fmt_secs(s.span_secs),
            fmt_secs(p99),
            fmt_secs(s.total_sched_secs()),
            format!("{:.3}", s.cache.hit_rate()),
            format!("{:.3}", warm),
        ]);
    };
    row(&mut cmp, "cached", &cached, warm_rate);
    row(&mut cmp, "no-cache", &baseline, 0.0);
    cmp.row(vec![
        "identical selections".into(),
        identical.to_string(),
        String::new(),
        format!(
            "{:.2}x less",
            baseline.total_sched_secs() / cached.total_sched_secs().max(1e-12)
        ),
        String::new(),
        String::new(),
    ]);

    let (c50, _, c99) = cached.latency_percentiles();
    let (_, _, b99) = baseline.latency_percentiles();
    let json = Json::obj(vec![
        ("requests", Json::num(trace.len() as f64)),
        ("lanes", Json::num(cached.lanes.len() as f64)),
        ("span_secs", Json::num(cached.span_secs)),
        ("p50_secs", Json::num(c50)),
        ("p99_secs", Json::num(c99)),
        ("sched_secs", Json::num(cached.total_sched_secs())),
        ("sched_fraction", Json::num(cached.sched_fraction())),
        (
            "cache",
            Json::obj(vec![
                ("hits", Json::num(cached.cache.hits as f64)),
                ("misses", Json::num(cached.cache.misses as f64)),
                ("evictions", Json::num(cached.cache.evictions as f64)),
                ("hit_rate", Json::num(cached.cache.hit_rate())),
                ("hit_rate_warm", Json::num(warm_rate)),
            ]),
        ),
        (
            "baseline",
            Json::obj(vec![
                ("span_secs", Json::num(baseline.span_secs)),
                ("p99_secs", Json::num(b99)),
                ("sched_secs", Json::num(baseline.total_sched_secs())),
                ("sched_fraction", Json::num(baseline.sched_fraction())),
            ]),
        ),
        (
            "sched_speedup",
            Json::num(baseline.total_sched_secs() / cached.total_sched_secs().max(1e-12)),
        ),
        ("identical_selections", Json::Bool(identical)),
    ]);
    let _ = std::fs::write(out_dir.join("BENCH_serve.json"), json.dump());
    let _ = lanes.write_csv(&out_dir.join("serve.csv"));
    vec![lanes, cmp]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_writes_report_with_identical_selections() {
        let dir = std::env::temp_dir().join("vortex_bench_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tables = serve(&dir, 7, 8);
        assert_eq!(tables.len(), 2);
        let text = std::fs::read_to_string(dir.join("BENCH_serve.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert!(j.get("requests").unwrap().as_f64().unwrap() >= 200.0);
        assert_eq!(j.get("identical_selections").unwrap().as_bool(), Some(true));
        assert!(j.get("cache").unwrap().get("hits").unwrap().as_f64().unwrap() > 0.0);
    }
}
