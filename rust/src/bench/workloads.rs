//! Benchmark workload suites (paper Tables 3 & 4): 1197 operator
//! configurations spanning DeepBench, Transformer, CNN and GNN shape
//! ranges, generated deterministically (log-uniform within each
//! published range, matching the published case counts).

use crate::ir::{DType, TensorProgram};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Case {
    pub category: &'static str,
    pub program: TensorProgram,
}

fn log_uniform(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    if lo >= hi {
        return lo;
    }
    let (a, b) = ((lo as f64).ln(), (hi as f64).ln());
    ((a + rng.f64() * (b - a)).exp().round() as usize).clamp(lo, hi)
}

/// Table 3: benchmarked GEMMs with dynamic shapes (506 cases).
pub fn gemm_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut gen = |cat: &'static str,
                   n_cases: usize,
                   m: (usize, usize),
                   n: (usize, usize),
                   k: (usize, usize),
                   rng: &mut Rng| {
        for _ in 0..n_cases {
            out.push(Case {
                category: cat,
                program: TensorProgram::Gemm {
                    m: log_uniform(rng, m.0, m.1),
                    n: log_uniform(rng, n.0, n.1),
                    k: log_uniform(rng, k.0, k.1),
                    dtype,
                },
            });
        }
    };
    gen("deepbench", 84, (35, 8448), (1, 6000), (128, 500_000), &mut rng);
    gen("transformer", 192, (1, 476), (768, 4096), (768, 4096), &mut rng);
    gen("cnn", 80, (1, 128), (80, 25088), (10, 4096), &mut rng);
    gen("gnn", 150, (2708, 1_888_584), (2, 121), (8, 3703), &mut rng);
    out
}

/// Table 4: benchmarked convolutions with dynamic shapes (691 cases),
/// now spanning the conv family's geometry: strides 1–2 and paddings
/// up to half the filter (the DeepBench/CNN ranges include both).
pub fn conv_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut gen = |cat: &'static str,
                   n_cases: usize,
                   bs: (usize, usize),
                   fmap: (usize, usize),
                   filt: (usize, usize),
                   cin: (usize, usize),
                   cout: (usize, usize),
                   rng: &mut Rng| {
        for _ in 0..n_cases {
            let kh = log_uniform(rng, filt.0, filt.1);
            // feature map must admit the filter even unpadded
            let h = log_uniform(rng, fmap.0.max(kh), fmap.1.max(kh));
            let stride = rng.usize(1, 2);
            let pad = rng.usize(0, kh / 2);
            out.push(Case {
                category: cat,
                program: TensorProgram::conv2d(
                    (log_uniform(rng, bs.0, bs.1), h, h, log_uniform(rng, cin.0, cin.1)),
                    (kh, kh, log_uniform(rng, cout.0, cout.1)),
                    (stride, pad, 1),
                    dtype,
                )
                .expect("suite geometry is valid by construction"),
            });
        }
    };
    gen("deepbench", 107, (1, 16), (7, 700), (1, 20), (1, 2048), (16, 2048), &mut rng);
    gen("cnn", 584, (1, 64), (4, 768), (1, 11), (3, 832), (16, 512), &mut rng);
    out
}

/// Conv-family suite (ROADMAP "next ops"): ResNet-style strided/padded
/// convolutions and MobileNet-style depthwise (`groups == cin`)
/// convolutions, each swept over dynamic batch sizes — the workloads
/// the generalized conv path exists for.
pub fn conv_family_suite(dtype: DType) -> Vec<Case> {
    let mut out = Vec::new();
    let conv = |cat: &'static str,
                io: (usize, usize, usize, usize),
                filt: (usize, usize, usize),
                geom: (usize, usize, usize)| Case {
        category: cat,
        program: TensorProgram::conv2d(io, filt, geom, dtype)
            .expect("family geometry is valid by construction"),
    };
    for b in [1usize, 8, 32] {
        // ResNet-50 stem + per-stage strided downsamples (3x3, s2, p1).
        out.push(conv("resnet_strided", (b, 224, 224, 3), (7, 7, 64), (2, 3, 1)));
        for &(hw, cin, cout) in
            &[(56usize, 64usize, 128usize), (28, 128, 256), (14, 256, 512)]
        {
            out.push(conv("resnet_strided", (b, hw, hw, cin), (3, 3, cout), (2, 1, 1)));
        }
        // MobileNetV1 depthwise ladder (3x3, pad 1, stride 1 and 2).
        for &(hw, c) in &[(112usize, 32usize), (56, 64), (28, 128), (14, 256), (7, 512)]
        {
            out.push(conv("mobilenet_depthwise", (b, hw, hw, c), (3, 3, c), (1, 1, c)));
            out.push(conv("mobilenet_depthwise", (b, hw, hw, c), (3, 3, c), (2, 1, c)));
        }
        // Grouped (non-depthwise) middle ground: ResNeXt-style 32 groups.
        out.push(conv("resnext_grouped", (b, 28, 28, 128), (3, 3, 128), (1, 1, 32)));
    }
    out
}

/// Batched-GEMM suite (200 cases): attention-style batched contractions
/// with dynamic batch x heads and sequence length — the QK^T score and
/// score x V context products every transformer layer executes. These
/// exercise the operator-generic strategy space over a genuinely
/// 4-axis iteration space (batch axis parallel, no cross-batch reuse).
pub fn batched_gemm_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let heads = [32usize, 64, 128]; // per-head dims of common models
    for _ in 0..100 {
        // scores: S[b, s, s] = Q[b, s, hd] @ K^T[b, hd, s]
        let s = log_uniform(&mut rng, 1, 476);
        let hd = heads[rng.usize(0, heads.len() - 1)];
        out.push(Case {
            category: "attention_score",
            program: TensorProgram::BatchedGemm {
                b: log_uniform(&mut rng, 1, 192),
                m: s,
                n: s,
                k: hd,
                dtype,
            },
        });
    }
    for _ in 0..100 {
        // context: C[b, s, hd] = S[b, s, s] @ V[b, s, hd]
        let s = log_uniform(&mut rng, 1, 476);
        let hd = heads[rng.usize(0, heads.len() - 1)];
        out.push(Case {
            category: "attention_ctx",
            program: TensorProgram::BatchedGemm {
                b: log_uniform(&mut rng, 1, 192),
                m: s,
                n: hd,
                k: s,
                dtype,
            },
        });
    }
    out
}

/// Attention-fused chain suite (51 cases): transformer head-group
/// chains sweeping the dynamic SEQUENCE LENGTH — the paper's 17-point
/// [1, 476] grid, including seq = 1 (decode) and non-power-of-two
/// lengths — at each fixed head dimension common to real models, with
/// randomized batch x heads. Sequence length enters the fused space
/// quadratically (both spatial axes), which is exactly the dynamism
/// the chain op exists for.
pub fn attention_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &hd in &[32usize, 64, 128] {
        for i in 0..17 {
            let seq = 1 + i * 475 / 16;
            let heads = [8usize, 12, 16][rng.usize(0, 2)];
            let batch = log_uniform(&mut rng, 1, 8);
            out.push(Case {
                category: "attention_chain",
                program: TensorProgram::attention((batch, seq), (heads * hd, heads), dtype)
                    .expect("suite geometry is valid by construction"),
            });
        }
    }
    out
}

/// Fig. 3 / Table 6 BERT GEMM-1 shape: M = batch x seq, N = 768, K = 2304.
pub fn bert_gemm1(batch: usize, seq: usize, dtype: DType) -> TensorProgram {
    TensorProgram::Gemm { m: batch * seq, n: 768, k: 2304, dtype }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_counts_match_paper() {
        assert_eq!(gemm_suite(DType::F32, 1).len(), 506);
        assert_eq!(conv_suite(DType::F32, 1).len(), 691);
        // 506 + 691 = 1197 operator configurations (paper §7.1)
        assert_eq!(batched_gemm_suite(DType::F32, 1).len(), 200);
        assert_eq!(attention_suite(DType::F32, 1).len(), 3 * 17);
    }

    #[test]
    fn attention_suite_sweeps_seq_at_fixed_head_dims() {
        let cases = attention_suite(DType::F16, 9);
        let mut seqs = std::collections::BTreeSet::new();
        let mut head_dims = std::collections::BTreeSet::new();
        for c in &cases {
            assert!(c.program.validate().is_ok(), "{}", c.program.id());
            let TensorProgram::Attention { batch, seq, d, heads, .. } = &c.program else {
                panic!("non-attention case in attention suite");
            };
            let (batch, seq, d, heads) = (*batch, *seq, *d, *heads);
            assert!((1..=8).contains(&batch));
            assert!((1..=476).contains(&seq));
            seqs.insert(seq);
            head_dims.insert(d / heads);
            assert_eq!(c.program.space().op, crate::ir::OpKind::FusedAttention);
        }
        // The paper's dynamic range endpoints, decode step included,
        // at every fixed head dim.
        assert!(seqs.contains(&1) && seqs.contains(&476));
        assert!(seqs.iter().any(|s| !s.is_power_of_two() && *s > 1));
        assert_eq!(head_dims.into_iter().collect::<Vec<_>>(), vec![32, 64, 128]);
    }

    #[test]
    fn batched_suite_shapes_are_attention_like() {
        for c in batched_gemm_suite(DType::F16, 5) {
            let crate::ir::TensorProgram::BatchedGemm { b, m, n, k, .. } = c.program
            else {
                panic!("non-batched case in batched suite");
            };
            assert!((1..=192).contains(&b));
            assert!((1..=476).contains(&m));
            match c.category {
                "attention_score" => assert!([32, 64, 128].contains(&k) && n == m),
                _ => assert!([32, 64, 128].contains(&n) && k == m),
            }
        }
    }

    #[test]
    fn shapes_respect_published_ranges() {
        for c in gemm_suite(DType::F32, 2) {
            if let TensorProgram::Gemm { m, n, k, .. } = c.program {
                match c.category {
                    "transformer" => {
                        assert!((1..=476).contains(&m));
                        assert!((768..=4096).contains(&n));
                        assert!((768..=4096).contains(&k));
                    }
                    "gnn" => assert!((2..=121).contains(&n)),
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn conv_fmaps_admit_filters() {
        for c in conv_suite(DType::F32, 3) {
            assert!(c.program.validate().is_ok(), "{}", c.program.id());
            if let TensorProgram::Conv2d { h, kh, stride, pad, .. } = c.program {
                assert!(h >= kh);
                assert!((1..=2).contains(&stride));
                assert!(pad <= kh / 2);
            }
        }
        // The randomized suite must actually exercise the new geometry.
        let strided = conv_suite(DType::F32, 3)
            .iter()
            .filter(|c| matches!(c.program, TensorProgram::Conv2d { stride: 2, .. }))
            .count();
        assert!(strided > 100, "only {} strided cases", strided);
    }

    #[test]
    fn conv_family_suite_covers_strided_and_depthwise() {
        let cases = conv_family_suite(DType::F16);
        assert!(!cases.is_empty());
        let mut depthwise = 0;
        let mut strided = 0;
        for c in &cases {
            assert!(c.program.validate().is_ok(), "{}", c.program.id());
            let TensorProgram::Conv2d { cin, stride, groups, .. } = &c.program else {
                panic!("non-conv case in conv family suite");
            };
            let (cin, stride, groups) = (*cin, *stride, *groups);
            if groups == cin {
                depthwise += 1;
                assert_eq!(c.program.space().op, crate::ir::OpKind::GroupedConv2d);
            }
            if stride == 2 {
                strided += 1;
            }
        }
        assert!(depthwise >= 10, "only {} depthwise cases", depthwise);
        assert!(strided >= 10, "only {} strided cases", strided);
    }

    #[test]
    fn deterministic_across_calls() {
        let a = gemm_suite(DType::F32, 42);
        let b = gemm_suite(DType::F32, 42);
        assert_eq!(
            a.iter().map(|c| c.program.id()).collect::<Vec<_>>(),
            b.iter().map(|c| c.program.id()).collect::<Vec<_>>()
        );
    }
}
