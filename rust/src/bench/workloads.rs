//! Benchmark workload suites (paper Tables 3 & 4): 1197 operator
//! configurations spanning DeepBench, Transformer, CNN and GNN shape
//! ranges, generated deterministically (log-uniform within each
//! published range, matching the published case counts).

use crate::ir::{DType, TensorProgram};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Case {
    pub category: &'static str,
    pub program: TensorProgram,
}

fn log_uniform(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    if lo >= hi {
        return lo;
    }
    let (a, b) = ((lo as f64).ln(), (hi as f64).ln());
    ((a + rng.f64() * (b - a)).exp().round() as usize).clamp(lo, hi)
}

/// Table 3: benchmarked GEMMs with dynamic shapes (506 cases).
pub fn gemm_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut gen = |cat: &'static str,
                   n_cases: usize,
                   m: (usize, usize),
                   n: (usize, usize),
                   k: (usize, usize),
                   rng: &mut Rng| {
        for _ in 0..n_cases {
            out.push(Case {
                category: cat,
                program: TensorProgram::Gemm {
                    m: log_uniform(rng, m.0, m.1),
                    n: log_uniform(rng, n.0, n.1),
                    k: log_uniform(rng, k.0, k.1),
                    dtype,
                },
            });
        }
    };
    gen("deepbench", 84, (35, 8448), (1, 6000), (128, 500_000), &mut rng);
    gen("transformer", 192, (1, 476), (768, 4096), (768, 4096), &mut rng);
    gen("cnn", 80, (1, 128), (80, 25088), (10, 4096), &mut rng);
    gen("gnn", 150, (2708, 1_888_584), (2, 121), (8, 3703), &mut rng);
    out
}

/// Table 4: benchmarked convolutions with dynamic shapes (691 cases).
pub fn conv_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut gen = |cat: &'static str,
                   n_cases: usize,
                   bs: (usize, usize),
                   fmap: (usize, usize),
                   filt: (usize, usize),
                   cin: (usize, usize),
                   cout: (usize, usize),
                   rng: &mut Rng| {
        for _ in 0..n_cases {
            let kh = log_uniform(rng, filt.0, filt.1);
            // feature map must admit the filter (valid conv)
            let h = log_uniform(rng, fmap.0.max(kh), fmap.1.max(kh));
            out.push(Case {
                category: cat,
                program: TensorProgram::Conv2d {
                    n: log_uniform(rng, bs.0, bs.1),
                    h,
                    w: h,
                    cin: log_uniform(rng, cin.0, cin.1),
                    cout: log_uniform(rng, cout.0, cout.1),
                    kh,
                    kw: kh,
                    dtype,
                },
            });
        }
    };
    gen("deepbench", 107, (1, 16), (7, 700), (1, 20), (1, 2048), (16, 2048), &mut rng);
    gen("cnn", 584, (1, 64), (4, 768), (1, 11), (3, 832), (16, 512), &mut rng);
    out
}

/// Batched-GEMM suite (200 cases): attention-style batched contractions
/// with dynamic batch x heads and sequence length — the QK^T score and
/// score x V context products every transformer layer executes. These
/// exercise the operator-generic strategy space over a genuinely
/// 4-axis iteration space (batch axis parallel, no cross-batch reuse).
pub fn batched_gemm_suite(dtype: DType, seed: u64) -> Vec<Case> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let heads = [32usize, 64, 128]; // per-head dims of common models
    for _ in 0..100 {
        // scores: S[b, s, s] = Q[b, s, hd] @ K^T[b, hd, s]
        let s = log_uniform(&mut rng, 1, 476);
        let hd = heads[rng.usize(0, heads.len() - 1)];
        out.push(Case {
            category: "attention_score",
            program: TensorProgram::BatchedGemm {
                b: log_uniform(&mut rng, 1, 192),
                m: s,
                n: s,
                k: hd,
                dtype,
            },
        });
    }
    for _ in 0..100 {
        // context: C[b, s, hd] = S[b, s, s] @ V[b, s, hd]
        let s = log_uniform(&mut rng, 1, 476);
        let hd = heads[rng.usize(0, heads.len() - 1)];
        out.push(Case {
            category: "attention_ctx",
            program: TensorProgram::BatchedGemm {
                b: log_uniform(&mut rng, 1, 192),
                m: s,
                n: hd,
                k: s,
                dtype,
            },
        });
    }
    out
}

/// Fig. 3 / Table 6 BERT GEMM-1 shape: M = batch x seq, N = 768, K = 2304.
pub fn bert_gemm1(batch: usize, seq: usize, dtype: DType) -> TensorProgram {
    TensorProgram::Gemm { m: batch * seq, n: 768, k: 2304, dtype }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_counts_match_paper() {
        assert_eq!(gemm_suite(DType::F32, 1).len(), 506);
        assert_eq!(conv_suite(DType::F32, 1).len(), 691);
        // 506 + 691 = 1197 operator configurations (paper §7.1)
        assert_eq!(batched_gemm_suite(DType::F32, 1).len(), 200);
    }

    #[test]
    fn batched_suite_shapes_are_attention_like() {
        for c in batched_gemm_suite(DType::F16, 5) {
            let crate::ir::TensorProgram::BatchedGemm { b, m, n, k, .. } = c.program
            else {
                panic!("non-batched case in batched suite");
            };
            assert!((1..=192).contains(&b));
            assert!((1..=476).contains(&m));
            match c.category {
                "attention_score" => assert!([32, 64, 128].contains(&k) && n == m),
                _ => assert!([32, 64, 128].contains(&n) && k == m),
            }
        }
    }

    #[test]
    fn shapes_respect_published_ranges() {
        for c in gemm_suite(DType::F32, 2) {
            if let TensorProgram::Gemm { m, n, k, .. } = c.program {
                match c.category {
                    "transformer" => {
                        assert!((1..=476).contains(&m));
                        assert!((768..=4096).contains(&n));
                        assert!((768..=4096).contains(&k));
                    }
                    "gnn" => assert!((2..=121).contains(&n)),
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn conv_fmaps_admit_filters() {
        for c in conv_suite(DType::F32, 3) {
            if let TensorProgram::Conv2d { h, kh, .. } = c.program {
                assert!(h >= kh);
            }
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let a = gemm_suite(DType::F32, 42);
        let b = gemm_suite(DType::F32, 42);
        assert_eq!(
            a.iter().map(|c| c.program.id()).collect::<Vec<_>>(),
            b.iter().map(|c| c.program.id()).collect::<Vec<_>>()
        );
    }
}
