//! Autoregressive decode benchmark: a Poisson-arrival decode trace
//! (geometric output lengths, in-horizon by construction) through the
//! continuous-batching decode lane under TWO dispatch configurations —
//! compile-time dispatch table and fresh per-step selection — per-token
//! tail latency, per-STEP tri-state accounting and decode throughput,
//! written to `decode.csv` and `BENCH_decode.json`.
//!
//! The fresh run is the correctness baseline: identical per-request
//! selections are REQUIRED (the table's guarantee), and the event
//! clock charges the same modeled per-step overhead either way — so
//! event-clock spans are identical between the legs by construction,
//! and throughput is compared over the MEASURED work seconds
//! (selection + modeled service), the component the table actually
//! removes. The headline invariant is the tentpole claim: with the
//! trace in-horizon and the table unclamped, EVERY decode step is
//! answered from the table — `warm_start_rate == 1.0`, zero selector
//! scans, zero cache probes, from the very first token. CI
//! schema-validates the emitted report against
//! `results/BENCH_decode.json` and gates the invariant.

use std::path::Path;

use crate::hw::presets;
use crate::ir::DType;
use crate::serve::{scenario, serve_mixed_trace, LaneClass, LaneStats, MixedStats, SimLaneEngine};
use crate::sim::Simulator;
use crate::util::json::Json;
use crate::util::table::{fmt_secs, Table};

use super::exp_serve::identical_selections;

/// The decode lane's stats (lanes carry only classes that saw
/// traffic; a decode trace feeds exactly one).
fn decode_lane(stats: &MixedStats) -> &LaneStats {
    stats
        .lanes
        .iter()
        .find(|l| l.class == LaneClass::Decode)
        .expect("decode lane missing from mixed stats")
}

/// Decode tokens served per second of measured lane work (selection +
/// modeled service). Event-clock spans are identical between the
/// table and fresh legs by construction (same modeled per-step
/// overhead on the clock), so this is the honest throughput lens: the
/// denominator shrinks exactly by the selection seconds the dispatch
/// table eliminates.
pub fn tokens_per_busy_sec(lane: &LaneStats) -> f64 {
    let busy = lane.metrics.total_sched_secs() + lane.metrics.total_exec_secs();
    if busy <= 0.0 {
        0.0
    } else {
        lane.metrics.count() as f64 / busy
    }
}

pub fn decode(out_dir: &Path, seed: u64, frac: usize) -> Vec<Table> {
    let hw = presets::a100();
    let selector = scenario::demo_selector(seed);

    // Enough sequences that the continuous batch reaches steady state
    // even in fast mode (geometric mean 24 tokens per sequence).
    let n = (320 / frac.max(1)).max(96);
    let trace = scenario::decode_trace(n, 3e-4, 24, seed, DType::F32);
    let tokens: usize = trace.iter().map(|r| r.steps).sum();
    let serve_cfg = scenario::serving_config();

    let run = |cfg: &crate::serve::ServeConfig| {
        let mut engine = SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
        serve_mixed_trace(&mut engine, &selector, cfg, &trace)
    };
    let table = run(&serve_cfg.with_dispatch(scenario::dispatch_config()));
    let fresh = run(&serve_cfg.without_cache());
    let identical = identical_selections(&table, &fresh);

    let tl = decode_lane(&table);
    let fl = decode_lane(&fresh);
    let bd = table.batch_dispatch();
    let fd = fresh.batch_dispatch();
    let steps = bd.table + bd.cache + bd.fresh;
    let (tp50, _, tp99) = tl.metrics.latency_percentiles();
    let (fp50, _, fp99) = fl.metrics.latency_percentiles();
    let tps_table = tokens_per_busy_sec(tl);
    let tps_fresh = tokens_per_busy_sec(fl);
    let build = table.dispatch_build.clone().unwrap_or_default();

    let mut cmp = Table::new(
        "decode lane: dispatch table vs fresh per-step selection (simulated A100)",
        &[
            "config", "tokens", "steps", "token p50", "token p99", "sched secs",
            "table/cache/fresh", "tok/s (busy)",
        ],
    );
    let row = |t: &mut Table, name: &str, l: &LaneStats, d: &crate::serve::DispatchStats| {
        let (p50, _, p99) = l.metrics.latency_percentiles();
        t.row(vec![
            name.into(),
            l.metrics.count().to_string(),
            l.batches.to_string(),
            fmt_secs(p50),
            fmt_secs(p99),
            fmt_secs(l.metrics.total_sched_secs()),
            format!("{}/{}/{}", d.table, d.cache, d.fresh),
            format!("{:.0}", tokens_per_busy_sec(l)),
        ]);
    };
    row(&mut cmp, "table", tl, &bd);
    row(&mut cmp, "fresh", fl, &fd);
    let sched_speedup = fl.metrics.total_sched_secs() / tl.metrics.total_sched_secs().max(1e-12);
    cmp.row(vec![
        "speedup".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{:.2}x less", sched_speedup),
        format!("warm start {:.3}", bd.warm_start_rate()),
        format!("{:.2}x", tps_table / tps_fresh.max(1e-12)),
    ]);

    let json = Json::obj(vec![
        ("schema", Json::str("vortex-bench-decode-v1")),
        ("sequences", Json::num(trace.len() as f64)),
        ("tokens", Json::num(tokens as f64)),
        ("steps", Json::num(steps as f64)),
        ("span_secs", Json::num(table.span_secs)),
        ("token_p50_secs", Json::num(tp50)),
        ("token_p99_secs", Json::num(tp99)),
        ("sched_secs", Json::num(tl.metrics.total_sched_secs())),
        ("exec_secs", Json::num(tl.metrics.total_exec_secs())),
        ("tokens_per_sec", Json::num(tps_table)),
        (
            "dispatch",
            Json::obj(vec![
                ("table_steps", Json::num(bd.table as f64)),
                ("cache_steps", Json::num(bd.cache as f64)),
                ("fresh_steps", Json::num(bd.fresh as f64)),
                ("warm_start_rate", Json::num(bd.warm_start_rate())),
                ("tables", Json::num(build.tables as f64)),
                ("cells", Json::num(build.cells as f64)),
                ("build_secs", Json::num(build.build_secs)),
                ("clamped", Json::Bool(build.clamped)),
            ]),
        ),
        (
            "baseline",
            Json::obj(vec![
                ("token_p50_secs", Json::num(fp50)),
                ("token_p99_secs", Json::num(fp99)),
                ("sched_secs", Json::num(fl.metrics.total_sched_secs())),
                ("tokens_per_sec", Json::num(tps_fresh)),
                ("fresh_steps", Json::num(fd.fresh as f64)),
            ]),
        ),
        ("tokens_per_sec_speedup", Json::num(tps_table / tps_fresh.max(1e-12))),
        ("sched_speedup", Json::num(sched_speedup)),
        ("identical_selections", Json::Bool(identical)),
        ("alloc_events", Json::num(tl.metrics.alloc_events as f64)),
    ]);
    let _ = std::fs::write(out_dir.join("BENCH_decode.json"), json.dump());
    let _ = cmp.write_csv(&out_dir.join("decode.csv"));
    vec![cmp]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_bench_reports_full_table_coverage_and_speedup() {
        let dir = std::env::temp_dir().join("vortex_bench_decode_test");
        std::fs::create_dir_all(&dir).unwrap();
        let tables = decode(&dir, 7, 8);
        assert_eq!(tables.len(), 1);
        let text = std::fs::read_to_string(dir.join("BENCH_decode.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("vortex-bench-decode-v1"));
        let seqs = j.get("sequences").unwrap().as_f64().unwrap();
        let tokens = j.get("tokens").unwrap().as_f64().unwrap();
        let steps = j.get("steps").unwrap().as_f64().unwrap();
        assert!(seqs >= 90.0);
        assert!(tokens >= seqs, "each sequence decodes at least one token");
        // Continuous batching: no more steps than tokens — strictly
        // fewer when concurrent sequences shared a batch.
        assert!(steps > 0.0 && steps <= tokens);
        // The tentpole invariant: IN-HORIZON decode is 100% table
        // hits — not one step paid a selector scan or a cache probe.
        let d = j.get("dispatch").unwrap();
        assert_eq!(d.get("clamped").unwrap().as_bool(), Some(false));
        assert_eq!(d.get("fresh_steps").unwrap().as_f64(), Some(0.0));
        assert_eq!(d.get("cache_steps").unwrap().as_f64(), Some(0.0));
        assert_eq!(d.get("warm_start_rate").unwrap().as_f64(), Some(1.0));
        assert_eq!(d.get("table_steps").unwrap().as_f64(), Some(steps));
        // The fresh baseline scanned on every step and picked the SAME
        // plans; the table leg is strictly faster on measured work.
        let b = j.get("baseline").unwrap();
        assert_eq!(b.get("fresh_steps").unwrap().as_f64(), Some(steps));
        assert_eq!(j.get("identical_selections").unwrap().as_bool(), Some(true));
        assert!(j.get("tokens_per_sec_speedup").unwrap().as_f64().unwrap() > 1.0);
        assert!(j.get("sched_speedup").unwrap().as_f64().unwrap() > 1.0);
        // Event-clock percentiles are well-formed.
        let p50 = j.get("token_p50_secs").unwrap().as_f64().unwrap();
        let p99 = j.get("token_p99_secs").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p99 >= p50);
        // Steady-state allocations are amortized: a handful of pool
        // builds, never a function of how many steps ran.
        assert!(j.get("alloc_events").unwrap().as_f64().unwrap() <= 8.0);
    }
}
