//! # Vortex — sample-free dynamic-shape tensor program optimization
//!
//! Reproduction of *"Vortex: Efficient Sample-Free Dynamic Tensor Program
//! Optimization via Hardware-aware Strategy Space Hierarchization"*
//! (cs.DC 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Vortex compiler and runtime: hardware
//!   hierarchy model ([`hw`]), `rKernel` IR ([`ir`]), bottom-up candidate
//!   generation ([`candgen`]), analytical + hybrid cost analysis
//!   ([`cost`]), offline library construction ([`compiler`]), runtime
//!   shape→kernel selection and kernel construction ([`coordinator`]),
//!   baselines ([`baselines`]), model-level workloads ([`models`]) and
//!   the paper's benchmark harness ([`bench`]).
//! * **Layer 2 (python/compile)** — jax graphs lowered AOT to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas micro-kernels.
//!
//! Python never runs at serving time: [`runtime`] loads the AOT
//! artifacts via the PJRT CPU client and the coordinator composes them
//! over dynamic shapes.

pub mod baselines;
pub mod bench;
pub mod candgen;
pub mod compiler;
pub mod coordinator;
pub mod cost;
pub mod hw;
pub mod ir;
pub mod models;
pub mod profiler;
pub mod runtime;
pub mod sim;
pub mod util;
