//! # Vortex — sample-free dynamic-shape tensor program optimization
//!
//! Reproduction of *"Vortex: Efficient Sample-Free Dynamic Tensor Program
//! Optimization via Hardware-aware Strategy Space Hierarchization"*
//! (cs.DC 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Vortex compiler and runtime: hardware
//!   hierarchy model ([`hw`]), `rKernel` IR + the operator-generic
//!   strategy space ([`ir`]), bottom-up candidate generation
//!   ([`candgen`]), analytical + hybrid cost analysis ([`cost`]),
//!   offline library construction ([`compiler`]), runtime shape→kernel
//!   selection and kernel construction ([`coordinator`]), baselines
//!   ([`baselines`]), model-level workloads ([`models`]) and the
//!   paper's benchmark harness ([`bench`]).
//! * **Layer 2 (python/compile)** — jax graphs lowered AOT to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas micro-kernels.
//!
//! Python never runs at serving time: [`runtime`] loads the AOT
//! artifacts via the PJRT CPU client and the coordinator composes them
//! over dynamic shapes. Dynamic execution streams operand tiles through
//! zero-materialization block providers (`OperandSource`: dense /
//! implicit-im2col / transpose views), batches group loops into native
//! `bgemm_acc` launches, and runs independent (M, N) grid cells on
//! scoped threads with bit-identical results — the "Runtime execution"
//! section of [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md)
//! documents the invariants.
//!
//! ## Operator-generic architecture
//!
//! Every layer is parameterized by an operator spec
//! ([`ir::OpSpec`] / [`ir::OpKind`]): `Gemm`, `BatchedGemm`, `Conv2d`
//! (strides, padding), `GroupedConv2d` (grouped / depthwise, group
//! axis = batch) and `FusedAttention` (the score · softmax · context
//! chain with the softmax fused at the L1 tile boundary) today. The op
//! owns its iteration-space axes (batch / spatial / reduction roles),
//! FLOP count, working-set formula, per-level load/store traffic,
//! padding + grid math, and the AOT artifact-name convention. Tiles
//! are rank-tagged [`ir::Tile`]s (`Copy`, allocation-free) rather than
//! raw `[usize; 3]` arrays, and a runtime problem is an
//! [`ir::IterSpace`] (op + dims + dtype).
//!
//! Programs with non-trivial geometry construct fallibly
//! (`TensorProgram::conv2d`, `TensorProgram::attention`: invalid
//! geometry is a construction-time error), and ops whose blocks are
//! another op's blocks declare a *measurement alias*
//! (`OpSpec::measurement_op`): Conv2d → Gemm and GroupedConv2d →
//! BatchedGemm by exact delegation, FusedAttention → BatchedGemm as a
//! two-kernel chain plus a softmax micro-measurement. Aliased ops
//! share profiling measurements with zero re-taking, and the selector
//! serves a space with no native library through the alias chain's
//! fixpoint — attention runs on batched-GEMM libraries with no
//! attention-specific side path.
//!
//! The full per-layer walkthrough and the "how to add a new op" recipe
//! (worked through `FusedAttention`) live in
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) at the repo
//! root — start there before touching the strategy-space stack.
//!
//! The offline stage's per-candidate analysis is parallelized across
//! threads (measurements are hoisted and profiled once, sequentially,
//! so profiler accounting stays exact), and compiled libraries can be
//! cached on disk keyed by (hw, op, dtype, analyzer) plus a
//! fingerprint of the hardware spec, measurement definitions and — on
//! the real testbed — the AOT artifact set — see
//! [`compiler::CompileOpts`].
//!
//! ## Serving layer
//!
//! The production serving subsystem ([`serve`]) runs multi-op traffic
//! through per-op-class request lanes (token-row merging for GEMM and
//! attention, batch-dim merging for the conv family) with a bucketed
//! plan cache ([`serve::PlanCache`]) that memoizes shape→kernel
//! selection by padded-tile bucket — O(1) amortized dispatch with a
//! guarantee that cached plans are identical to fresh selection.
//!
//! On top of the cache sits the offline **shape-space partitioner**
//! ([`dispatch`]): at compile time each dynamic axis is partitioned at
//! L1-extent multiples up to a configurable horizon and the winning
//! kernel is enumerated per cell, yielding a
//! [`dispatch::DispatchTable`] that answers any in-horizon shape in
//! `O(axes · log intervals)` with zero warm-up and provably identical
//! plans to fresh selection; the plan cache is demoted to the
//! beyond-horizon fallback (tri-state table / cache / fresh stats).
//! Tables ship inside schema-v3 library JSON
//! ([`compiler::LIBRARY_SCHEMA_VERSION`]) via `vortex compile
//! --dispatch`. The "Serving layer" and "Dispatch tables" sections of
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) cover the
//! lanes, the bucket-key derivation, the region-soundness argument and
//! cache coherence with library reload; the `serve` bench and `vortex
//! serve --mixed [--dispatch]` exercise it end to end.
//!
//! Autoregressive decode gets its own continuous-batching lane
//! ([`serve::LaneClass::Decode`]): sequences of single-token
//! `CausalAttention` steps share a slot pool, every merged step is
//! answered from the dispatch table (100% warm-start in-horizon), and
//! the steady-state path performs zero selector scans and zero
//! transient allocations — `vortex bench decode` regenerates
//! `BENCH_decode.json` and CI gates the invariant. [`runtime::KvCache`]
//! and [`runtime::causal_decode_dynamic`] execute decode steps against
//! pointer-stable K/V cache slabs through transpose views (no per-step
//! re-materialization). The "Decode serving" section of
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) documents
//! the lane, the KV-cache-aware cost terms and the zero-scan argument.
//!
//! At deployment scale the serving layer shards across a **fleet**
//! ([`serve::serve_fleet`]): deterministic routing assigns every
//! request to one of N replicas (sharing one `Arc`-held dispatch
//! table, each owning its own cache shards) as a pure pre-pass, and the
//! independent (replica, lane) units execute either sequentially or on
//! a work-stealing thread pool with *bit-identical* results — the
//! determinism oracle in `tests/fleet_oracle.rs` checks selections,
//! latencies and drop decisions across worker counts. Per-lane latency
//! SLOs ([`serve::LaneSlo`]) derive the batching window from the
//! deadline budget and shed or mode-downgrade unmeetable requests
//! under a chosen [`serve::OverloadPolicy`], with static feasibility
//! checked by [`analysis::audit_slo`]. The "Latency SLOs" and "Fleet
//! serving" sections of
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) give the
//! budget-split semantics and the determinism-by-construction
//! argument; `vortex serve --replicas N --workers K` is the CLI entry.
//!
//! ## Static analysis
//!
//! The plan auditor ([`analysis`]) closes the loop on "sample-free":
//! the invariants the runtime and serving layers depend on — disjoint
//! parallel write-sets, working sets within `HwSpec` capacities,
//! dispatch-table region soundness, measurement-alias fixpoints and
//! artifact/dtype consistency — are *proved* symbolically over each
//! axis interval (never at sampled shapes) by `vortex audit
//! [--dispatch] [--deny warnings]`, which CI runs over every shipped
//! preset × op × dtype. The "Static analysis layer" section of
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) gives the
//! monotone-segment soundness argument.
//!
//! ## Observability
//!
//! The tracing + metrics layer ([`obs`]) is the runtime half of that
//! loop: structured spans over compile phases and every serving
//! decision (admission, batch formation, tri-state plan resolution,
//! launch, drop/degrade), stamped from the **deterministic
//! discrete-event clock** so a traced run is bit-identical to an
//! untraced one (the fleet oracle proves it), exported as Chrome
//! trace-event JSON (`vortex serve --trace`, `vortex trace
//! summarize`), Prometheus text, and exact-percentile latency
//! histograms per replica × lane. Wall-clock time appears only in
//! explicitly-marked offline spans, and [`analysis::audit_trace`]
//! checks that rule (plus timestamp sanity) on any trace file. The
//! "Layer 9 — observability" section of
//! [`docs/ARCHITECTURE.md`](../../../docs/ARCHITECTURE.md) gives the
//! span taxonomy and the zero-perturbation argument.

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod candgen;
pub mod compiler;
pub mod coordinator;
pub mod cost;
pub mod dispatch;
pub mod hw;
pub mod ir;
pub mod models;
pub mod obs;
pub mod profiler;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
