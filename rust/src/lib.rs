//! # Vortex — sample-free dynamic-shape tensor program optimization
//!
//! Reproduction of *"Vortex: Efficient Sample-Free Dynamic Tensor Program
//! Optimization via Hardware-aware Strategy Space Hierarchization"*
//! (cs.DC 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Vortex compiler and runtime: hardware
//!   hierarchy model ([`hw`]), `rKernel` IR + the operator-generic
//!   strategy space ([`ir`]), bottom-up candidate generation
//!   ([`candgen`]), analytical + hybrid cost analysis ([`cost`]),
//!   offline library construction ([`compiler`]), runtime shape→kernel
//!   selection and kernel construction ([`coordinator`]), baselines
//!   ([`baselines`]), model-level workloads ([`models`]) and the
//!   paper's benchmark harness ([`bench`]).
//! * **Layer 2 (python/compile)** — jax graphs lowered AOT to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas micro-kernels.
//!
//! Python never runs at serving time: [`runtime`] loads the AOT
//! artifacts via the PJRT CPU client and the coordinator composes them
//! over dynamic shapes.
//!
//! ## Operator-generic architecture
//!
//! Every layer is parameterized by an operator spec
//! ([`ir::OpSpec`] / [`ir::OpKind`]): `Gemm`, `BatchedGemm`, `Conv2d`
//! (strides, padding) and `GroupedConv2d` (grouped / depthwise, group
//! axis = batch) today. The op owns its iteration-space axes (batch /
//! spatial / reduction roles), FLOP count, working-set formula,
//! per-level load/store traffic, padding + grid math, and the AOT
//! artifact-name convention. Tiles are rank-tagged [`ir::Tile`]s
//! (`Copy`, allocation-free) rather than raw `[usize; 3]` arrays, and a
//! runtime problem is an [`ir::IterSpace`] (op + dims + dtype).
//!
//! The conv family maps onto the contraction ops through validated
//! geometry (`TensorProgram::conv2d` is fallible; invalid geometry is a
//! construction-time error) and the *measurement alias* chain
//! (`OpSpec::measurement_op`): an ungrouped conv's space IS the GEMM
//! contraction space, a grouped conv's IS the per-group batched
//! contraction space, so their libraries, profiling measurements and
//! selector fallbacks all alias the contraction ops' with zero extra
//! profiling.
//!
//! Adding a new operator touches exactly one extension point per layer:
//!
//! 1. **ir** — implement `OpSpec` for a unit struct, register it in
//!    `OpKind::ALL`, and map the new `TensorProgram` variant to its
//!    `IterSpace` in `TensorProgram::space()` (with `validate()` rules
//!    if the mapping can be geometrically invalid).
//! 2. **candgen** — nothing: Algorithm 2 enumerates per-axis multiplier
//!    ladders chosen by axis role and prunes with `OpSpec::working_set`.
//! 3. **cost / sim** — nothing: Eqs. 2–4 read loop extents and traffic
//!    from the op; the simulator reuses the same spec.
//! 4. **compiler** — nothing: `compile(hw, op, dtype, ...)` builds an
//!    op-keyed [`compiler::MicroKernelLibrary`] (JSON schema v2 carries
//!    an `"op"` field; v1 GEMM-only files still load). A contraction
//!    library lifts onto batch-extended ops via
//!    `MicroKernelLibrary::lift_to_batched`.
//! 5. **coordinator / runtime** — nothing for selection
//!    (`Selector::select` is `IterSpace`-driven and chases the
//!    measurement-alias chain); real execution needs an artifact path
//!    honoring `OpSpec::artifact_name` (the conv family reuses the
//!    `gemm_acc` blocks via per-group im2col in
//!    [`runtime::conv2d_dynamic`]).
//!
//! The offline stage's per-candidate analysis is parallelized across
//! threads (measurements are hoisted and profiled once, sequentially,
//! so profiler accounting stays exact), and compiled libraries can be
//! cached on disk keyed by (hw, op, dtype, analyzer) — see
//! [`compiler::CompileOpts`].

pub mod baselines;
pub mod bench;
pub mod candgen;
pub mod compiler;
pub mod coordinator;
pub mod cost;
pub mod hw;
pub mod ir;
pub mod models;
pub mod profiler;
pub mod runtime;
pub mod sim;
pub mod util;
