//! `vortex` — CLI for the Vortex reproduction.
//!
//! Subcommands:
//!   compile   Run the offline stage for a testbed; print library stats.
//!   select    Select a micro-kernel for one shape and explain it.
//!   run       Execute a dynamic-shape GEMM on the REAL PJRT engine.
//!   serve     Dynamic-batch serving loop over a synthetic trace.
//!   audit     Symbolic plan auditor; exit code is the CI gate.
//!   trace     Summarize a Chrome trace-event file the other commands wrote.
//!   bench     Regenerate a paper table/figure ("all" for everything).
//!   info      Print hardware presets + rKernel mapping (Table 1).

use std::path::{Path, PathBuf};

use vortex::bench;
use vortex::compiler::{compile, CompileOpts};
use vortex::coordinator::{self, HwMode, Selector};
use vortex::cost::hybrid::AnalyzerConfig;
use vortex::hw::presets;
use vortex::ir::{Contraction, DType, OpKind, RKernel, TensorProgram};
use vortex::profiler::SimProfiler;
use vortex::runtime::{build_real_library, gemm_host_ref, RealEngine};
use vortex::sim::Simulator;
use vortex::util::cli::Args;
use vortex::util::rng::Rng;
use vortex::util::table::Table;

const USAGE: &str = "\
vortex — sample-free dynamic-shape tensor program optimization (reproduction)

USAGE:
  vortex compile  [--testbed sim-a100|sim-xeon|real] [--dtype f32|f16|bf16]
                  [--op gemm|batched_gemm|conv2d|grouped_conv2d|attention]
                  [--analyzer default|analytical|e0|e1] [--cache-dir DIR]
                  [--dispatch] [--horizon H] [--batch-horizon B]
                  [--dump-library PATH] [--emit-manifest PATH]
                  [--trace [PATH]]
                  (--dispatch: enumerate the shape-space dispatch table
                   offline and embed it in the dumped library — schema
                   v3 — so serving starts with zero warm-up. --trace
                   writes per-phase compile spans — candgen, profiling,
                   ranking, per-(op,mode) table builds — as Chrome
                   trace-event JSON, default compile_trace.json.)
  vortex select   --m M --n N --k K [--b B(atch/groups/head-groups)] [--op ...]
                  [--testbed ...] [--dtype ...] [--mode adaptive|cuda|tensor]
  vortex run      --m M --n N --k K [--artifacts DIR] [--verify]
  vortex serve    [--requests N] [--mean-gap-us U] [--max-batch B]
                  [--mixed] [--decode] [--mean-tokens T]
                  [--no-cache] [--dispatch]
                  [--replicas N] [--workers K] [--routing hash|load]
                  [--slo-ms D] [--slo-policy serve|drop|degrade]
                  [--trace [PATH]] [--metrics] [--metrics-json]
                  (--mixed: multi-op request lanes + bucketed plan cache
                   over a BERT-token + vision-burst trace; --decode: an
                   autoregressive decode trace (geometric output
                   lengths, mean --mean-tokens) through the
                   continuous-batching lane — one causal decode step
                   per token against a growing KV depth, with per-STEP
                   tri-state dispatch accounting printed (with
                   --dispatch the in-horizon trace is 100% table hits);
                   --no-cache
                   disables plan memoization; --dispatch answers
                   in-horizon shapes from the compile-time table and
                   demotes the cache to the beyond-horizon fallback.
                   --replicas shards admission across a fleet (implies
                   --mixed), --workers sizes the work-stealing pool
                   (0/1 = sequential oracle, bit-identical results),
                   --slo-ms sets a per-lane deadline whose overload
                   policy sheds (drop) or mode-downgrades (degrade)
                   unmeetable heads. `vortex --serve ...` is an alias
                   for the subcommand. --trace records event-clock
                   spans — zero-perturbation: outcomes are bit-identical
                   to an untraced run — as Chrome trace-event JSON,
                   default serve_trace.json (implies --mixed);
                   --metrics / --metrics-json print Prometheus-style
                   counters + exact latency percentiles.)
  vortex audit    [--testbed ...] [--op all|gemm|...] [--dtype f32|f16|bf16]
                  [--lib dump.json] [--dispatch] [--horizon H]
                  [--batch-horizon B] [--deny warnings] [--seed S] [--json]
                  (symbolic plan auditor: proves parallel write-set
                   disjointness, capacity bounds, measurement-alias
                   fixpoints and artifact consistency over whole axis
                   intervals — never at sampled shapes. --lib audits a
                   dumped library including its embedded schema-v3
                   tables; --dispatch builds dispatch tables in process
                   and re-proves every cell's argmin. Exits 1 on any
                   error, or on warnings too with --deny warnings.
                   --json emits the structured diagnostic list instead
                   of the human report; the exit code is unchanged.)
  vortex trace    summarize <trace.json>
                  (parse a Chrome trace-event file written by compile,
                   serve or bench, run the trace-schema audit, and
                   print a per-track/per-span-name time breakdown.
                   Exits 1 on parse or schema errors.)
  vortex bench    <fig3|fig5|table5|table6|fig13|offline|fig14|fig15|table7|fig16|ablation|ops|serve|decode|all>
                  [--out results/] [--seed S] [--full]
  vortex info
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "compile" => cmd_compile(&args),
        "select" => cmd_select(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "audit" => cmd_audit(&args),
        "trace" => cmd_trace(&args),
        "bench" => cmd_bench(&args),
        "info" => cmd_info(),
        // `vortex --serve ...` flag form (serving-mode alias).
        _ if args.has_flag("serve") => cmd_serve(&args),
        _ => print!("{USAGE}"),
    }
}

fn testbed_of(args: &Args) -> vortex::hw::HwSpec {
    let name = args.get_or("testbed", "sim-a100");
    presets::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown testbed {name}; using sim-a100");
        presets::a100()
    })
}

fn dtype_of(args: &Args, hw: &vortex::hw::HwSpec) -> DType {
    match args.get("dtype") {
        Some(d) => DType::parse(d).expect("bad --dtype"),
        None => {
            if hw.name == "a100" {
                DType::F16
            } else {
                DType::F32
            }
        }
    }
}

fn op_of(args: &Args) -> OpKind {
    let name = args.get_or("op", "gemm");
    OpKind::parse(name).unwrap_or_else(|| {
        eprintln!("unknown --op {name}; using gemm");
        OpKind::Gemm
    })
}

/// `--trace [PATH]` destination: the parser treats `--trace out.json`
/// as an option and a bare `--trace` (followed by another `--` arg or
/// nothing) as a flag, so accept both and fall back to `default`.
fn trace_path(args: &Args, default: &str) -> Option<PathBuf> {
    args.get("trace")
        .map(PathBuf::from)
        .or_else(|| args.has_flag("trace").then(|| PathBuf::from(default)))
}

fn analyzer_of(args: &Args, hw: &vortex::hw::HwSpec) -> AnalyzerConfig {
    match args.get_or("analyzer", "default") {
        "analytical" => AnalyzerConfig::analytical_only(),
        "e0" => AnalyzerConfig::empirical(0),
        "e1" => AnalyzerConfig::empirical(1),
        _ => AnalyzerConfig::default_for(hw),
    }
}

fn cmd_compile(args: &Args) {
    let hw = testbed_of(args);
    let dtype = dtype_of(args, &hw);
    let op = op_of(args);
    let cfg = analyzer_of(args, &hw);
    let seed = args.get_u64("seed", 7);
    println!(
        "offline compile: hw={} op={} dtype={} analyzer={}",
        hw.name,
        op,
        dtype,
        cfg.label()
    );
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let mut opts = CompileOpts {
        cache_dir: args.get("cache-dir").map(std::path::PathBuf::from),
        ..CompileOpts::default()
    };
    // Real-testbed builds fold the AOT artifact set into the cache
    // fingerprint: regenerated Pallas blocks invalidate stale libraries.
    if hw.is_real_testbed() {
        if let Ok(m) = vortex::runtime::Manifest::load(&artifacts_dir(args)) {
            opts.aot_fingerprint = m.fingerprint();
        }
    }
    let mut r = compile(&hw, op, dtype, &cfg, &mut prof, &opts);
    // Offline shape-space partitioning: enumerate the dispatch table
    // for this library's single-library selector and embed it (schema
    // v3) so a deployment loading the dump serves with zero warm-up.
    let mut dispatch_stats = None;
    if args.has_flag("dispatch") {
        use vortex::dispatch::{DispatchConfig, DispatchTable};
        let dcfg = DispatchConfig {
            horizon: args.get_usize("horizon", 256),
            batch_horizon: args.get_usize("batch-horizon", 32),
            ..DispatchConfig::default()
        };
        let selector = Selector::new(hw.clone(), vec![r.library.clone()]);
        let table = DispatchTable::for_selector(&selector, &dcfg);
        r.library.dispatch = table.to_data(&selector);
        dispatch_stats = Some(table.stats);
    }
    let mut t = Table::new("compile report", &["metric", "value"]);
    t.row(vec!["candidates (Algorithm 2)".into(), r.candidates_total.to_string()]);
    t.row(vec!["chains analyzed".into(), r.chains_analyzed.to_string()]);
    t.row(vec!["profile queries".into(), r.profile_queries.to_string()]);
    t.row(vec!["library kernels".into(), r.library.kernels.len().to_string()]);
    t.row(vec![
        "offline time (modeled on target)".into(),
        vortex::util::table::fmt_secs(r.offline_secs),
    ]);
    t.row(vec![
        "wall time here".into(),
        vortex::util::table::fmt_secs(r.wall_secs),
    ]);
    t.row(vec![
        "analysis threads / speedup".into(),
        format!("{} / {:.2}x", r.analysis_threads, r.analysis_speedup()),
    ]);
    t.row(vec!["loaded from cache".into(), r.from_cache.to_string()]);
    if let Some(ds) = &dispatch_stats {
        t.row(vec![
            "dispatch tables (op x mode)".into(),
            format!("{} ({} clamped)", ds.tables, if ds.clamped { "horizons" } else { "none" }),
        ]);
        t.row(vec![
            "dispatch cells (merged / enumerated)".into(),
            format!("{} / {}", ds.cells, ds.cells_enumerated),
        ]);
        t.row(vec![
            "dispatch build time".into(),
            vortex::util::table::fmt_secs(ds.build_secs),
        ]);
    }
    t.print();
    if let Some(path) = trace_path(args, "compile_trace.json") {
        let trace = vortex::obs::compile_trace(&r, dispatch_stats.as_ref());
        std::fs::write(&path, trace.to_chrome_json()).expect("write trace");
        println!(
            "compile trace written to {} (load in chrome://tracing or Perfetto)",
            path.display()
        );
    }
    if let Some(path) = args.get("dump-library") {
        std::fs::write(path, r.library.to_json().dump()).expect("write library");
        println!("library written to {path}");
    }
    if let Some(path) = args.get("emit-manifest") {
        // Regenerate the python micro-kernel manifest from this compile:
        // the gemm_acc entries aot.py lowers for the REAL testbed. The
        // inner tile equals the block (EXPERIMENTS.md §Perf L1).
        // Only contraction-space (rank-3) blocks map onto gemm_acc
        // artifacts; batched tiles would emit name/params nonsense.
        if r.library.op.spec().rank() != 3 {
            eprintln!(
                "--emit-manifest supports contraction-space ops (gemm/conv2d); \
                 op {} has no gemm_acc artifact mapping",
                r.library.op
            );
            return;
        }
        use vortex::util::json::Json;
        let entries: Vec<Json> = r
            .library
            .kernels
            .iter()
            .map(|k| {
                Json::obj(vec![
                    ("name", Json::str(k.artifact_name(r.library.op, dtype))),
                    ("kind", Json::str("gemm_acc")),
                    (
                        "params",
                        Json::obj(vec![
                            ("bm", Json::num(k.l1[0] as f64)),
                            ("bn", Json::num(k.l1[1] as f64)),
                            ("bk", Json::num(k.l1[2] as f64)),
                            ("tm", Json::num(k.l1[0] as f64)),
                            ("tn", Json::num(k.l1[1] as f64)),
                            ("tk", Json::num(k.l1[2] as f64)),
                            ("in_dtype", Json::str(dtype.name())),
                        ]),
                    ),
                ])
            })
            .collect();
        let manifest = Json::obj(vec![
            (
                "comment",
                Json::arr(vec![Json::str(
                    "generated by `vortex compile --emit-manifest` — gemm_acc \
                     blocks only; merge conv/encoder entries by hand (the \
                     attention softmax is a profiler micro-measurement, not \
                     an AOT artifact)",
                )]),
            ),
            ("entries", Json::arr(entries)),
        ]);
        std::fs::write(path, manifest.dump()).expect("write manifest");
        println!("micro-kernel manifest written to {path}");
    }
}

fn cmd_select(args: &Args) {
    let hw = testbed_of(args);
    let dtype = dtype_of(args, &hw);
    let cfg = analyzer_of(args, &hw);
    let seed = args.get_u64("seed", 7);
    let op = op_of(args);
    let (m, n, k) = (
        args.get_usize("m", 128),
        args.get_usize("n", 768),
        args.get_usize("k", 768),
    );
    let space = match op {
        // --b is the batch count (batched GEMM), group count (grouped
        // conv) or head-group count (attention) — each leads the
        // rank-4 iteration space.
        OpKind::BatchedGemm
        | OpKind::GroupedConv2d
        | OpKind::FusedAttention
        | OpKind::CausalAttention => vortex::ir::IterSpace {
            op,
            dims: vortex::ir::Tile::new(&[args.get_usize("b", 8), m, n, k]),
            dtype,
        },
        _ => vortex::ir::IterSpace { op, dims: vortex::ir::Tile::new(&[m, n, k]), dtype },
    };
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let mut libs = vec![
        compile(&hw, op, dtype, &cfg, &mut prof, &CompileOpts::default()).library,
    ];
    if hw.name == "a100" && dtype == DType::F16 {
        libs.push(
            compile(&hw, op, DType::F32, &cfg, &mut prof, &CompileOpts::default())
                .library,
        );
    }
    let selector = Selector::new(hw.clone(), libs);
    let mode = match args.get_or("mode", "adaptive") {
        "cuda" => HwMode::Only("cuda_core_f32"),
        "tensor" => HwMode::Only("tensor_core_f16"),
        _ => HwMode::Adaptive,
    };
    let sel = selector.select(space, mode).expect("selection");
    let k = selector.kernel(&sel);
    let mut t = Table::new(
        &format!("selection for {} {} on {}", op, space.dims, hw.name),
        &["field", "value"],
    );
    t.row(vec!["backend".into(), hw.backends[k.backend].name.into()]);
    t.row(vec!["L0 tile".into(), format!("{:?}", k.l0)]);
    t.row(vec!["L1 tile".into(), format!("{:?}", k.l1)]);
    t.row(vec!["padded problem".into(), format!("{:?}", sel.padded)]);
    t.row(vec!["grid".into(), format!("{:?}", sel.grid)]);
    t.row(vec!["estimated time".into(), vortex::util::table::fmt_secs(sel.est_secs)]);
    t.row(vec![
        "selection overhead".into(),
        vortex::util::table::fmt_secs(sel.select_secs),
    ]);
    t.print();
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn cmd_run(args: &Args) {
    let (m, n, k) = (
        args.get_usize("m", 77),
        args.get_usize("n", 768),
        args.get_usize("k", 768),
    );
    let engine = RealEngine::load(&artifacts_dir(args)).expect("engine");
    let hw = presets::cpu_pjrt();
    println!("profiling micro-kernel blocks on the real engine...");
    let lib = build_real_library(&engine, &hw, DType::F32, 2).expect("library");
    let selector = Selector::new(hw, vec![lib]);
    let c = Contraction { m, n, k, dtype: DType::F32 };
    let sel = selector.select(c, HwMode::Adaptive).expect("selection");
    let kern = selector.kernel(&sel);
    println!(
        "selected block {:?} (L0 {:?}), grid {:?}, padded {:?}",
        kern.l1, kern.l0, sel.grid, sel.padded
    );
    let mut rng = Rng::new(42);
    let a = rng.normal_f32_vec(m * k);
    let b = rng.normal_f32_vec(k * n);
    let t0 = std::time::Instant::now();
    let out = engine
        .gemm_dynamic(&a, &b, (m, n, k), kern.l1.to3(), DType::F32)
        .expect("gemm");
    let dt = t0.elapsed().as_secs_f64();
    let gflops = 2.0 * m as f64 * n as f64 * k as f64 / dt / 1e9;
    println!(
        "real GEMM {}x{}x{} in {:.2} ms -> {:.2} GFLOP/s (select {:.1} us)",
        m,
        n,
        k,
        dt * 1e3,
        gflops,
        sel.select_secs * 1e6
    );
    if args.has_flag("verify") {
        let want = gemm_host_ref(&a, &b, m, n, k);
        let worst = out
            .iter()
            .zip(want.iter())
            .map(|(g, w)| ((g - w).abs() / (1.0 + w.abs())) as f64)
            .fold(0.0, f64::max);
        println!(
            "verification: worst rel err {:.2e} — {}",
            worst,
            if worst < 1e-3 { "OK" } else { "FAIL" }
        );
    }
}

fn cmd_serve(args: &Args) {
    let n_req = args.get_usize("requests", 200);
    let gap = args.get_f64("mean-gap-us", 500.0) * 1e-6;
    let max_batch = args.get_usize("max-batch", 8);
    let seed = args.get_u64("seed", 7);
    // Tracing and metrics live in the mixed/fleet serving loop, so
    // either implies the --mixed scenario.
    let observed = trace_path(args, "serve_trace.json").is_some()
        || args.has_flag("metrics")
        || args.has_flag("metrics-json");
    if args.has_flag("mixed")
        || args.has_flag("decode")
        || args.get("replicas").is_some()
        || observed
    {
        // Only an EXPLICIT --max-batch overrides the scenario's
        // per-lane caps (the legacy default of 8 is not implied).
        let max_batch = args.get("max-batch").and_then(|v| v.parse().ok());
        return cmd_serve_mixed(
            n_req,
            gap,
            seed,
            !args.has_flag("no-cache"),
            args.has_flag("dispatch"),
            max_batch,
            args,
        );
    }
    let hw = presets::a100();
    let cfg = AnalyzerConfig::default_for(&hw);
    let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
    let lib = compile(&hw, OpKind::Gemm, DType::F32, &cfg, &mut prof, &CompileOpts::default())
        .library;
    let selector = Selector::new(hw.clone(), vec![lib]);
    let trace = coordinator::server::gen_trace(n_req, gap, 1, 476, seed);
    let mut engine = coordinator::server::SimEngine { sim: Simulator::new(hw, seed) };
    let scfg = coordinator::ServerConfig { max_batch, ..Default::default() };
    let stats = coordinator::server::serve_trace(&mut engine, &selector, &scfg, &trace);
    println!(
        "served {} requests in {} batches (mean batch {:.2})",
        n_req,
        stats.batches,
        stats.mean_batch()
    );
    println!("{}", stats.metrics.summary());
}

/// Multi-op serving: BERT token traffic + vision bursts through the
/// request lanes, with the bucketed plan cache (unless disabled) and
/// optionally the compile-time dispatch table in front of it. With
/// `--replicas N` the trace shards across a fleet (`--workers K` for
/// the work-stealing pool, `--routing hash|load`, `--slo-ms D` +
/// `--slo-policy serve|drop|degrade` for per-lane deadlines).
#[allow(clippy::too_many_arguments)]
fn cmd_serve_mixed(
    n_req: usize,
    gap: f64,
    seed: u64,
    cache: bool,
    dispatch: bool,
    max_batch: Option<usize>,
    args: &Args,
) {
    use vortex::serve::{
        scenario, serve_fleet, serve_mixed_trace, FleetConfig, LaneClass, LaneSlo,
        OverloadPolicy, RoutePolicy, SimLaneEngine,
    };
    let hw = presets::a100();
    let selector = scenario::demo_selector(seed);
    // --decode swaps the workload: autoregressive sequences through
    // the continuous-batching lane, one causal step per token.
    let trace = if args.has_flag("decode") {
        let mean_tokens = args.get_usize("mean-tokens", 24);
        scenario::decode_trace(n_req, gap, mean_tokens, seed, DType::F32)
    } else {
        scenario::mixed_trace(n_req, gap, seed, DType::F32)
    };
    let trace_out = trace_path(args, "serve_trace.json");
    let mut serve_cfg = if cache {
        scenario::serving_config()
    } else {
        scenario::serving_config().without_cache()
    };
    serve_cfg.trace = trace_out.is_some();
    if dispatch {
        serve_cfg = serve_cfg.with_dispatch(scenario::dispatch_config());
    }
    if let Some(mb) = max_batch {
        for class in LaneClass::ALL {
            serve_cfg.lane_mut(class).max_batch = mb;
        }
    }
    if let Some(ms) = args.get("slo-ms").and_then(|v| v.parse::<f64>().ok()) {
        let policy = match args.get_or("slo-policy", "serve") {
            "drop" => OverloadPolicy::Drop,
            "degrade" => OverloadPolicy::Degrade(HwMode::Only("cuda_core_f32")),
            _ => OverloadPolicy::ServeAnyway,
        };
        let slo = LaneSlo::with_deadline(ms * 1e-3).with_policy(policy);
        for class in LaneClass::ALL {
            serve_cfg.lane_mut(class).slo = slo;
        }
    }

    let replicas = args.get_usize("replicas", 1);
    let workers = args.get_usize("workers", 0);
    if replicas > 1 || workers > 1 {
        let routing = match args.get_or("routing", "hash") {
            "load" => RoutePolicy::LeastLoaded,
            _ => RoutePolicy::HashKey,
        };
        let cfg = FleetConfig { replicas, workers, routing, serve: serve_cfg };
        let make_engine = || SimLaneEngine { sim: Simulator::new(hw.clone(), seed) };
        let stats = serve_fleet(make_engine, &selector, &cfg, &trace);
        for d in &stats.slo_diags {
            eprintln!("slo audit: {d}");
        }
        for d in &stats.table_diags {
            eprintln!("table adoption: {d}");
        }
        let (p50, _, p99) = stats.latency_percentiles();
        println!(
            "fleet: {} replicas ({} routing), {} workers — served {} of {} offered \
             ({} degraded, {} dropped): span {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
            replicas,
            routing.name(),
            workers,
            stats.count(),
            stats.offered(),
            stats.degraded(),
            stats.drops.len(),
            stats.span_secs * 1e3,
            p50 * 1e3,
            p99 * 1e3,
        );
        for (i, rep) in stats.replicas.iter().enumerate() {
            let (rp50, _, rp99) = rep.latency_percentiles();
            println!(
                "  replica {i}: {} served / {} dropped, span {:.2} ms, \
                 p50 {:.2} ms, p99 {:.2} ms, {}:{}:{} table:cache:fresh",
                rep.count(),
                rep.drops.len(),
                rep.span_secs * 1e3,
                rp50 * 1e3,
                rp99 * 1e3,
                rep.dispatch.table,
                rep.dispatch.cache,
                rep.dispatch.fresh,
            );
        }
        if let Some(path) = &trace_out {
            write_trace(path, stats.trace.as_ref());
        }
        if args.has_flag("metrics") || args.has_flag("metrics-json") {
            let snap = vortex::obs::snapshot_fleet(&stats);
            if args.has_flag("metrics") {
                print!("{}", snap.to_prometheus());
            }
            if args.has_flag("metrics-json") {
                println!("{}", snap.to_json().dump());
            }
        }
        return;
    }
    let mut engine = SimLaneEngine { sim: Simulator::new(hw, seed) };
    let stats = serve_mixed_trace(&mut engine, &selector, &serve_cfg, &trace);
    for d in &stats.table_diags {
        eprintln!("table adoption: {d}");
    }
    bench::exp_serve::lanes_table("multi-op serving lanes", &stats).print();
    let (p50, _, p99) = stats.latency_percentiles();
    println!(
        "served {} requests across {} lanes: span {:.2} ms, p50 {:.2} ms, p99 {:.2} ms, sched {:.2}%",
        stats.count(),
        stats.lanes.len(),
        stats.span_secs * 1e3,
        p50 * 1e3,
        p99 * 1e3,
        100.0 * stats.sched_fraction()
    );
    if dispatch {
        let b = stats.dispatch_build.clone().unwrap_or_default();
        println!(
            "dispatch table: {} table hits / {} cache hits / {} fresh \
             (warm-start rate {:.1}%; {} tables, {} cells merged from {}, \
             built offline in {:.1} ms{})",
            stats.dispatch.table,
            stats.dispatch.cache,
            stats.dispatch.fresh,
            100.0 * stats.dispatch.warm_start_rate(),
            b.tables,
            b.cells,
            b.cells_enumerated,
            b.build_secs * 1e3,
            if b.clamped { "; horizons clamped by cell budget" } else { "" }
        );
    }
    if cache {
        println!(
            "plan cache: {} hits / {} misses / {} evictions (hit rate {:.1}%)",
            stats.cache.hits,
            stats.cache.misses,
            stats.cache.evictions,
            100.0 * stats.cache.hit_rate()
        );
    } else {
        println!("plan cache disabled (--no-cache): every batch ran fresh selection");
    }
    if args.has_flag("decode") {
        // Per-STEP accounting: one count per event-clock decode step —
        // the granularity the zero-scan claim is made at.
        let bd = stats.batch_dispatch();
        println!(
            "decode steps: {} table / {} cache / {} fresh (per-step warm-start rate {:.1}%)",
            bd.table,
            bd.cache,
            bd.fresh,
            100.0 * bd.warm_start_rate()
        );
    }
    if let Some(path) = &trace_out {
        write_trace(path, stats.trace.as_ref());
    }
    if args.has_flag("metrics") || args.has_flag("metrics-json") {
        let snap = vortex::obs::snapshot_mixed(&stats);
        if args.has_flag("metrics") {
            print!("{}", snap.to_prometheus());
        }
        if args.has_flag("metrics-json") {
            println!("{}", snap.to_json().dump());
        }
    }
}

fn write_trace(path: &Path, trace: Option<&vortex::obs::Trace>) {
    match trace {
        Some(t) => {
            std::fs::write(path, t.to_chrome_json()).expect("write trace");
            println!(
                "serve trace written to {} (load in chrome://tracing or Perfetto)",
                path.display()
            );
        }
        None => eprintln!("no trace recorded (tracing was not enabled for this run)"),
    }
}

/// Symbolic plan auditor over a preset's full op × dtype grid (or a
/// dumped library file): every diagnostic is printed, the exit code is
/// the CI gate.
fn cmd_audit(args: &Args) {
    use vortex::analysis::{audit_dispatch_table, AuditConfig, PlanAuditor};
    use vortex::compiler::MicroKernelLibrary;
    use vortex::dispatch::{DispatchConfig, DispatchTable};
    let hw = testbed_of(args);
    let seed = args.get_u64("seed", 7);
    let acfg = AuditConfig {
        horizon: args.get_usize("horizon", 128),
        batch_horizon: args.get_usize("batch-horizon", 8),
    };
    // The selector under audit: a dumped library file, or a fresh
    // in-process compile of the preset's op × dtype grid (analytical
    // analyzer unless overridden — the audit proves plan invariants,
    // not cost-model accuracy, so the cheap analyzer is the default).
    let libs: Vec<MicroKernelLibrary> = if let Some(path) = args.get("lib") {
        let text = std::fs::read_to_string(path).expect("read --lib file");
        let json = vortex::util::json::Json::parse(&text).expect("parse --lib JSON");
        vec![MicroKernelLibrary::from_json(&json).expect("library schema")]
    } else {
        let cfg = if args.get("analyzer").is_some() {
            analyzer_of(args, &hw)
        } else {
            AnalyzerConfig::analytical_only()
        };
        let ops: Vec<OpKind> = match args.get("op") {
            None | Some("all") => OpKind::ALL.to_vec(),
            Some(_) => vec![op_of(args)],
        };
        let dtypes: Vec<DType> = match args.get("dtype") {
            Some(d) => vec![DType::parse(d).expect("bad --dtype")],
            None => {
                // One dtype per backend element width, read off the
                // backend-name suffix (cuda_core_f32 → f32, mxu_bf16 →
                // bf16) — the grid CI proves is the grid that serves.
                let mut v: Vec<DType> = hw
                    .backends
                    .iter()
                    .filter_map(|b| b.name.rsplit('_').next().and_then(DType::parse))
                    .collect();
                v.sort_by_key(|d| d.name());
                v.dedup();
                if v.is_empty() {
                    v.push(DType::F32);
                }
                v
            }
        };
        let mut prof = SimProfiler::new(Simulator::new(hw.clone(), seed));
        let mut libs = Vec::new();
        for &dtype in &dtypes {
            for &op in &ops {
                libs.push(
                    compile(&hw, op, dtype, &cfg, &mut prof, &CompileOpts::default())
                        .library,
                );
            }
        }
        libs
    };
    let selector = Selector::new(hw.clone(), libs);
    let manifest = if hw.is_real_testbed() {
        vortex::runtime::Manifest::load(&artifacts_dir(args)).ok()
    } else {
        None
    };
    let mut auditor = PlanAuditor::new(&selector, acfg.clone());
    if let Some(m) = &manifest {
        auditor = auditor.with_manifest(m);
    }
    let mut report = auditor.audit();
    if args.has_flag("dispatch") {
        let dcfg = DispatchConfig {
            horizon: acfg.horizon,
            batch_horizon: acfg.batch_horizon,
            max_cells: 1 << 17,
            ..DispatchConfig::default()
        };
        let table = DispatchTable::for_selector(&selector, &dcfg);
        report.merge(audit_dispatch_table(&selector, &table));
    }
    if args.has_flag("json") {
        // The same findings as the human report, machine-shaped:
        // stable family.code strings plus op/mode/kernel/axis context.
        println!("{}", report.to_json().dump());
    } else {
        for d in &report.diagnostics {
            println!("{d}");
        }
        println!("audit ({}): {}", hw.name, report.summary());
    }
    let deny = matches!(args.get("deny"), Some("warnings"));
    if !report.is_clean(deny) {
        std::process::exit(1);
    }
}

/// `vortex trace summarize <file.json>`: parse a Chrome trace-event
/// file back into a [`vortex::obs::Trace`], audit it against the
/// schema invariants ([`vortex::analysis::audit_trace`]), and print
/// the per-track/per-span-name breakdown. Exit 1 on parse or schema
/// errors — the CI trace-schema gate in executable form.
fn cmd_trace(args: &Args) {
    use vortex::analysis::audit_trace;
    use vortex::obs::Trace;
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    let path = args.positional.get(2);
    let (Some(path), "summarize") = (path, sub) else {
        eprintln!("usage: vortex trace summarize <trace.json>");
        std::process::exit(2);
    };
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("read {path}: {e}");
        std::process::exit(1);
    });
    let trace = Trace::from_chrome_json(&text).unwrap_or_else(|e| {
        eprintln!("{path} is not a Vortex Chrome trace: {e}");
        std::process::exit(1);
    });
    let report = audit_trace(&trace);
    for d in &report.diagnostics {
        println!("{d}");
    }
    trace.summary_table().print();
    println!(
        "{} spans across {} processes / {} thread tracks: {} errors, {} warnings",
        report.spans_checked,
        trace.processes.len(),
        trace.threads.len(),
        report.errors(),
        report.warnings()
    );
    if !report.is_clean(false) {
        std::process::exit(1);
    }
}

fn cmd_bench(args: &Args) {
    let name = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let out = PathBuf::from(args.get_or("out", "results"));
    let seed = args.get_u64("seed", 7);
    let fast = !args.has_flag("full");
    let tables = bench::run(name, &out, seed, fast);
    for t in tables {
        println!();
        t.print();
    }
    println!("\nCSV series written under {}/", out.display());
}

fn cmd_info() {
    for hw in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
        let mut t = Table::new(
            &format!("hardware preset: {}", hw.name),
            &["level", "name", "capacity", "bw GB/s", "units", "binding", "analyzer"],
        );
        let rk = RKernel::for_hw(&hw, &[0, 1]);
        for (i, l) in hw.levels.iter().enumerate() {
            t.row(vec![
                format!("L{}", i),
                l.name.into(),
                format!("{}", l.capacity_bytes),
                format!("{}", l.load_bw_gbps),
                l.unit_count.to_string(),
                rk.layers[i].binding.into(),
                format!("{:?}", rk.layers[i].analyzer),
            ]);
        }
        t.print();
        for b in &hw.backends {
            println!(
                "  backend {}: {} GFLOP/s peak, ISA {:?}, {}B elems",
                b.name, b.peak_gflops, b.isa, b.dtype_bytes
            );
        }
        println!();
    }
    let p = TensorProgram::conv2d((8, 56, 56, 64), (3, 3, 128), (2, 1, 1), DType::F32)
        .unwrap();
    println!(
        "implicit-GEMM example: {} -> contraction {:?}",
        p.id(),
        p.contraction().dims()
    );
    let dw = TensorProgram::conv2d((8, 28, 28, 128), (3, 3, 128), (1, 1, 128), DType::F32)
        .unwrap();
    println!(
        "depthwise example: {} -> {} space {:?}",
        dw.id(),
        dw.space().op,
        dw.space().dims
    );
}
