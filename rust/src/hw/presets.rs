//! Hardware presets (paper Table 2 + this machine's real testbed).

use super::{Backend, HwSpec, MemLevel};

/// NVIDIA Ampere A100-40GB (paper Table 2).
///
/// Hierarchy mapping (paper Table 1): L0 = Warp/registers, L1 =
/// CTA/shared memory, L2 = Grid/global memory.
pub fn a100() -> HwSpec {
    HwSpec {
        name: "a100",
        levels: vec![
            MemLevel {
                name: "reg",
                // 256 KB register file per SM shared by 4 scheduler
                // partitions x 8 co-resident warps at full occupancy: a
                // warp-level candidate's A/B fragments + C accumulator
                // must fit the per-warp share.
                capacity_bytes: 256 * 1024 / 32,
                load_bw_gbps: 4500.0, // shared->reg per warp-scheduler
                unit_count: 4,        // warp schedulers per SM
            },
            MemLevel {
                name: "smem",
                capacity_bytes: 48 * 1024, // 48 KB/SM (Table 2)
                load_bw_gbps: 14.4,        // 1555 GB/s global / 108 SMs
                unit_count: 108,           // SMs
            },
            MemLevel {
                name: "global",
                capacity_bytes: 40 * 1024 * 1024 * 1024,
                load_bw_gbps: 1555.0, // HBM2e aggregate (PCIe ingress unmodeled)
                unit_count: 1,
            },
        ],
        backends: vec![
            Backend {
                name: "cuda_core_f32",
                peak_gflops: 19_500.0,
                isa: [4, 4, 1], // FFMA with float4 vectorization granularity
                dtype_bytes: 4,
                launch_factor: 1.0,
            },
            Backend {
                name: "tensor_core_f16",
                peak_gflops: 312_000.0,
                isa: [16, 8, 16], // mma.sync.aligned.m16n8k16
                dtype_bytes: 2,
                launch_factor: 3.0, // fragment fill + swizzle setup
            },
        ],
        min_util: 0.25,
        max_l0_per_l1: 32, // 1024 threads / 32-thread warps per CTA
        launch_overhead_secs: 4e-6, // CUDA kernel-launch latency class
    }
}

/// Intel Xeon Platinum 8255C, 48 cores (paper Table 2).
///
/// Hierarchy mapping (paper Table 1): L0 = ALU/registers, L1 = thread
/// with CacheBuf (per-core L2 budget), L2 = process/multi-core.
pub fn xeon_8255c() -> HwSpec {
    HwSpec {
        name: "xeon_8255c",
        levels: vec![
            MemLevel {
                name: "reg",
                capacity_bytes: 2 * 1024, // 2 KB vector regs/core (Table 2)
                load_bw_gbps: 400.0,      // L1/L2 -> reg per core
                unit_count: 1,            // one vector pipe domain per core
            },
            MemLevel {
                name: "cachebuf",
                // paper §4.2: CacheBuffer sized within L2 limits (1 MB/core)
                capacity_bytes: 1024 * 1024,
                load_bw_gbps: 2.9, // ~140 GB/s DRAM / 48 cores
                unit_count: 48,    // cores
            },
            MemLevel {
                name: "global",
                capacity_bytes: 250 * 1024 * 1024 * 1024,
                load_bw_gbps: 140.0, // 6-channel DDR4-2933 aggregate
                unit_count: 1,
            },
        ],
        backends: vec![Backend {
            name: "avx512_f32",
            peak_gflops: 7_344.0,
            isa: [1, 16, 1], // one ZMM of f32 lanes
            dtype_bytes: 4,
            launch_factor: 1.0,
        }],
        min_util: 0.25,
        // L0 has no parallel binding on CPU (Table 1: "-"): register
        // blocking inside a thread is serial, so no concurrency cap.
        max_l0_per_l1: 4096,
        launch_overhead_secs: 1e-6, // thread-pool dispatch, no driver
    }
}

/// The REAL testbed: this machine's single-core CPU PJRT client.
///
/// TPU-flavoured adaptation (DESIGN.md §3): the on-chip tier is a
/// VMEM-analog working-set budget (sized so XLA CPU keeps tiles
/// L2-resident), and the ISA granularity is the Pallas sublane/lane tile
/// the micro-kernels are built on — (8, 128, 128) plays the role the MMA
/// shape plays on the A100. Peak numbers are calibrated by
//  `profiler::calibrate` and are intentionally conservative defaults.
pub fn cpu_pjrt() -> HwSpec {
    HwSpec {
        name: "cpu_pjrt",
        levels: vec![
            MemLevel {
                // dot tier: the working set one XLA-native dot (the MXU
                // analog on this testbed) consumes — L2-cache resident.
                // Block-sized inner tiles are the hardware-aware choice
                // here (EXPERIMENTS.md §Perf: 17x over sub-tiling).
                name: "reg",
                capacity_bytes: 4 * 1024 * 1024,
                load_bw_gbps: 40.0,
                unit_count: 1,
            },
            MemLevel {
                name: "vmem", // staging working-set budget (L3-resident)
                capacity_bytes: 8 * 1024 * 1024,
                load_bw_gbps: 12.0,
                unit_count: 1,
            },
            MemLevel {
                name: "dram",
                capacity_bytes: 8 * 1024 * 1024 * 1024,
                load_bw_gbps: 12.0, // single-channel DDR
                unit_count: 1,
            },
        ],
        backends: vec![
            Backend {
                name: "mxu_f32",
                peak_gflops: 40.0,
                isa: [8, 128, 128], // pallas sublane/lane/contraction tile
                dtype_bytes: 4,
                launch_factor: 1.0,
            },
            Backend {
                name: "mxu_bf16",
                peak_gflops: 60.0,
                isa: [8, 128, 128],
                dtype_bytes: 2,
                launch_factor: 1.0,
            },
        ],
        min_util: 0.01,
        max_l0_per_l1: 4096, // single core: pallas grid steps are serial
        // One PJRT executable invocation per block: client call +
        // buffer hand-off dominates (measured order of magnitude).
        launch_overhead_secs: 30e-6,
    }
}

/// All simulated paper testbeds (the real one is `cpu_pjrt`).
pub fn by_name(name: &str) -> Option<HwSpec> {
    match name {
        "a100" | "sim-a100" => Some(a100()),
        "xeon_8255c" | "sim-xeon" => Some(xeon_8255c()),
        "cpu_pjrt" | "real" => Some(cpu_pjrt()),
        _ => None,
    }
}
