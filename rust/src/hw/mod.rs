//! Hardware hierarchy model (paper §2.3, Table 2).
//!
//! Every target — the paper's A100 GPU and Xeon 8255c CPU (simulated) and
//! this machine's CPU-PJRT testbed (real) — is described by the same
//! 3-level [`HwSpec`]: level 0 is the compute/register tier (Warp/ALU),
//! level 1 the on-chip staging tier (SharedMem / CacheBuf / VMEM-analog),
//! level 2 the device/global tier. Candidate generation (Algorithm 2),
//! the analytical cost model (Eqs. 2–4) and the performance simulator all
//! read hardware limits exclusively from these structs.

pub mod presets;

/// One tier of the memory/compute hierarchy.
#[derive(Debug, Clone, PartialEq)]
pub struct MemLevel {
    /// Display name ("reg", "smem", "global", ...).
    pub name: &'static str,
    /// Working-set budget for a candidate tile at this level, per unit,
    /// in bytes (paper: "assessing memory usage against layer-specific
    /// limits").
    pub capacity_bytes: u64,
    /// Per-unit bandwidth for loading from the level above, GB/s.
    pub load_bw_gbps: f64,
    /// Parallel execution units at this level, per unit of the level
    /// above (warps per SM, SMs per device, cores per socket, ...).
    pub unit_count: u32,
}

/// A compute backend reachable from level 0 (paper §6.2: CUDA cores vs
/// Tensor cores; the runtime selects adaptively between them).
#[derive(Debug, Clone, PartialEq)]
pub struct Backend {
    pub name: &'static str,
    /// Whole-chip peak, GFLOP/s.
    pub peak_gflops: f64,
    /// ISA instruction granularity (FilterByISA, Algorithm 2): candidate
    /// L0 tiles must be multiples of (m, n, k).
    pub isa: [usize; 3],
    /// Bytes per input element.
    pub dtype_bytes: usize,
    /// Multiplier on kernel-launch overhead for this backend (tensor-
    /// core kernels pay extra fragment-fill/swizzle setup per launch —
    /// the effect that lets CUDA cores win tiny-M GEMMs in Fig. 16).
    pub launch_factor: f64,
}

impl Backend {
    /// Peak GFLOP/s available to a single level-0 unit.
    pub fn peak_per_l0_unit(&self, spec: &HwSpec) -> f64 {
        let total_units: u64 = spec.levels.iter().map(|l| l.unit_count as u64).product();
        self.peak_gflops / total_units as f64
    }
}

/// A full hardware target.
#[derive(Debug, Clone, PartialEq)]
pub struct HwSpec {
    pub name: &'static str,
    /// `levels[0]` = compute tier ... `levels[last]` = global tier. Always 3
    /// tiers in this repo (paper §6.1: "for both CPU and GPU, we set the
    /// hierarchy level to three").
    pub levels: Vec<MemLevel>,
    pub backends: Vec<Backend>,
    /// Utilization window for candidate pruning (paper §2.3/Fig. 5):
    /// candidates whose per-level working set falls below `min_util` of
    /// capacity are wasteful; above 1.0 they spill. Expressed as a
    /// fraction of `capacity_bytes`.
    pub min_util: f64,
    /// Max level-0 tiles that may execute concurrently inside one
    /// level-1 unit (the paper's "1024 threads-per-block" constraint:
    /// 32 warps/CTA on A100).
    pub max_l0_per_l1: u32,
    /// Per-launch overhead in seconds, before the backend's
    /// `launch_factor` multiplier (measured on the real testbed;
    /// simulator value on the paper testbeds). Owned by the preset —
    /// like [`HwSpec::is_real_testbed`], callers must not re-derive
    /// this from `name` string comparisons.
    pub launch_overhead_secs: f64,
}

impl HwSpec {
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn level(&self, l: usize) -> &MemLevel {
        &self.levels[l]
    }

    pub fn backend(&self, name: &str) -> Option<&Backend> {
        self.backends.iter().find(|b| b.name == name)
    }

    pub fn backend_idx(&self, name: &str) -> Option<usize> {
        self.backends.iter().position(|b| b.name == name)
    }

    /// True for the REAL testbed (PJRT CPU today): one executable
    /// dispatch per parallel block, and the micro-kernel library is
    /// backed by AOT artifacts (so compile caches must fold in the
    /// artifact fingerprint). The single place the "which testbed is
    /// real" question is answered — callers must not re-derive it
    /// from `name` string comparisons.
    pub fn is_real_testbed(&self) -> bool {
        self.name == "cpu_pjrt"
    }

    /// Total parallel units at `level` across the whole chip
    /// (e.g. warps: 4 * 108 on A100).
    pub fn total_units_at(&self, level: usize) -> u64 {
        self.levels[level..].iter().map(|l| l.unit_count as u64).product()
    }

    /// GEMM working-set bytes for a tile at a given level: the A slab,
    /// B slab and C accumulator that must co-reside at that tier.
    pub fn gemm_working_set(tile: [usize; 3], in_bytes: usize) -> u64 {
        let [m, n, k] = tile;
        // C accumulates in f32 regardless of input dtype.
        (m * k * in_bytes + k * n * in_bytes + m * n * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn all_presets_have_three_levels() {
        for spec in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
            assert_eq!(spec.n_levels(), 3, "{}", spec.name);
            assert!(!spec.backends.is_empty());
            for b in &spec.backends {
                assert!(b.peak_gflops > 0.0);
                assert!(b.isa.iter().all(|&g| g > 0));
            }
        }
    }

    #[test]
    fn launch_overhead_is_a_preset_field() {
        // The per-launch overhead lives in the spec (like
        // `is_real_testbed`), not in scattered name matches: every
        // preset declares a positive value, and the real single-core
        // PJRT testbed pays more per dispatch than the GPU/CPU sims.
        for spec in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
            assert!(spec.launch_overhead_secs > 0.0, "{}", spec.name);
        }
        assert!(
            presets::cpu_pjrt().launch_overhead_secs
                > presets::a100().launch_overhead_secs
        );
    }

    #[test]
    fn capacity_increases_up_the_hierarchy() {
        for spec in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
            for w in spec.levels.windows(2) {
                assert!(
                    w[0].capacity_bytes < w[1].capacity_bytes,
                    "{}: {} !< {}",
                    spec.name,
                    w[0].name,
                    w[1].name
                );
            }
        }
    }

    #[test]
    fn per_unit_bandwidth_is_positive_and_inner_tier_fastest() {
        // levels[0].load_bw is per-L0-unit and must exceed the per-unit
        // share of the staging tier; the top level holds the aggregate
        // DRAM bandwidth used by the whole-problem roofline.
        for spec in [presets::a100(), presets::xeon_8255c(), presets::cpu_pjrt()] {
            assert!(spec.levels.iter().all(|l| l.load_bw_gbps > 0.0));
            assert!(
                spec.levels[0].load_bw_gbps >= spec.levels[1].load_bw_gbps,
                "{}",
                spec.name
            );
        }
    }

    #[test]
    fn working_set_math() {
        // 64x128x256 f32: A 64*256*4 + B 256*128*4 + C 64*128*4
        let ws = HwSpec::gemm_working_set([64, 128, 256], 4);
        assert_eq!(ws, (64 * 256 * 4 + 256 * 128 * 4 + 64 * 128 * 4) as u64);
    }

    #[test]
    fn a100_tensor_core_is_faster_than_cuda_core() {
        let a100 = presets::a100();
        let cc = a100.backend("cuda_core_f32").unwrap();
        let tc = a100.backend("tensor_core_f16").unwrap();
        assert!(tc.peak_gflops > 10.0 * cc.peak_gflops);
        assert_eq!(tc.isa, [16, 8, 16]); // mma.sync.m16n8k16
    }

    #[test]
    fn total_units() {
        let a100 = presets::a100();
        assert_eq!(a100.total_units_at(2), 1);
        assert_eq!(a100.total_units_at(1), 108);
        assert_eq!(a100.total_units_at(0), 4 * 108);
    }
}
