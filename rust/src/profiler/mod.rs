//! Empirical profiling drivers (the "E" half of the hybrid analyzer).
//!
//! On simulated testbeds the profiler queries the [`crate::sim`]
//! simulator — including the hidden micro-architectural factors the
//! analytical model cannot see — and *accounts for the tuning time* each
//! query would have cost on real hardware (kernel compile + launch +
//! run), which is what the paper's offline-overhead numbers (§7.4,
//! Table 7) measure. On the real testbed the profiler wall-clocks the
//! AOT PJRT executables (see `runtime::RealProfiler`).

use std::collections::HashMap;

use crate::cost::Strategy;
use crate::ir::{DType, OpKind, Tile};
use crate::sim::Simulator;

/// Source of empirical measurements for the hybrid analyzer.
pub trait Profiler {
    /// True cost of the subchain `strat.tiles[..=level]` (one unit's
    /// execution of the nested tiles up to `level`).
    fn measure_subchain(&mut self, dtype: DType, strat: &Strategy, level: usize)
        -> f64;

    /// True end-to-end cost of the full chain (DietCode-style whole
    /// kernel profiling).
    fn measure_full(&mut self, dtype: DType, strat: &Strategy) -> f64;

    /// Accumulated offline tuning wall-clock attributable to profiling.
    fn tuning_secs(&self) -> f64;

    /// Number of profiling queries issued.
    fn queries(&self) -> usize;

    /// Identity of the measurement source (e.g. the simulator seed):
    /// libraries built from different sources must not alias in the
    /// on-disk compile cache.
    fn fingerprint(&self) -> u64 {
        0
    }
}

/// Simulator-backed profiler for the paper's testbeds.
pub struct SimProfiler {
    pub sim: Simulator,
    /// Fixed per-query harness overhead on real hardware (codegen +
    /// compile + launch + timing loop); dominates tuning time.
    pub per_query_overhead: f64,
    tuning: f64,
    queries: usize,
    cache: HashMap<(OpKind, Vec<Tile>, usize, usize), f64>,
}

impl SimProfiler {
    pub fn new(sim: Simulator) -> SimProfiler {
        // ~0.1 s per profiled candidate: matches the paper's §7.4
        // arithmetic (e.g. E:L0 on CPU = 260-ish candidates → ~30 s).
        SimProfiler {
            sim,
            per_query_overhead: 0.1,
            tuning: 0.0,
            queries: 0,
            cache: HashMap::new(),
        }
    }

    fn account(&mut self, kernel_secs: f64) {
        self.queries += 1;
        // Adaptive repeats, as real tuning harnesses do: short kernels
        // are re-run to stabilize the measurement, long kernels once,
        // and catastrophic configs are killed by the TVM-style timeout.
        const TIMEOUT: f64 = 1.0;
        let reps = (0.3 / kernel_secs.max(1e-9)).ceil().clamp(1.0, 3.0);
        self.tuning += self.per_query_overhead + (reps * kernel_secs).min(TIMEOUT);
    }
}

impl Profiler for SimProfiler {
    fn measure_subchain(
        &mut self,
        dtype: DType,
        strat: &Strategy,
        level: usize,
    ) -> f64 {
        // Keyed by the MEASUREMENT op: ops whose formulas are exact
        // delegations (Conv2d -> Gemm) share one measurement instead of
        // re-profiling identical subchains.
        let key = (
            strat.op.spec().measurement_op(),
            strat.tiles[..=level].to_vec(),
            strat.backend,
            dtype.bytes(),
        );
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        let secs = match level {
            0 => self.sim.true_l0_secs(dtype, strat),
            1 => self.sim.true_subchain_secs(dtype, strat),
            _ => panic!("empirical profiling only supported at L0/L1"),
        };
        self.account(secs);
        self.cache.insert(key, secs);
        secs
    }

    fn measure_full(&mut self, dtype: DType, strat: &Strategy) -> f64 {
        let secs = self.sim.execute(dtype, strat);
        self.account(secs);
        secs
    }

    fn tuning_secs(&self) -> f64 {
        self.tuning
    }

    fn queries(&self) -> usize {
        self.queries
    }

    fn fingerprint(&self) -> u64 {
        self.sim.seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    fn mk() -> (SimProfiler, Strategy) {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let strat =
            Strategy::new(vec![[16, 8, 16], [64, 64, 32], [512, 512, 512]], bi);
        (SimProfiler::new(Simulator::new(hw, 3)), strat)
    }

    #[test]
    fn caches_repeat_queries() {
        let (mut p, s) = mk();
        let a = p.measure_subchain(DType::F16, &s, 0);
        let b = p.measure_subchain(DType::F16, &s, 0);
        assert_eq!(a, b);
        assert_eq!(p.queries(), 1, "second query must hit the cache");
    }

    #[test]
    fn accounts_tuning_time() {
        let (mut p, s) = mk();
        p.measure_subchain(DType::F16, &s, 0);
        p.measure_subchain(DType::F16, &s, 1);
        assert_eq!(p.queries(), 2);
        assert!(p.tuning_secs() >= 2.0 * p.per_query_overhead);
    }

    #[test]
    fn subchain_l1_ge_l0() {
        let (mut p, s) = mk();
        let l0 = p.measure_subchain(DType::F16, &s, 0);
        let l1 = p.measure_subchain(DType::F16, &s, 1);
        assert!(l1 > l0, "L1 subchain contains L0: {} vs {}", l1, l0);
    }
}
