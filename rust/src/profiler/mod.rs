//! Empirical profiling drivers (the "E" half of the hybrid analyzer).
//!
//! On simulated testbeds the profiler queries the [`crate::sim`]
//! simulator — including the hidden micro-architectural factors the
//! analytical model cannot see — and *accounts for the tuning time* each
//! query would have cost on real hardware (kernel compile + launch +
//! run), which is what the paper's offline-overhead numbers (§7.4,
//! Table 7) measure. On the real testbed the profiler wall-clocks the
//! AOT PJRT executables (see `runtime::RealProfiler`).

use std::collections::HashMap;

use crate::cost::Strategy;
use crate::ir::{DType, OpKind, Tile};
use crate::sim::Simulator;

/// A point-in-time reading of a profiler's accumulated counters —
/// subtract two snapshots to attribute queries/tuning time to one
/// compile phase (the per-phase spans of
/// [`crate::compiler::CompileReport::phases`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ProfSnapshot {
    pub queries: usize,
    pub tuning_secs: f64,
}

impl ProfSnapshot {
    /// Counter deltas since `earlier` (`self` is the later reading).
    pub fn since(self, earlier: ProfSnapshot) -> ProfSnapshot {
        ProfSnapshot {
            queries: self.queries - earlier.queries,
            tuning_secs: self.tuning_secs - earlier.tuning_secs,
        }
    }
}

/// Source of empirical measurements for the hybrid analyzer.
pub trait Profiler {
    /// True cost of the subchain `strat.tiles[..=level]` (one unit's
    /// execution of the nested tiles up to `level`).
    ///
    /// Ops with a `measurement_op` alias are measured AS the alias:
    /// the same tiles under the alias's key, so aliased ops share one
    /// measurement set. A fused chain op's subchain is priced as
    /// `chain_kernels()` alias blocks, plus the `softmax_tile`
    /// epilogue once the measured subchain reaches the L1 boundary.
    fn measure_subchain(&mut self, dtype: DType, strat: &Strategy, level: usize)
        -> f64;

    /// True end-to-end cost of the full chain (DietCode-style whole
    /// kernel profiling).
    fn measure_full(&mut self, dtype: DType, strat: &Strategy) -> f64;

    /// Measured cost of one fused streaming row-softmax pass over a
    /// (rows x cols) f32 score tile — the attention epilogue
    /// micro-measurement (`OpSpec::softmax_tile` supplies the shape).
    fn measure_softmax(&mut self, rows: usize, cols: usize) -> f64;

    /// Accumulated offline tuning wall-clock attributable to profiling.
    fn tuning_secs(&self) -> f64;

    /// Number of profiling queries issued.
    fn queries(&self) -> usize;

    /// Current counter reading ([`ProfSnapshot::since`] attributes
    /// queries/tuning time to a compile phase).
    fn snapshot(&self) -> ProfSnapshot {
        ProfSnapshot { queries: self.queries(), tuning_secs: self.tuning_secs() }
    }

    /// Identity of the measurement source — the simulator seed PLUS
    /// the definition of every micro-measurement (currently the
    /// softmax per-element op count): libraries built from different
    /// sources or measurement definitions must not alias in the
    /// on-disk compile cache.
    fn fingerprint(&self) -> u64 {
        0
    }
}

/// Default scalar-op count of one streaming row-softmax pass per score
/// element: running max compare, rescale multiply, subtract, exp, sum
/// add on the online sweep; subtract, exp, normalize multiply on the
/// write-back sweep — rounded to a power of two.
pub const SOFTMAX_OPS_PER_ELEM: f64 = 8.0;

/// Simulator-backed profiler for the paper's testbeds.
pub struct SimProfiler {
    pub sim: Simulator,
    /// Fixed per-query harness overhead on real hardware (codegen +
    /// compile + launch + timing loop); dominates tuning time.
    pub per_query_overhead: f64,
    /// Per-element op count of the softmax micro-measurement — an
    /// input of the measurement's definition, folded into
    /// [`Profiler::fingerprint`] so a changed definition invalidates
    /// cached libraries.
    pub softmax_ops_per_elem: f64,
    tuning: f64,
    queries: usize,
    cache: HashMap<(OpKind, Vec<Tile>, usize, usize), f64>,
    softmax_cache: HashMap<(usize, usize), f64>,
}

impl SimProfiler {
    pub fn new(sim: Simulator) -> SimProfiler {
        // ~0.1 s per profiled candidate: matches the paper's §7.4
        // arithmetic (e.g. E:L0 on CPU = 260-ish candidates → ~30 s).
        SimProfiler {
            sim,
            per_query_overhead: 0.1,
            softmax_ops_per_elem: SOFTMAX_OPS_PER_ELEM,
            tuning: 0.0,
            queries: 0,
            cache: HashMap::new(),
            softmax_cache: HashMap::new(),
        }
    }

    fn account(&mut self, kernel_secs: f64) {
        self.queries += 1;
        // Adaptive repeats, as real tuning harnesses do: short kernels
        // are re-run to stabilize the measurement, long kernels once,
        // and catastrophic configs are killed by the TVM-style timeout.
        const TIMEOUT: f64 = 1.0;
        let reps = (0.3 / kernel_secs.max(1e-9)).ceil().clamp(1.0, 3.0);
        self.tuning += self.per_query_overhead + (reps * kernel_secs).min(TIMEOUT);
    }
}

impl Profiler for SimProfiler {
    fn measure_subchain(
        &mut self,
        dtype: DType,
        strat: &Strategy,
        level: usize,
    ) -> f64 {
        let spec = strat.op.spec();
        let meas = spec.measurement_op();
        if meas != strat.op {
            // Measure AS the measurement op: the subchain's blocks ARE
            // the alias's blocks (exact-delegation ops like Conv2d →
            // Gemm measure identically; chain ops execute
            // `chain_kernels()` cost-symmetric alias blocks). Keying
            // and simulating under the alias keeps the cache coherent
            // — a conv measurement IS a gemm measurement, an attention
            // block measurement IS a batched-gemm block measurement.
            let alias = Strategy::for_op(meas, strat.tiles.clone(), strat.backend);
            let block = self.measure_subchain(dtype, &alias, level);
            let mut secs = spec.chain_kernels() as f64 * block;
            // The fused epilogue enters at the L1 tile boundary.
            if level >= 1 {
                if let Some((rows, cols)) = spec.softmax_tile(strat.tiles[level]) {
                    secs += self.measure_softmax(rows, cols);
                }
            }
            return secs;
        }
        let key = (
            strat.op,
            strat.tiles[..=level].to_vec(),
            strat.backend,
            dtype.bytes(),
        );
        if let Some(&v) = self.cache.get(&key) {
            return v;
        }
        let secs = match level {
            0 => self.sim.true_l0_secs(dtype, strat),
            1 => self.sim.true_subchain_secs(dtype, strat),
            _ => panic!("empirical profiling only supported at L0/L1"),
        };
        self.account(secs);
        self.cache.insert(key, secs);
        secs
    }

    fn measure_full(&mut self, dtype: DType, strat: &Strategy) -> f64 {
        let secs = self.sim.execute(dtype, strat);
        self.account(secs);
        secs
    }

    fn measure_softmax(&mut self, rows: usize, cols: usize) -> f64 {
        if let Some(&v) = self.softmax_cache.get(&(rows, cols)) {
            return v;
        }
        let secs = self.sim.softmax_secs(self.softmax_ops_per_elem, rows, cols);
        self.account(secs);
        self.softmax_cache.insert((rows, cols), secs);
        secs
    }

    fn tuning_secs(&self) -> f64 {
        self.tuning
    }

    fn queries(&self) -> usize {
        self.queries
    }

    fn fingerprint(&self) -> u64 {
        // Seed + micro-measurement definitions: a changed softmax op
        // count is a different measurement source and must invalidate
        // cached libraries (ROADMAP offline-stage item).
        crate::util::rng::hash_key(&[self.sim.seed, self.softmax_ops_per_elem.to_bits()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    fn mk() -> (SimProfiler, Strategy) {
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let strat =
            Strategy::new(vec![[16, 8, 16], [64, 64, 32], [512, 512, 512]], bi);
        (SimProfiler::new(Simulator::new(hw, 3)), strat)
    }

    #[test]
    fn caches_repeat_queries() {
        let (mut p, s) = mk();
        let a = p.measure_subchain(DType::F16, &s, 0);
        let b = p.measure_subchain(DType::F16, &s, 0);
        assert_eq!(a, b);
        assert_eq!(p.queries(), 1, "second query must hit the cache");
    }

    #[test]
    fn accounts_tuning_time() {
        let (mut p, s) = mk();
        p.measure_subchain(DType::F16, &s, 0);
        p.measure_subchain(DType::F16, &s, 1);
        assert_eq!(p.queries(), 2);
        assert!(p.tuning_secs() >= 2.0 * p.per_query_overhead);
    }

    #[test]
    fn subchain_l1_ge_l0() {
        let (mut p, s) = mk();
        let l0 = p.measure_subchain(DType::F16, &s, 0);
        let l1 = p.measure_subchain(DType::F16, &s, 1);
        assert!(l1 > l0, "L1 subchain contains L0: {} vs {}", l1, l0);
    }

    #[test]
    fn softmax_measurement_caches_and_accounts() {
        let (mut p, _) = mk();
        let a = p.measure_softmax(128, 64);
        assert!(a > 0.0);
        assert_eq!(a, p.measure_softmax(128, 64));
        assert_eq!(p.queries(), 1, "second softmax query must hit the cache");
        let _ = p.measure_softmax(128, 65);
        assert_eq!(p.queries(), 2);
    }

    #[test]
    fn attention_subchain_decomposes_into_alias_blocks_plus_softmax() {
        // One attention block = 2 batched-gemm blocks + the fused
        // row-softmax over the resident score tile — sharing the
        // batched-gemm measurement cache, so the attention measurement
        // after a batched one issues ONLY the softmax query.
        let hw = presets::a100();
        let bi = hw.backend_idx("tensor_core_f16").unwrap();
        let tiles = vec![
            crate::ir::Tile::new(&[1, 16, 8, 16]),
            crate::ir::Tile::new(&[1, 64, 64, 32]),
        ];
        let bg = Strategy::for_op(OpKind::BatchedGemm, tiles.clone(), bi);
        let at = Strategy::for_op(OpKind::FusedAttention, tiles, bi);
        let mut p = SimProfiler::new(Simulator::new(hw, 3));
        let block = p.measure_subchain(DType::F16, &bg, 1);
        let q_after_bgemm = p.queries();
        let fused = p.measure_subchain(DType::F16, &at, 1);
        assert_eq!(p.queries(), q_after_bgemm + 1, "only the softmax is new");
        let softmax = p.measure_softmax(64, 64);
        assert_eq!(fused, 2.0 * block + softmax);
        // At L0 the softmax has not entered yet (fusion is at L1).
        let at_l0 = p.measure_subchain(DType::F16, &at, 0);
        let bg_l0 = p.measure_subchain(DType::F16, &bg, 0);
        assert_eq!(at_l0, 2.0 * bg_l0);
    }

    #[test]
    fn fingerprint_covers_softmax_measurement_definition() {
        let hw = presets::a100();
        let a = SimProfiler::new(Simulator::new(hw.clone(), 3));
        let mut b = SimProfiler::new(Simulator::new(hw.clone(), 3));
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.softmax_ops_per_elem = 2.0 * SOFTMAX_OPS_PER_ELEM;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let c = SimProfiler::new(Simulator::new(hw, 4));
        assert_ne!(a.fingerprint(), c.fingerprint());
    }
}
