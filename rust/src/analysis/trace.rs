//! Trace-schema sanity checks: the static auditor for the
//! observability layer ([`crate::obs`]).
//!
//! A trace is a claim about what the system did, and a malformed trace
//! is worse than none — a viewer renders it wrong, or a summary
//! silently mis-attributes time. The checks here are the finitely
//! checkable invariants every well-formed Vortex trace satisfies:
//!
//! * **Finite, ordered time** — every timestamp and duration is a
//!   finite number and no duration is negative
//!   (`trace.nonfinite_time`, `trace.negative_duration`).
//! * **Clock discipline** — serving spans (cat `"serve"`) are stamped
//!   from the deterministic event clock ONLY. A wall-clock span in a
//!   serving cat would mean recording perturbed the run — the exact
//!   thing the zero-perturbation contract forbids
//!   (`trace.wall_in_serving`).
//! * **Track exclusivity** — complete spans on one (pid, tid) track
//!   never overlap (beyond [`OVERLAP_EPS_US`] of float rounding): a
//!   lane serves one batch at a time, and the compile pipeline's
//!   phases are contiguous by construction (`trace.overlap`).
//! * **Plan-source vocabulary** — every `"plan"` instant carries a
//!   `source` arg from the closed `table`/`cache`/`fresh` set the
//!   metrics layer counts (`trace.bad_plan_source`).
//! * **Labeled tracks** — every (pid, tid) a span lands on has
//!   process/thread metadata, so viewers show lane names instead of
//!   bare ids (`trace.unlabeled_track`, warning).
//!
//! Wired into `vortex trace summarize` and the CI trace-schema step;
//! the fleet-oracle tracing leg asserts a clean report on every
//! generated trace.

use std::collections::BTreeMap;

use crate::obs::{Span, SpanClock, Trace};

use super::{AuditReport, Diagnostic};

/// Tolerated overlap between adjacent complete spans on one track, in
/// µs (1 ns): adjacent span boundaries are converted seconds → µs
/// independently, so exact contiguity can round to a hair of overlap.
pub const OVERLAP_EPS_US: f64 = 1e-3;

/// Plan-resolution sources the metrics layer counts; a `"plan"` span
/// arg outside this set would silently vanish from every breakdown.
const PLAN_SOURCES: [&str; 3] = ["table", "cache", "fresh"];

fn span_entry(i: usize, s: &Span) -> String {
    format!("span #{i} '{}' @({},{})", s.name, s.pid, s.tid)
}

/// Audit one [`Trace`] against the schema invariants in the module
/// docs. Every span contributes to `spans_checked`, so a clean report
/// on a non-empty trace is a discharged proof, not a vacuous pass.
pub fn audit_trace(trace: &Trace) -> AuditReport {
    let mut report = AuditReport::default();
    let pids: Vec<u64> = trace.processes.iter().map(|(p, _)| *p).collect();
    let tids: Vec<(u64, u64)> = trace.threads.iter().map(|(p, t, _)| (*p, *t)).collect();
    // Per-track complete-span intervals for the exclusivity pass:
    // (start, end, span index), skipping spans already flagged
    // non-finite so the sort below stays total.
    let mut tracks: BTreeMap<(u64, u64), Vec<(f64, f64, usize)>> = BTreeMap::new();
    for (i, s) in trace.spans.iter().enumerate() {
        report.spans_checked += 1;
        let dur = s.dur_us.unwrap_or(0.0);
        if !s.ts_us.is_finite() || !dur.is_finite() {
            report.diagnostics.push(
                Diagnostic::error(
                    "trace.nonfinite_time",
                    format!("ts={} dur={:?} µs", s.ts_us, s.dur_us),
                )
                .with_entry(span_entry(i, s)),
            );
            continue;
        }
        if dur < 0.0 {
            report.diagnostics.push(
                Diagnostic::error(
                    "trace.negative_duration",
                    format!("duration {dur} µs is negative"),
                )
                .with_entry(span_entry(i, s)),
            );
            continue;
        }
        if s.clock == SpanClock::Wall && s.cat == "serve" {
            report.diagnostics.push(
                Diagnostic::error(
                    "trace.wall_in_serving",
                    "wall-clock span in a serving cat — serving spans must be \
                     stamped from the deterministic event clock",
                )
                .with_entry(span_entry(i, s)),
            );
        }
        if !pids.contains(&s.pid) || !tids.contains(&(s.pid, s.tid)) {
            report.diagnostics.push(
                Diagnostic::warning(
                    "trace.unlabeled_track",
                    "span lands on a (pid, tid) track with no process/thread \
                     metadata — viewers will show bare ids",
                )
                .with_entry(span_entry(i, s)),
            );
        }
        if s.name == "plan" {
            let source = s
                .args
                .iter()
                .find(|(k, _)| k == "source")
                .and_then(|(_, v)| v.as_str());
            match source {
                Some(src) if PLAN_SOURCES.contains(&src) => {}
                Some(src) => report.diagnostics.push(
                    Diagnostic::error(
                        "trace.bad_plan_source",
                        format!("plan source {src:?} is not one of {PLAN_SOURCES:?}"),
                    )
                    .with_entry(span_entry(i, s)),
                ),
                None => report.diagnostics.push(
                    Diagnostic::error(
                        "trace.bad_plan_source",
                        "plan span carries no 'source' arg",
                    )
                    .with_entry(span_entry(i, s)),
                ),
            }
        }
        if s.dur_us.is_some() {
            tracks
                .entry((s.pid, s.tid))
                .or_default()
                .push((s.ts_us, s.ts_us + dur, i));
        }
    }
    for spans in tracks.values_mut() {
        spans.sort_by(|a, b| a.partial_cmp(b).expect("finite by the pass above"));
        for w in spans.windows(2) {
            let ((_, prev_end, pi), (start, _, si)) = (w[0], w[1]);
            if start < prev_end - OVERLAP_EPS_US {
                report.diagnostics.push(
                    Diagnostic::error(
                        "trace.overlap",
                        format!(
                            "overlaps '{}' (span #{pi}) by {:.3} µs on the same track",
                            trace.spans[pi].name,
                            prev_end - start
                        ),
                    )
                    .with_entry(span_entry(si, &trace.spans[si])),
                );
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn labeled(mut t: Trace) -> Trace {
        t.processes = vec![(0, "p".to_string())];
        t.threads = vec![(0, 0, "t".to_string())];
        t
    }

    fn codes(r: &AuditReport) -> Vec<&'static str> {
        r.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_trace_audits_clean_and_non_vacuously() {
        let t = labeled(Trace {
            spans: vec![
                Span::complete("form", "serve", 0, 0, 0.0, 1e-3),
                Span::complete("exec", "serve", 0, 0, 1e-3, 2e-3),
                Span::instant("plan", "serve", 0, 0, 1e-3)
                    .arg("source", Json::str("table")),
                Span::complete("candgen", "compile", 0, 0, 5e-3, 1e-3).wall(),
            ],
            ..Trace::default()
        });
        let r = audit_trace(&t);
        assert!(r.is_clean(true), "{:?}", r.diagnostics);
        assert_eq!(r.spans_checked, 4);
    }

    #[test]
    fn wall_clock_in_a_serving_cat_is_refused() {
        let t = labeled(Trace {
            spans: vec![Span::complete("exec", "serve", 0, 0, 0.0, 1e-3).wall()],
            ..Trace::default()
        });
        assert_eq!(codes(&audit_trace(&t)), vec!["trace.wall_in_serving"]);
    }

    #[test]
    fn time_pathologies_are_refused() {
        let t = labeled(Trace {
            spans: vec![
                Span::complete("a", "serve", 0, 0, f64::NAN, 1.0),
                Span::complete("b", "serve", 0, 0, 0.0, -1.0),
            ],
            ..Trace::default()
        });
        assert_eq!(
            codes(&audit_trace(&t)),
            vec!["trace.nonfinite_time", "trace.negative_duration"]
        );
    }

    #[test]
    fn overlapping_spans_on_one_track_are_refused_but_cross_track_is_fine() {
        let mut t = labeled(Trace {
            spans: vec![
                Span::complete("a", "serve", 0, 0, 0.0, 2e-3),
                Span::complete("b", "serve", 0, 0, 1e-3, 2e-3),
            ],
            ..Trace::default()
        });
        assert_eq!(codes(&audit_trace(&t)), vec!["trace.overlap"]);
        // Same intervals on different tracks: concurrent lanes are fine.
        t.spans[1].tid = 1;
        t.threads.push((0, 1, "t2".to_string()));
        assert!(audit_trace(&t).is_clean(true));
        // Exact contiguity with µs-conversion rounding is not overlap.
        let c = labeled(Trace {
            spans: vec![
                Span::complete("a", "serve", 0, 0, 0.3, 0.1),
                Span::complete("b", "serve", 0, 0, 0.4, 0.1),
            ],
            ..Trace::default()
        });
        assert!(audit_trace(&c).is_clean(true), "{:?}", audit_trace(&c).diagnostics);
    }

    #[test]
    fn plan_spans_must_name_a_known_source() {
        let bad = labeled(Trace {
            spans: vec![
                Span::instant("plan", "serve", 0, 0, 0.0).arg("source", Json::str("psychic")),
                Span::instant("plan", "serve", 0, 0, 1.0),
            ],
            ..Trace::default()
        });
        assert_eq!(
            codes(&audit_trace(&bad)),
            vec!["trace.bad_plan_source", "trace.bad_plan_source"]
        );
    }

    #[test]
    fn unlabeled_tracks_warn_but_do_not_error() {
        let t = Trace {
            spans: vec![Span::complete("a", "serve", 7, 7, 0.0, 1.0)],
            ..Trace::default()
        };
        let r = audit_trace(&t);
        assert_eq!(codes(&r), vec!["trace.unlabeled_track"]);
        assert!(r.is_clean(false) && !r.is_clean(true));
    }
}
